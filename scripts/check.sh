#!/usr/bin/env bash
# Tier-1 verify — the one-command CI entry point (see ROADMAP.md).
#
#   scripts/check.sh
#
# Builds the workspace in release mode, runs the full test suite
# (unit + integration: parallel-runtime grids, pool stress, property
# sweeps, engine equivalence, distributed replica sharding, the
# multi-process transport grid, budgeted-planner invariants, the
# fault-tolerance chaos grid, the tracing contract), re-runs the
# distributed, transport, planner, fault-tolerance, trace and
# reversible suites as dedicated invocations so
# replica/transport/planner/recovery/tracing/gradcheck failures stay
# visible at the end of CI output (MOONWALK_SLOW_TESTS=1 additionally
# runs the #[ignore]d slow matrices), then enforces the
# documentation surface (rustdoc must build warning-free and every
# doctest must pass — the doc system is tier-1 from PR 4 on), the
# perf_ops --quick smoke, which emits BENCH_perf_ops.json (including
# the replicas {1,2} scaling rows, the local/unix transport-overhead
# rows, the planner_rows budget sweep, the fault_rows recovery smoke,
# the conv_rows autotune family and the trace_rows tracing-overhead
# family; field schema in docs/BENCH_SCHEMA.md) so the perf trajectory
# stays diffable across commits, and finally a --trace train smoke on
# the local and unix transports asserting the merged Chrome trace is
# emitted and parses. Exits non-zero on the first failure.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
# Lint gate (PR 7): warnings are errors across every target. Accepted
# style lints are allowed centrally in Cargo.toml's [lints.clippy]
# table rather than scattered as inline #[allow]s.
cargo clippy --all-targets -- -D warnings
cargo test -q
cargo test -q --test distributed
cargo test -q --test transport
cargo test -q --test planner
cargo test -q --test fault_tolerance
cargo test -q --test trace
# Reversible layer family (PR 9): gradcheck battery, depth grids,
# planner free-vijp discovery, wire-format block topologies.
cargo test -q --test reversible
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo test -q --doc
# Opt-in slow tier: the #[ignore]d suites (full variant × engine ×
# thread matrices at depth 128, and any other marked-slow rows).
if [ "${MOONWALK_SLOW_TESTS:-0}" = "1" ]; then
  cargo test -q -- --include-ignored
fi
cargo bench --bench perf_ops -- --quick

# --trace smoke (PR 8): a tiny train run per transport must emit one
# merged, parseable Chrome trace file. Uses the release binary built
# above; python3 validates the JSON when available, otherwise the
# check degrades to non-empty.
trace_dir="$(mktemp -d)"
trap 'rm -rf "$trace_dir"' EXIT
cat > "$trace_dir/cfg.json" <<'EOF'
{"arch": "cnn2d", "depth": 2, "channels": 4, "input_hw": 16,
 "cin": 2, "classes": 4, "seed": 3, "batch": 4, "steps": 2,
 "dataset_size": 16}
EOF
for transport in local unix; do
  out="$trace_dir/$transport.trace.json"
  ./target/release/moonwalk train --config "$trace_dir/cfg.json" \
    --engine moonwalk --transport "$transport" --replicas 2 \
    --trace "$out"
  test -s "$out"
  if command -v python3 > /dev/null 2>&1; then
    python3 - "$out" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    trace = json.load(f)
events = trace["traceEvents"]
assert events, "empty traceEvents"
assert all("ph" in e and "pid" in e for e in events)
names = {e.get("name") for e in events}
assert "moonwalk.phase1" in names, sorted(names)
EOF
  fi
done
