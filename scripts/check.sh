#!/usr/bin/env bash
# Tier-1 verify — the one-command CI entry point (see ROADMAP.md).
#
#   scripts/check.sh
#
# Builds the workspace in release mode, runs the full test suite
# (unit + integration: parallel-runtime grids, pool stress, property
# sweeps, engine equivalence, distributed replica sharding), re-runs the
# distributed suite as a dedicated invocation so replica-sharding
# failures stay visible at the end of CI output, then the perf_ops
# --quick smoke, which emits BENCH_perf_ops.json (including the
# replicas {1,2} scaling rows) so the perf trajectory stays diffable
# across commits. Exits non-zero on the first failure.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo test -q --test distributed
cargo bench --bench perf_ops -- --quick
