#!/usr/bin/env bash
# Tier-1 verify — the one-command CI entry point (see ROADMAP.md).
#
#   scripts/check.sh
#
# Builds the workspace in release mode, runs the full test suite
# (unit + integration: parallel-runtime grids, pool stress, property
# sweeps, engine equivalence, distributed replica sharding, the
# multi-process transport grid, budgeted-planner invariants, the
# fault-tolerance chaos grid, the tracing contract, the live
# telemetry plane), re-runs the distributed, transport, planner,
# fault-tolerance, trace, reversible and metrics_http suites as
# dedicated invocations so replica/transport/planner/recovery/
# tracing/gradcheck/telemetry failures stay visible at the end of
# CI output (MOONWALK_SLOW_TESTS=1 additionally
# runs the #[ignore]d slow matrices), then enforces the
# documentation surface (rustdoc must build warning-free and every
# doctest must pass — the doc system is tier-1 from PR 4 on), the
# perf_ops --quick smoke, which emits BENCH_perf_ops.json (including
# the replicas {1,2} scaling rows, the local/unix transport-overhead
# rows, the planner_rows budget sweep, the fault_rows recovery smoke,
# the conv_rows autotune family, the trace_rows tracing-overhead
# family and the metrics_rows telemetry-overhead family; field schema
# in docs/BENCH_SCHEMA.md) so the perf trajectory stays diffable
# across commits, and finally three end-to-end smokes against the
# release binary: a --trace train per transport asserting the merged
# Chrome trace is emitted and parses, a `moonwalk report` pass over
# that trace asserting the attribution table / JSON / folded-stack
# outputs, and a --metrics-listen train asserting a live mid-run
# scrape returns valid exposition with the per-replica fleet series.
# Exits non-zero on the first failure.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
# Lint gate (PR 7): warnings are errors across every target. Accepted
# style lints are allowed centrally in Cargo.toml's [lints.clippy]
# table rather than scattered as inline #[allow]s.
cargo clippy --all-targets -- -D warnings
cargo test -q
cargo test -q --test distributed
cargo test -q --test transport
cargo test -q --test planner
cargo test -q --test fault_tolerance
cargo test -q --test trace
# Reversible layer family (PR 9): gradcheck battery, depth grids,
# planner free-vijp discovery, wire-format block topologies.
cargo test -q --test reversible
# Live telemetry plane (PR 10): exposition correctness, per-replica
# fleet series, snapshot schema, scrape determinism.
cargo test -q --test metrics_http
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo test -q --doc
# Opt-in slow tier: the #[ignore]d suites (full variant × engine ×
# thread matrices at depth 128, and any other marked-slow rows).
if [ "${MOONWALK_SLOW_TESTS:-0}" = "1" ]; then
  cargo test -q -- --include-ignored
fi
cargo bench --bench perf_ops -- --quick

# --trace smoke (PR 8): a tiny train run per transport must emit one
# merged, parseable Chrome trace file. Uses the release binary built
# above; python3 validates the JSON when available, otherwise the
# check degrades to non-empty.
trace_dir="$(mktemp -d)"
trap 'rm -rf "$trace_dir"' EXIT
cat > "$trace_dir/cfg.json" <<'EOF'
{"arch": "cnn2d", "depth": 2, "channels": 4, "input_hw": 16,
 "cin": 2, "classes": 4, "seed": 3, "batch": 4, "steps": 2,
 "dataset_size": 16}
EOF
for transport in local unix; do
  out="$trace_dir/$transport.trace.json"
  ./target/release/moonwalk train --config "$trace_dir/cfg.json" \
    --engine moonwalk --transport "$transport" --replicas 2 \
    --trace "$out"
  test -s "$out"
  if command -v python3 > /dev/null 2>&1; then
    python3 - "$out" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    trace = json.load(f)
events = trace["traceEvents"]
assert events, "empty traceEvents"
assert all("ph" in e and "pid" in e for e in events)
names = {e.get("name") for e in events}
assert "moonwalk.phase1" in names, sorted(names)
EOF
  fi
done

# Profile report smoke (PR 10): `moonwalk report` over the unix trace
# emitted above must print an attribution table, write the JSON view,
# and emit a non-empty folded-stack file.
./target/release/moonwalk report "$trace_dir/unix.trace.json" \
  --json "$trace_dir/report.json" --folded "$trace_dir/report.folded" \
  > "$trace_dir/report.txt"
grep -q "phase totals:" "$trace_dir/report.txt"
test -s "$trace_dir/report.json"
test -s "$trace_dir/report.folded"

# Metrics endpoint smoke (PR 10): a short 2-replica unix train with
# --metrics-listen 127.0.0.1:0 must print its resolved ephemeral
# endpoint and serve valid Prometheus text exposition mid-run,
# including the per-replica fleet series the workers piggyback over
# the wire. Skips gracefully when the binary or python3 is absent
# (mirroring the perf_ops --quick skip symmetry).
if [ ! -x ./target/release/moonwalk ]; then
  echo "metrics smoke: skipped (moonwalk binary not built)"
elif ! command -v python3 > /dev/null 2>&1; then
  echo "metrics smoke: skipped (python3 not available)"
else
  cat > "$trace_dir/metrics_cfg.json" <<'EOF'
{"arch": "cnn2d", "depth": 2, "channels": 4, "input_hw": 16,
 "cin": 2, "classes": 4, "seed": 5, "batch": 4, "steps": 12,
 "dataset_size": 32}
EOF
  metrics_log="$trace_dir/metrics_train.log"
  ./target/release/moonwalk train --config "$trace_dir/metrics_cfg.json" \
    --engine moonwalk --transport unix --replicas 2 \
    --metrics-listen 127.0.0.1:0 > "$metrics_log" 2>&1 &
  train_pid=$!
  endpoint=""
  for _ in $(seq 1 100); do
    endpoint="$(sed -n 's#^metrics endpoint listening on http://\([^/]*\)/metrics$#\1#p' "$metrics_log")"
    if [ -n "$endpoint" ]; then
      break
    fi
    if ! kill -0 "$train_pid" 2> /dev/null; then
      break
    fi
    sleep 0.1
  done
  if [ -z "$endpoint" ]; then
    cat "$metrics_log"
    echo "metrics smoke: endpoint line never appeared" >&2
    exit 1
  fi
  python3 - "$endpoint" "$train_pid" <<'EOF'
import os, re, sys, time, urllib.request

addr, pid = sys.argv[1], int(sys.argv[2])
name = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
label = rf'{name}="[^"]*"'
sample = re.compile(rf"^{name}(\{{{label}(,{label})*\}})? \S+$")

def alive(pid):
    try:
        os.kill(pid, 0)
        return True
    except OSError:
        return False

must_have = [
    'moonwalk_step_seconds_count{replica="0"}',
    'moonwalk_step_seconds_count{replica="1"}',
    'moonwalk_transport_step_seconds_count{replica="0"}',
    "moonwalk_tracker_peak_bytes",
]
found = set()
scrapes = 0
deadline = time.time() + 60
while time.time() < deadline and len(found) < len(must_have):
    try:
        with urllib.request.urlopen(f"http://{addr}/metrics", timeout=5) as r:
            body = r.read().decode()
    except OSError:
        if not alive(pid):
            break  # the run (and with it the listener) already exited
        time.sleep(0.1)
        continue
    scrapes += 1
    lines = body.splitlines()
    for line in lines:
        if not line:
            continue
        if line.startswith("#"):
            # TYPE lines plus the renderer's own "# moonwalk:" notes
            # (e.g. mixed-kind series skips) are the only comments.
            assert line.startswith(("# TYPE ", "# moonwalk:")), f"unexpected comment: {line!r}"
            continue
        assert sample.match(line), f"exposition grammar violation: {line!r}"
    for key in must_have:
        if any(l.startswith(key + " ") for l in lines):
            found.add(key)
    time.sleep(0.2)
assert scrapes > 0, "never managed to scrape the live endpoint"
missing = sorted(set(must_have) - found)
assert not missing, f"series never appeared across {scrapes} scrapes: {missing}"
print(f"metrics smoke: {scrapes} scrape(s), all must-have series present")
EOF
  wait "$train_pid"
fi
