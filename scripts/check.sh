#!/usr/bin/env bash
# Tier-1 verify — the one-command CI entry point (see ROADMAP.md).
#
#   scripts/check.sh
#
# Builds the workspace in release mode, runs the full test suite
# (unit + integration: parallel-runtime grids, pool stress, property
# sweeps, engine equivalence, distributed replica sharding, the
# multi-process transport grid, budgeted-planner invariants, the
# fault-tolerance chaos grid), re-runs the distributed, transport,
# planner and fault-tolerance suites as dedicated invocations so
# replica/transport/planner/recovery failures stay visible at the end
# of CI output, then enforces the documentation surface (rustdoc must
# build warning-free and every doctest must pass — the doc system is
# tier-1 from PR 4 on), and finally the perf_ops --quick smoke, which
# emits BENCH_perf_ops.json (including the replicas {1,2} scaling
# rows, the local/unix transport-overhead rows, the planner_rows
# budget sweep, the fault_rows recovery smoke and the conv_rows
# autotune family; field schema in docs/BENCH_SCHEMA.md) so the perf
# trajectory stays diffable across commits. Exits non-zero on the
# first failure.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
# Lint gate (PR 7): warnings are errors across every target. Accepted
# style lints are allowed centrally in Cargo.toml's [lints.clippy]
# table rather than scattered as inline #[allow]s.
cargo clippy --all-targets -- -D warnings
cargo test -q
cargo test -q --test distributed
cargo test -q --test transport
cargo test -q --test planner
cargo test -q --test fault_tolerance
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo test -q --doc
cargo bench --bench perf_ops -- --quick
