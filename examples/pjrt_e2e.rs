//! End-to-end three-layer validation (DESIGN.md §6): train the flagship
//! submersive CNN on a real (synthetic-texture) classification workload
//! with **every conv/activation/dense/loss op executing inside
//! PJRT-compiled XLA executables** produced by the JAX/Pallas AOT path —
//! including the paper's vijp operator as the Pallas Alg.-2 kernel.
//! Python is not running: the HLO was lowered once by `make artifacts`.
//!
//! The driver implements mixed-mode Moonwalk (Alg. 1) over the compiled
//! ops, cross-checks its gradients against the native Rust Backprop
//! engine at step 0, trains for `steps` steps with SGD + submersive
//! projection, and logs the loss curve to artifacts/e2e_metrics.jsonl.
//!
//! Run: `make artifacts && cargo run --release --example pjrt_e2e [steps]`

use std::path::Path;

use moonwalk::autodiff::{Backprop, GradEngine};
use moonwalk::coordinator::{SyntheticSpec, TextureDataset};
use moonwalk::model::Network;
use moonwalk::nn::{
    Conv2d, Dense, Layer, LeakyRelu, Loss, MaxPool2d, ResidualKind, SoftmaxCrossEntropy,
    Upsample,
};
use moonwalk::runtime::PjrtRuntime;
use moonwalk::tensor::{rel_err, Tensor};
use moonwalk::util::json::Json;
use moonwalk::util::logging::JsonlWriter;
use moonwalk::util::{Rng, Timer};

struct E2eModel {
    rt: PjrtRuntime,
    // Native mirrors own the parameters (and the submersive projection).
    convs: Vec<Conv2d>,
    dense: Dense,
    upsample: Upsample,
    pool: Option<MaxPool2d>,
    lrelu: LeakyRelu,
    batch: usize,
    classes: usize,
    dense_in: usize,
}

struct StepOut {
    loss: f32,
    logits: Tensor,
    grads: Vec<(String, Tensor)>,
}

impl E2eModel {
    fn load(dir: &Path, rng: &mut Rng) -> anyhow::Result<E2eModel> {
        let rt = PjrtRuntime::load(dir)?;
        let cfg = rt.manifest.config.clone();
        let (ch, k, s, p) = (
            cfg.req_usize("channels")?,
            cfg.req_usize("k")?,
            cfg.req_usize("stride")?,
            cfg.req_usize("pad")?,
        );
        let depth = cfg.req_usize("depth")?;
        let convs: Vec<Conv2d> = (0..depth)
            .map(|_| Conv2d::new_submersive(k, ch, ch, s, p, false, rng))
            .collect();
        let dense_in = cfg.req_usize("dense_in")?;
        let classes = cfg.req_usize("classes")?;
        let pool_w = cfg.req_usize("pool")?;
        Ok(E2eModel {
            convs,
            dense: Dense::new(dense_in, classes, true, rng),
            upsample: Upsample::new(cfg.req_usize("cin")?, ch),
            pool: (pool_w > 1).then(|| MaxPool2d::new(pool_w)),
            lrelu: LeakyRelu::new(cfg.req_f64("alpha")? as f32),
            batch: cfg.req_usize("batch")?,
            classes,
            dense_in,
            rt,
        })
    }

    /// A native Network sharing this model's parameter values (for the
    /// gradient cross-check).
    fn native_mirror(&self) -> Network {
        let mut layers: Vec<Box<dyn Layer>> = Vec::new();
        layers.push(Box::new(Upsample::new(self.upsample.cin, self.upsample.cout)));
        for c in &self.convs {
            let mut clone = Conv2d::new_submersive(
                c.k, c.cin, c.cout, c.stride, c.pad, false,
                &mut Rng::new(0),
            );
            clone.w = c.w.clone();
            layers.push(Box::new(clone));
            layers.push(Box::new(LeakyRelu::new(self.lrelu.alpha)));
        }
        if let Some(p) = &self.pool {
            layers.push(Box::new(MaxPool2d::new(p.window)));
        }
        let mut d = Dense::new(self.dense.din, self.dense.dout, true, &mut Rng::new(0));
        d.w = self.dense.w.clone();
        d.bias = self.dense.bias.clone();
        layers.push(Box::new(d));
        Network::new(layers)
    }

    /// One Moonwalk (Alg. 1) loss+gradient evaluation over PJRT ops.
    fn moonwalk_step(&self, x: &Tensor, onehot: &Tensor) -> anyhow::Result<StepOut> {
        let rt = &self.rt;
        let depth = self.convs.len();

        // ---- Phase I: forward through the compiled executables.
        let x_up = self.upsample.forward(x);
        let mut conv_in = Vec::with_capacity(depth); // inputs to each conv
        let mut conv_out = Vec::with_capacity(depth); // pre-activations
        let mut act = x_up.clone();
        for (i, conv) in self.convs.iter().enumerate() {
            conv_in.push(act.clone());
            let c = rt.execute1(&format!("conv{i}_fwd"), &[&act, &conv.w])?;
            act = rt.execute1(&format!("lrelu{i}_fwd"), &[&c])?;
            conv_out.push(c);
        }
        let (pooled, pool_res) = match &self.pool {
            Some(p) => {
                let (y, res) = p.forward_res(&act, ResidualKind::Minimal);
                (y, Some(res))
            }
            None => (act.clone(), None),
        };
        let flat = pooled.reshape(&[self.batch, self.dense_in]);
        let logits = rt.execute1(
            "dense_fwd",
            &[&flat, &self.dense.w, self.dense.bias.as_ref().unwrap()],
        )?;
        let mut out = rt.execute("loss_grad", &[&logits, onehot])?;
        let g_logits = out.pop().unwrap();
        let loss = out.pop().unwrap().data()[0];

        // ---- Phase II: input-cotangent sweep; anchor at conv0's output
        // (the h₁ seed — the chain is broken by the channel-expanding
        // upsample, §4.3).
        let h_flat = rt.execute1("dense_vjp_in", &[&g_logits, &self.dense.w])?;
        let h_pooled = h_flat.reshape(pooled.shape());
        let mut h = match (&self.pool, &pool_res) {
            (Some(p), Some(res)) => p.vjp_input(res, &h_pooled),
            _ => h_pooled,
        };
        // back through blocks depth-1 .. 1, stopping at the anchor
        let mut anchor = None;
        for i in (0..depth).rev() {
            let h_c = rt.execute1(&format!("lrelu{i}_vjp"), &[&conv_out[i], &h])?;
            if i == 0 {
                anchor = Some(h_c); // output cotangent of conv0
                break;
            }
            h = rt.execute1(&format!("conv{i}_vjp_in"), &[&h_c, &self.convs[i].w])?;
        }
        let anchor = anchor.expect("depth >= 1");

        // ---- Phase III: forward vijp sweep (Alg. 1), grads as we go.
        let mut grads: Vec<(String, Tensor)> = Vec::new();
        let mut h = anchor;
        for i in 0..depth {
            if i > 0 {
                // cotangent entering conv i is the lrelu output cotangent;
                // push it through conv i with the Pallas vijp kernel.
                h = rt.execute1(&format!("conv{i}_vijp"), &[&h, &self.convs[i].w])?;
            }
            grads.push((
                format!("conv{i}"),
                rt.execute1(&format!("conv{i}_vjp_w"), &[&conv_in[i], &h])?,
            ));
            if i + 1 < depth {
                h = rt.execute1(&format!("lrelu{i}_vijp"), &[&conv_out[i], &h])?;
            }
        }
        let mut dw = rt.execute("dense_vjp_w", &[&flat, &g_logits])?;
        let db = dw.pop().unwrap();
        let dwt = dw.pop().unwrap();
        grads.push(("dense_w".into(), dwt));
        grads.push(("dense_b".into(), db));

        Ok(StepOut {
            loss,
            logits,
            grads,
        })
    }

    fn apply_sgd(&mut self, grads: &[(String, Tensor)], lr: f32) {
        for (name, g) in grads {
            let target: &mut Tensor = if let Some(rest) = name.strip_prefix("conv") {
                let i: usize = rest.parse().unwrap();
                &mut self.convs[i].w
            } else if name == "dense_w" {
                &mut self.dense.w
            } else {
                self.dense.bias.as_mut().unwrap()
            };
            for (p, gv) in target.data_mut().iter_mut().zip(g.data()) {
                *p -= lr * gv;
            }
        }
        for c in &mut self.convs {
            c.project_submersive(); // keep Lemma-1 constraints (§6.4)
        }
    }
}

fn onehot(labels: &[usize], classes: usize) -> Tensor {
    let mut t = Tensor::zeros(&[labels.len(), classes]);
    for (i, &l) in labels.iter().enumerate() {
        t.data_mut()[i * classes + l] = 1.0;
    }
    t
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps"))
        .unwrap_or(300);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rng = Rng::new(42);
    let mut model = E2eModel::load(&dir, &mut rng)?;
    println!(
        "loaded {} compiled ops on {} (depth {}, batch {})",
        model.rt.op_names().len(),
        model.rt.platform(),
        model.convs.len(),
        model.batch
    );

    let cfg = model.rt.manifest.config.clone();
    let data = TextureDataset::generate(
        SyntheticSpec {
            classes: model.classes,
            hw: cfg.req_usize("hw")?,
            cin: cfg.req_usize("cin")?,
            noise: 0.25,
            seed: 42,
        },
        512,
    );
    let (train, test) = data.split(0.2);

    // ---- Step-0 gradient cross-check: PJRT Moonwalk vs native Backprop.
    let (x0, labels0) = train.batch(&(0..model.batch).collect::<Vec<_>>());
    let oh0 = onehot(&labels0, model.classes);
    let pjrt_out = model.moonwalk_step(&x0, &oh0)?;
    let native = model.native_mirror();
    let loss0 = SoftmaxCrossEntropy::new(labels0.clone());
    let native_out = Backprop.compute(&native, &x0, &loss0)?;
    let mut native_grads: Vec<&Tensor> = Vec::new();
    for g in native_out.grads.iter() {
        for t in g {
            native_grads.push(t);
        }
    }
    let mut worst = 0f32;
    for ((name, g_pjrt), g_native) in pjrt_out.grads.iter().zip(&native_grads) {
        let err = rel_err(g_pjrt, g_native);
        worst = worst.max(err);
        println!("  gradcheck {name:<8} rel err {err:.2e}");
    }
    assert!(
        worst < 5e-3,
        "PJRT Moonwalk disagrees with native Backprop: {worst}"
    );
    assert!((pjrt_out.loss - native_out.loss).abs() < 1e-4);
    println!("gradcheck OK (max rel err {worst:.2e}); training {steps} steps...");

    // ---- Training loop, all compute through PJRT executables.
    let metrics_path = dir.join("e2e_metrics.jsonl");
    let mut metrics = JsonlWriter::create(&metrics_path)?;
    let timer = Timer::start();
    let mut curve = Vec::new();
    let mut batches: Vec<Vec<usize>> = Vec::new();
    let lr = 0.05;
    for step in 0..steps {
        if batches.is_empty() {
            batches = train.epoch_batches(model.batch, &mut rng);
            batches.reverse();
        }
        let idx = batches.pop().unwrap();
        let (x, labels) = train.batch(&idx);
        let oh = onehot(&labels, model.classes);
        let out = model.moonwalk_step(&x, &oh)?;
        model.apply_sgd(&out.grads, lr);
        curve.push(out.loss);
        if step % 10 == 0 || step + 1 == steps {
            let acc = SoftmaxCrossEntropy::new(labels.clone()).accuracy(&out.logits);
            metrics.write(&Json::from_pairs(vec![
                ("step", step.into()),
                ("loss", (out.loss as f64).into()),
                ("batch_acc", (acc as f64).into()),
            ]))?;
        }
    }
    metrics.flush()?;

    // ---- Evaluation through the compiled forward path.
    let eval = |ds: &TextureDataset| -> anyhow::Result<f32> {
        let mut correct = 0f32;
        let mut count = 0usize;
        let idx: Vec<usize> = (0..ds.len()).collect();
        for chunk in idx.chunks(model.batch) {
            if chunk.len() != model.batch {
                continue; // fixed-shape executables
            }
            let (x, labels) = ds.batch(chunk);
            let oh = onehot(&labels, model.classes);
            let out = model.moonwalk_step(&x, &oh)?;
            correct +=
                SoftmaxCrossEntropy::new(labels.clone()).accuracy(&out.logits) * chunk.len() as f32;
            count += chunk.len();
        }
        Ok(correct / count as f32)
    };
    let train_acc = eval(&train)?;
    let test_acc = eval(&test)?;
    let early: f32 = curve[..10.min(curve.len())].iter().sum::<f32>() / 10.0;
    let late: f32 =
        curve[curve.len().saturating_sub(10)..].iter().sum::<f32>() / 10.0;
    println!(
        "e2e: steps={steps} loss {early:.3} -> {late:.3}, train_acc={train_acc:.3}, \
         test_acc={test_acc:.3}, wall={:.1}s, metrics={}",
        timer.elapsed_s(),
        metrics_path.display()
    );
    assert!(late < early, "loss must decrease");
    Ok(())
}
