//! Fig.-4 experiment (§6.4): constrained (Lemma-1 upper-triangular)
//! versus unconstrained convolutions on a classification task — both
//! should reach comparable accuracy, showing the submersive
//! parameterization does not cost expressivity.
//!
//! Run: `cargo run --release --example train_classifier [steps]`

use moonwalk::autodiff::{engine_by_name, GradEngine};
use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::util::Rng;

fn run(constrained: bool, steps: usize, engine: &dyn GradEngine) -> anyhow::Result<(f32, f32)> {
    let spec = SubmersiveCnn2dSpec {
        input_hw: 32,
        channels: 16,
        depth: 3,
        classes: 4,
        cin: 3,
        constrained,
        ..Default::default()
    };
    let mut rng = Rng::new(7);
    let mut net = build_cnn2d(&spec, &mut rng);
    let data = TextureDataset::generate(
        SyntheticSpec {
            classes: 4,
            hw: 32,
            cin: 3,
            noise: 1.25,
            seed: 7,
        },
        640,
    );
    let (train, test) = data.split(0.2);
    let opt = Optimizer::new(OptimizerKind::Adam, 2e-3, &net, constrained);
    let mut trainer = Trainer::new(&mut net, engine, opt);
    let mut rng2 = Rng::new(8);
    let report = trainer.train(&train, &test, 8, steps, &mut rng2, None)?;
    println!(
        "  constrained={constrained:<5} engine={:<10} final_loss={:.4} train_acc={:.3} test_acc={:.3} ({:.1}s)",
        engine.name(),
        report.final_loss,
        report.train_accuracy,
        report.test_accuracy,
        report.total_time_s
    );
    Ok((report.train_accuracy, report.test_accuracy))
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps"))
        .unwrap_or(150);
    println!("Fig. 4: constrained vs unconstrained convolutions ({steps} steps)");
    // Constrained model trains with Moonwalk (its whole point); the
    // unconstrained baseline uses Backprop.
    let moonwalk = engine_by_name("moonwalk", 4, 0, 0)?;
    let backprop = engine_by_name("backprop", 4, 0, 0)?;
    let (_, acc_con) = run(true, steps, moonwalk.as_ref())?;
    let (_, acc_unc) = run(false, steps, backprop.as_ref())?;
    println!(
        "test accuracy: constrained {acc_con:.3} vs unconstrained {acc_unc:.3} (paper: both ≈0.90)"
    );
    Ok(())
}
