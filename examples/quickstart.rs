//! Quickstart: build a submersive CNN, compute gradients with Backprop
//! and Moonwalk, verify they agree exactly, and compare peak memory —
//! the paper's core claim in ~60 lines of user code.
//!
//! Run: `cargo run --release --example quickstart`

use moonwalk::autodiff::{Backprop, GradEngine, Moonwalk, MoonwalkOpts};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::{rel_err, tracker, Tensor};
use moonwalk::util::Rng;

fn main() -> anyhow::Result<()> {
    // The paper's §6.2 architecture, scaled for CPU: 3→32 channels,
    // 3×3 stride-2 pad-1 submersive convolutions + LeakyReLU.
    let spec = SubmersiveCnn2dSpec {
        input_hw: 64,
        channels: 32,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(0);
    let net = build_cnn2d(&spec, &mut rng);
    let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
    println!(
        "network: {} layers, {} parameters, submersive suffix: {}",
        net.depth(),
        net.n_params(),
        net.audit()[1..].iter().all(|s| s.is_submersive())
    );

    // Gradients via both engines.
    let bp = Backprop.compute(&net, &x, &MeanLoss)?;
    let mw = Moonwalk::new(MoonwalkOpts::default()).compute(&net, &x, &MeanLoss)?;
    let mut worst = 0f32;
    for (a, b) in bp.grads.iter().flatten().zip(mw.grads.iter().flatten()) {
        worst = worst.max(rel_err(b, a));
    }
    println!("loss: backprop {:.6} vs moonwalk {:.6}", bp.loss, mw.loss);
    println!("max relative gradient error: {worst:.2e} (exact up to fp)");
    assert!(worst < 5e-3);

    // Peak memory under the paper's grad-free accounting (§11).
    let (_, bp_mem) = tracker::measure(|| {
        Backprop
            .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
            .unwrap()
    });
    let (_, mw_mem) = tracker::measure(|| {
        Moonwalk::new(MoonwalkOpts::default())
            .compute_streaming(&net, &x, &MeanLoss, &mut |_, g| drop(g))
            .unwrap()
    });
    println!(
        "peak extra memory: backprop {} vs moonwalk {}  ({:.0}% saving)",
        tracker::fmt_bytes(bp_mem.peak_extra_bytes),
        tracker::fmt_bytes(mw_mem.peak_extra_bytes),
        100.0 * (1.0 - mw_mem.peak_extra_bytes as f64 / bp_mem.peak_extra_bytes as f64)
    );
    Ok(())
}
