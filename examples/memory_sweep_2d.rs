//! Fig.-2 style sweep (§6.2): peak memory and wall-clock vs depth for
//! Backprop, checkpointed Backprop and Moonwalk on the fully parallel
//! submersive 2-D CNN.
//!
//! Run: `cargo run --release --example memory_sweep_2d [depths...]`
//! (cargo bench --bench fig2_2d produces the full figure data.)

use moonwalk::autodiff::engine_by_name;
use moonwalk::coordinator::sweep::{format_table, measure_engine, SweepRow};
use moonwalk::model::{build_cnn2d, SubmersiveCnn2dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::Tensor;
use moonwalk::util::Rng;

fn main() -> anyhow::Result<()> {
    let depths: Vec<usize> = std::env::args()
        .skip(1)
        .map(|a| a.parse().expect("depth"))
        .collect();
    let depths = if depths.is_empty() {
        vec![1, 2, 3, 4, 6, 8]
    } else {
        depths
    };
    let mut rows = Vec::new();
    for &depth in &depths {
        let spec = SubmersiveCnn2dSpec {
            input_hw: 64,
            channels: 32,
            depth,
            ..Default::default()
        };
        let mut rng = Rng::new(0);
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[4, 64, 64, 3], 1.0, &mut rng);
        for name in ["backprop", "backprop_ckpt", "moonwalk"] {
            let engine = engine_by_name(name, 4, 0, 0)?;
            let (mem, time, loss) = measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, 3)?;
            rows.push(SweepRow {
                engine: engine.name(),
                depth,
                param: 0,
                peak_mem_bytes: mem,
                median_time_s: time,
                loss,
            });
        }
    }
    print!("{}", format_table("2-D submersive CNN sweep (Fig. 2)", &rows));
    Ok(())
}
