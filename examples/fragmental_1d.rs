//! Fig.-3 style demo (§6.3): the non-submersive 1-D CNN with fragmental
//! gradient checkpointing — memory/time across block sizes B, plus the
//! exactness check against Backprop.
//!
//! Run: `cargo run --release --example fragmental_1d`

use moonwalk::autodiff::{Backprop, GradEngine, Moonwalk, MoonwalkOpts};
use moonwalk::coordinator::sweep::{format_table, measure_engine, SweepRow};
use moonwalk::model::{build_cnn1d_fragmental, FragmentalCnn1dSpec};
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::{rel_err, Tensor};
use moonwalk::util::Rng;

fn main() -> anyhow::Result<()> {
    let spec = FragmentalCnn1dSpec {
        input_len: 512,
        channels: 64,
        depth: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(0);
    let net = build_cnn1d_fragmental(&spec, &mut rng);
    let x = Tensor::randn(&[4, 512, 3], 1.0, &mut rng);

    // Exactness: fragmental Moonwalk equals Backprop.
    let bp = Backprop.compute(&net, &x, &MeanLoss)?;
    let frag = Moonwalk::new(MoonwalkOpts {
        fragment_block: Some(8),
        ..Default::default()
    });
    let fr = frag.compute(&net, &x, &MeanLoss)?;
    let mut worst = 0f32;
    for (a, b) in bp.grads.iter().flatten().zip(fr.grads.iter().flatten()) {
        worst = worst.max(rel_err(b, a));
    }
    println!("fragmental vs backprop: max rel grad err {worst:.2e}");
    assert!(worst < 5e-3);

    // Block-size trade-off (Fig. 3b): larger B → less memory, more
    // recomputation.
    let mut rows = Vec::new();
    let (mem, time, loss) = measure_engine(&Backprop, &net, &x, &MeanLoss, 1, 3)?;
    rows.push(SweepRow {
        engine: "backprop".into(),
        depth: spec.depth,
        param: 0,
        peak_mem_bytes: mem,
        median_time_s: time,
        loss,
    });
    for block in [4usize, 8, 16, 32] {
        let engine = Moonwalk::new(MoonwalkOpts {
            fragment_block: Some(block),
            ..Default::default()
        });
        let (mem, time, loss) = measure_engine(&engine, &net, &x, &MeanLoss, 1, 3)?;
        rows.push(SweepRow {
            engine: engine.name(),
            depth: spec.depth,
            param: block,
            peak_mem_bytes: mem,
            median_time_s: time,
            loss,
        });
    }
    print!("{}", format_table("1-D fragmental checkpointing (Fig. 3)", &rows));
    Ok(())
}
