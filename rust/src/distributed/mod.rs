//! Data-parallel replica sharding on top of the persistent worker pool.
//!
//! The scale-out seam the ROADMAP calls for: a [`ReplicaGroup`] runs one
//! [`GradEngine`] per replica over disjoint sub-batches of a global
//! batch, all on `runtime::pool`'s persistent team, and reduces
//! gradients **per layer, streamed** through
//! [`reduce::StreamingAllReduce`]: the moment every replica has emitted a
//! layer (the paper's §4.3 streamed-gradient property), that layer is
//! all-reduced on the delivering thread — overlapped with the other
//! replicas' still-running sweeps — and handed to the caller's sink. No
//! full gradient buffer is ever required, so the no-stored-activations
//! property survives sharding.
//!
//! Scheduling: replicas fan out as one pool region, so each replica's
//! engine runs with nested kernel parallelism suppressed — the batch
//! axis *is* the parallel axis, exactly as it is for the batch-parallel
//! conv kernels. With one replica the engine runs on the calling thread
//! with full internal parallelism (the group is a no-op wrapper there).
//! Determinism mirrors the pool's contract: fixed replica count + fixed
//! thread count ⇒ bit-identical gradients run-to-run, because per-replica
//! computation is deterministic and the reduce folds in replica order.
//!
//! A panicking replica is caught by the pool, re-raised on the submitting
//! thread, and the team keeps serving later regions; an `Err` from a
//! replica's engine aborts the step with that replica's error. Replica
//! count resolution: explicit [`set_replicas`] (the CLI's `--replicas`) >
//! `MOONWALK_REPLICAS` env var > 1.
//!
//! The companion [`pipeline`] module supplies the deterministic sharded
//! batches (double-buffered prefetch); [`broadcast`] syncs replica-local
//! parameter copies from a source network — in-process replicas normally
//! share one `&Network`, but the broadcast is the construction-time sync
//! step the future multi-process transport will reuse.

pub mod pipeline;
pub mod reduce;

pub use reduce::{ReduceOp, StreamingAllReduce};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::Loss;
use crate::runtime::pool;
use crate::tensor::Tensor;

// ----- replica-count resolution ---------------------------------------------

/// Global replica budget; 0 = not yet resolved.
static REPLICAS: AtomicUsize = AtomicUsize::new(0);

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("MOONWALK_REPLICAS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    1
}

/// The configured replica count (resolving lazily on first use):
/// [`set_replicas`] > `MOONWALK_REPLICAS` > 1.
pub fn replicas() -> usize {
    let r = REPLICAS.load(Ordering::Relaxed);
    if r != 0 {
        return r;
    }
    let r = resolve_default();
    REPLICAS.store(r, Ordering::Relaxed);
    r
}

/// Set the replica count explicitly (CLI `--replicas`). Clamped to ≥ 1.
pub fn set_replicas(n: usize) {
    REPLICAS.store(n.max(1), Ordering::Relaxed);
}

// ----- parameter broadcast ---------------------------------------------------

/// Broadcast `src`'s parameters into every replica-local network copy
/// (shape-checked, bit-exact). The group-construction sync step of a
/// data-parallel setup.
pub fn broadcast(src: &Network, locals: &mut [Network]) -> anyhow::Result<()> {
    for (r, local) in locals.iter_mut().enumerate() {
        local
            .copy_params_from(src)
            .map_err(|e| e.context(format!("broadcast to replica {r}")))?;
    }
    Ok(())
}

// ----- the replica group -----------------------------------------------------

/// One replica's slice of a global step: its input shard and loss head
/// (the loss holds shard-local targets).
pub struct Shard<'a> {
    pub x: &'a Tensor,
    pub loss: &'a dyn Loss,
}

/// Loss/timing summary of one replicated gradient step.
#[derive(Clone, Debug)]
pub struct ReplicaStep {
    /// Mean of the per-replica losses — the global-batch loss for equal
    /// shards under a per-shard mean loss.
    pub loss: f32,
    /// Per-replica shard losses, in replica order.
    pub replica_losses: Vec<f32>,
    /// Wall-clock spent folding inside the streaming all-reduce (overlaps
    /// the replicas' sweeps; compare against step time for the overlap
    /// ratio the perf bench tracks).
    pub reduce_s: f64,
}

/// [`ReplicaStep`] plus the collected reduced gradients (convenience
/// mirror of [`GradEngine::compute`]).
pub struct ReplicaResult {
    pub loss: f32,
    pub replica_losses: Vec<f32>,
    /// Per-layer reduced gradients, aligned with `net.layers` (empty for
    /// parameter-free layers).
    pub grads: Vec<Vec<Tensor>>,
    pub reduce_s: f64,
}

/// A fixed-size data-parallel replica group (see module docs).
pub struct ReplicaGroup {
    replicas: usize,
}

impl ReplicaGroup {
    pub fn new(replicas: usize) -> anyhow::Result<ReplicaGroup> {
        anyhow::ensure!(replicas >= 1, "replica count must be >= 1");
        Ok(ReplicaGroup { replicas })
    }

    /// A group sized to `locals`, after broadcasting `src`'s parameters
    /// into every replica-local copy (the multi-process seam; in-process
    /// callers usually share one `&Network` and use [`ReplicaGroup::new`]).
    pub fn new_synced(src: &Network, locals: &mut [Network]) -> anyhow::Result<ReplicaGroup> {
        broadcast(src, locals)?;
        ReplicaGroup::new(locals.len())
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Run `engine` once per replica over `shards` (one shard per
    /// replica, replica order) and stream each layer's **reduced**
    /// gradients to `sink(layer, grads)` the moment the last replica
    /// emits that layer. `sink` is called from whichever replica thread
    /// completes a layer — it must be `Sync`; calls for distinct layers
    /// never overlap a call for the same layer.
    pub fn compute_streaming(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[Shard<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        anyhow::ensure!(
            shards.len() == self.replicas,
            "group has {} replicas but {} shards were supplied",
            self.replicas,
            shards.len()
        );
        if self.replicas == 1 {
            // Single replica: run on the calling thread with full
            // internal kernel parallelism (a region fan-out here would
            // needlessly serialize the engine's own kernels).
            let loss =
                engine.compute_streaming(net, shards[0].x, shards[0].loss, &mut |li, g| {
                    sink(li, g)
                })?;
            return Ok(ReplicaStep {
                loss,
                replica_losses: vec![loss],
                reduce_s: 0.0,
            });
        }
        // Oversubscription caveat: with more replicas than pool workers,
        // a share runs its replicas *sequentially*, so an early
        // replica's whole gradient set parks in the reducer until the
        // late replicas deliver — peak memory degrades from
        // one-layer-per-replica toward full-model-per-early-replica.
        // Correctness and determinism are unaffected; warn once so the
        // memory profile change is not silent.
        if self.replicas > pool::threads() {
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                crate::log_warn!(
                    "replicas ({}) exceed pool threads ({}): replicas run \
                     sequentially per worker and early replicas' gradients \
                     are parked until the reduce completes, raising peak \
                     memory; prefer replicas <= threads",
                    self.replicas,
                    pool::threads()
                );
            });
        }
        let reducer = StreamingAllReduce::new(net.depth(), self.replicas, op);
        // One pool region, one task per replica. Shares cover contiguous
        // replica ranges, so the share-ordered merge below concatenates
        // outcomes back in replica order.
        let outcomes: Vec<(usize, anyhow::Result<f32>)> = pool::run_reduce(
            self.replicas,
            pool::effective_threads(self.replicas),
            Vec::new,
            |range, acc: &mut Vec<(usize, anyhow::Result<f32>)>| {
                for r in range {
                    let shard = &shards[r];
                    let res =
                        engine.compute_streaming(net, shard.x, shard.loss, &mut |li, g| {
                            if let Some(reduced) = reducer.submit(li, r, g) {
                                sink(li, reduced);
                            }
                        });
                    acc.push((r, res));
                }
            },
            |a, b| a.extend(b),
        );
        let mut replica_losses = Vec::with_capacity(self.replicas);
        for (r, res) in outcomes {
            match res {
                Ok(l) => replica_losses.push(l),
                Err(e) => return Err(e.context(format!("replica {r} failed"))),
            }
        }
        let loss = replica_losses.iter().sum::<f32>() / replica_losses.len() as f32;
        Ok(ReplicaStep {
            loss,
            replica_losses,
            reduce_s: reducer.reduce_seconds(),
        })
    }

    /// [`Self::compute_streaming`] collecting the reduced gradients.
    pub fn compute(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[Shard<'_>],
        op: ReduceOp,
    ) -> anyhow::Result<ReplicaResult> {
        let grads: Mutex<Vec<Vec<Tensor>>> =
            Mutex::new((0..net.depth()).map(|_| Vec::new()).collect());
        let step = self.compute_streaming(net, engine, shards, op, &|li, g| {
            crate::util::lock_ignore_poison(&grads)[li] = g;
        })?;
        let grads = match grads.into_inner() {
            Ok(v) => v,
            Err(poisoned) => poisoned.into_inner(),
        };
        Ok(ReplicaResult {
            loss: step.loss,
            replica_losses: step.replica_losses,
            grads,
            reduce_s: step.reduce_s,
        })
    }
}

/// Split a batched tensor into `parts` equal contiguous sub-batches along
/// axis 0 (the in-process shard materializer used by benches and tests;
/// the training path shards indices in [`pipeline::BatchPlan`] instead,
/// before tensors are ever built).
pub fn split_batch(x: &Tensor, parts: usize) -> anyhow::Result<Vec<Tensor>> {
    anyhow::ensure!(parts >= 1, "parts must be >= 1");
    anyhow::ensure!(x.rank() >= 1, "need a batch axis");
    let n = x.shape()[0];
    anyhow::ensure!(
        n % parts == 0 && n >= parts,
        "batch {n} is not divisible into {parts} shards"
    );
    let per = n / parts;
    let rec: usize = x.shape()[1..].iter().product();
    let mut shape = x.shape().to_vec();
    shape[0] = per;
    Ok((0..parts)
        .map(|r| {
            Tensor::from_vec(
                x.data()[r * per * rec..(r + 1) * per * rec].to_vec(),
                &shape,
            )
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_mlp, Network};
    use crate::nn::MeanLoss;
    use crate::util::Rng;

    fn tiny_net(seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        build_mlp(&[6, 5, 3], 0.1, &mut rng)
    }

    #[test]
    fn single_replica_matches_plain_engine() {
        let net = tiny_net(0);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let reference = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let group = ReplicaGroup::new(1).unwrap();
        let shards = [Shard {
            x: &x,
            loss: &MeanLoss,
        }];
        let got = group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .unwrap();
        assert_eq!(got.loss, reference.loss);
        for (a, b) in reference.grads.iter().zip(&got.grads) {
            assert_eq!(a.len(), b.len());
            for (ga, gb) in a.iter().zip(b) {
                assert_eq!(ga.data(), gb.data(), "1-replica group must be identity");
            }
        }
    }

    #[test]
    fn shard_count_mismatch_rejected() {
        let net = tiny_net(2);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 6], 1.0, &mut rng);
        let group = ReplicaGroup::new(2).unwrap();
        let shards = [Shard {
            x: &x,
            loss: &MeanLoss,
        }];
        assert!(group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .is_err());
    }

    #[test]
    fn split_batch_partitions() {
        let x = Tensor::from_vec((0..12).map(|v| v as f32).collect(), &[4, 3]);
        let parts = split_batch(&x, 2).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].shape(), &[2, 3]);
        assert_eq!(parts[0].data(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(parts[1].data(), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert!(split_batch(&x, 3).is_err());
    }

    #[test]
    fn broadcast_syncs_params() {
        let src = tiny_net(10);
        let mut locals = vec![tiny_net(11), tiny_net(12)];
        assert_ne!(
            locals[0].layers[0].params()[0].data(),
            src.layers[0].params()[0].data(),
            "independent seeds must start out of sync"
        );
        let group = ReplicaGroup::new_synced(&src, &mut locals).unwrap();
        assert_eq!(group.replicas(), 2);
        for local in &locals {
            for (ls, ld) in src.layers.iter().zip(&local.layers) {
                for (ps, pd) in ls.params().iter().zip(ld.params()) {
                    assert_eq!(ps.data(), pd.data(), "broadcast must be bit-exact");
                }
            }
        }
    }

    #[test]
    fn replica_count_resolution() {
        // set_replicas wins and clamps.
        let before = replicas();
        set_replicas(3);
        assert_eq!(replicas(), 3);
        set_replicas(0);
        assert_eq!(replicas(), 1);
        set_replicas(before);
    }
}
