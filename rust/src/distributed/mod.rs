//! Data-parallel replica sharding on top of the persistent worker pool,
//! with pluggable execution transports.
//!
//! The scale-out seam the ROADMAP calls for: a [`ReplicaGroup`] runs one
//! [`GradEngine`] per replica over disjoint sub-batches of a global
//! batch and reduces gradients **per layer, streamed** through
//! [`reduce::StreamingAllReduce`]: the moment every replica has emitted a
//! layer (the paper's §4.3 streamed-gradient property), that layer is
//! all-reduced — overlapped with the other replicas' still-running
//! sweeps — and handed to the caller's sink. No full gradient buffer is
//! ever required, so the no-stored-activations property survives
//! sharding.
//!
//! **Where replicas execute is a [`transport::Transport`]**: in-process
//! on the worker pool ([`transport::LocalTransport`], the default), in
//! one worker subprocess per replica over unix-domain sockets
//! ([`transport::UnixTransport`], `--transport unix`), or over TCP for
//! multi-host runs ([`transport::TcpTransport`], `--transport tcp`).
//! Every contract below is transport-independent; `tests/transport.rs`
//! proves the socket transports bit-identical to the in-process path at
//! equal replica counts.
//!
//! **Fault tolerance** (the elastic fault-tolerance PR): a failed or
//! timed-out step can be **retried exactly** via
//! [`ReplicaGroup::step_retrying`] — every attempt discards all partial
//! per-layer gradient deliveries (the reducer is rebuilt per attempt),
//! re-syncs (respawning dead workers and re-uploading the unchanged
//! parameters) and replays the identical batch, so a recovered run's
//! loss curve is **bit-identical** to a no-fault run
//! (`tests/fault_tolerance.rs`). When a replica cannot come back, the
//! group **fails over** by shrinking its elastic membership
//! ([`ReplicaGroup::set_members`]): the fixed logical shard set is
//! re-queued onto the survivors, and because the reduce folds in
//! logical shard order the reduced gradient at equal global batch stays
//! bit-identical too. Replicas may likewise join/leave between steps by
//! growing/shrinking membership and re-syncing.
//!
//! In-process scheduling: replicas fan out as one pool region, so each
//! replica's engine runs with nested kernel parallelism suppressed — the
//! batch axis *is* the parallel axis, exactly as it is for the
//! batch-parallel conv kernels. With one replica the engine runs on the
//! calling thread with full internal parallelism (the group is a no-op
//! wrapper there). Determinism mirrors the pool's contract: fixed
//! replica count + fixed thread count ⇒ bit-identical gradients
//! run-to-run, because per-replica computation is deterministic and the
//! reduce folds in replica order.
//!
//! A panicking replica is caught by the pool, re-raised on the
//! submitting thread, and the team keeps serving later regions; an `Err`
//! from a replica's engine aborts the step with that replica's error (a
//! *subprocess* replica that dies surfaces the same way — a step error
//! naming the replica). Replica count resolution: explicit
//! [`set_replicas`] (the CLI's `--replicas`) > `MOONWALK_REPLICAS` env
//! var > 1.
//!
//! The companion [`pipeline`] module supplies the deterministic sharded
//! batches (double-buffered prefetch); [`broadcast`] syncs replica-local
//! parameter copies from a source network — in-process replicas normally
//! share one `&Network`, and the same seam is what
//! [`transport::Transport::broadcast`] carries across the process
//! boundary.

#![deny(missing_docs)]

pub mod pipeline;
pub mod reduce;
pub mod transport;

pub use reduce::{ReduceOp, StreamingAllReduce};
pub use transport::{Transport, TransportKind};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::Loss;
use crate::tensor::Tensor;

use transport::supervisor::Backoff;
use transport::{LocalTransport, ShardSpec};

// ----- replica-count resolution ---------------------------------------------

/// Global replica budget; 0 = not yet resolved.
static REPLICAS: AtomicUsize = AtomicUsize::new(0);

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("MOONWALK_REPLICAS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    1
}

/// The configured replica count (resolving lazily on first use):
/// [`set_replicas`] > `MOONWALK_REPLICAS` > 1.
pub fn replicas() -> usize {
    let r = REPLICAS.load(Ordering::Relaxed);
    if r != 0 {
        return r;
    }
    let r = resolve_default();
    REPLICAS.store(r, Ordering::Relaxed);
    r
}

/// Set the replica count explicitly (CLI `--replicas`). Clamped to ≥ 1.
pub fn set_replicas(n: usize) {
    REPLICAS.store(n.max(1), Ordering::Relaxed);
}

// ----- parameter broadcast ---------------------------------------------------

/// Broadcast `src`'s parameters into every replica-local network copy
/// (shape-checked, bit-exact). The group-construction sync step of a
/// data-parallel setup.
pub fn broadcast(src: &Network, locals: &mut [Network]) -> anyhow::Result<()> {
    for (r, local) in locals.iter_mut().enumerate() {
        local
            .copy_params_from(src)
            .map_err(|e| e.context(format!("broadcast to replica {r}")))?;
    }
    Ok(())
}

// ----- the replica group -----------------------------------------------------

/// One replica's slice of a global step: its input shard and loss head
/// (the loss holds shard-local targets). This is the borrow-based
/// in-process view; [`transport::ShardSpec`] is the transport-portable
/// twin.
pub struct Shard<'a> {
    /// The replica-local input batch.
    pub x: &'a Tensor,
    /// The loss head evaluated on this shard.
    pub loss: &'a dyn Loss,
}

/// Loss/timing summary of one replicated gradient step.
#[derive(Clone, Debug)]
pub struct ReplicaStep {
    /// Mean of the per-replica losses — the global-batch loss for equal
    /// shards under a per-shard mean loss.
    pub loss: f32,
    /// Per-replica shard losses, in replica order.
    pub replica_losses: Vec<f32>,
    /// Wall-clock spent folding inside the streaming all-reduce (overlaps
    /// the replicas' sweeps; compare against step time for the overlap
    /// ratio the perf bench tracks).
    pub reduce_s: f64,
}

/// [`ReplicaStep`] plus the collected reduced gradients (convenience
/// mirror of [`GradEngine::compute`]).
pub struct ReplicaResult {
    /// Mean of the per-replica losses.
    pub loss: f32,
    /// Per-replica shard losses, in replica order.
    pub replica_losses: Vec<f32>,
    /// Per-layer reduced gradients, aligned with `net.layers` (empty for
    /// parameter-free layers).
    pub grads: Vec<Vec<Tensor>>,
    /// Wall-clock spent folding inside the streaming all-reduce.
    pub reduce_s: f64,
}

/// How [`ReplicaGroup::step_retrying`] responds to step failures.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retry attempts per membership level after the first failure
    /// (0 = fail fast, the pre-supervision behavior).
    pub retries: usize,
    /// Base delay before a retry; doubles per attempt (capped at 8×).
    pub backoff_ms: u64,
    /// After the retry budget is exhausted, shrink the elastic
    /// membership by one and keep going (re-queueing the dead worker's
    /// logical shards onto survivors, bit-identically) until the group
    /// is down to a single member.
    pub failover: bool,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            retries: 2,
            backoff_ms: 50,
            failover: false,
        }
    }
}

/// What recovering a step cost (per-step observability; the trainer
/// logs these per JSONL row).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Failed attempts that were retried at unchanged membership.
    pub retries: usize,
    /// Membership shrinks (failovers onto survivors).
    pub failovers: usize,
}

/// A fixed-size data-parallel replica group executing on a pluggable
/// [`Transport`] (see module docs).
///
/// # Example
///
/// ```
/// use moonwalk::autodiff::Backprop;
/// use moonwalk::distributed::{split_batch, ReduceOp, ReplicaGroup, Shard};
/// use moonwalk::model::build_mlp;
/// use moonwalk::nn::MeanLoss;
/// use moonwalk::tensor::Tensor;
/// use moonwalk::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let net = build_mlp(&[4, 3], 0.1, &mut rng);
/// let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
/// let xs = split_batch(&x, 2)?;
/// let shards: Vec<Shard<'_>> = xs.iter().map(|x| Shard { x, loss: &MeanLoss }).collect();
/// let group = ReplicaGroup::new(2)?;
/// let out = group.compute(&net, &Backprop, &shards, ReduceOp::Mean)?;
/// assert_eq!(out.replica_losses.len(), 2);
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct ReplicaGroup {
    replicas: usize,
    transport: Mutex<Box<dyn Transport>>,
}

impl ReplicaGroup {
    /// An in-process group of `replicas` replicas (the
    /// [`LocalTransport`] path).
    pub fn new(replicas: usize) -> anyhow::Result<ReplicaGroup> {
        anyhow::ensure!(replicas >= 1, "replica count must be >= 1");
        Ok(ReplicaGroup {
            replicas,
            transport: Mutex::new(Box::new(LocalTransport::new(replicas))),
        })
    }

    /// A group executing on an explicit transport (sized by it). Call
    /// [`Self::sync`] before the first [`Self::step`] so remote replicas
    /// hold the coordinator's parameters.
    pub fn with_transport(transport: Box<dyn Transport>) -> anyhow::Result<ReplicaGroup> {
        let replicas = transport.replicas();
        anyhow::ensure!(replicas >= 1, "transport must execute >= 1 replica");
        Ok(ReplicaGroup {
            replicas,
            transport: Mutex::new(transport),
        })
    }

    /// A group sized to `locals`, after broadcasting `src`'s parameters
    /// into every replica-local copy (the multi-process seam; in-process
    /// callers usually share one `&Network` and use [`ReplicaGroup::new`]).
    pub fn new_synced(src: &Network, locals: &mut [Network]) -> anyhow::Result<ReplicaGroup> {
        broadcast(src, locals)?;
        ReplicaGroup::new(locals.len())
    }

    /// The fixed replica count of this group.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Dissolve the group, handing its transport back (so a caller that
    /// lent a transport for one run — e.g. the trainer — can reuse it
    /// for the next without respawning workers).
    pub fn into_transport(self) -> Box<dyn Transport> {
        match self.transport.into_inner() {
            Ok(t) => t,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// The active transport's name (`"local"`, `"unix"`, `"tcp"`), for
    /// metrics.
    pub fn transport_name(&self) -> String {
        crate::util::lock_ignore_poison(&self.transport).name()
    }

    /// The transport's live executor count (≤ [`Self::replicas`]; they
    /// differ only while running degraded after a failover or an
    /// explicit membership change).
    pub fn members(&self) -> usize {
        crate::util::lock_ignore_poison(&self.transport).members()
    }

    /// Elastically resize the executor set (join/leave between steps).
    /// The logical shard count is fixed, so gradients stay bit-identical
    /// at equal global batch; call [`Self::sync`] before the next step.
    pub fn set_members(&self, members: usize) -> anyhow::Result<()> {
        crate::util::lock_ignore_poison(&self.transport).set_members(members)
    }

    /// The transport's heartbeat interval (ms; 0 = none), for metrics.
    pub fn heartbeat_ms(&self) -> u64 {
        crate::util::lock_ignore_poison(&self.transport).heartbeat_ms()
    }

    /// Synchronize every replica's parameters with `net` through the
    /// transport's broadcast seam. A no-op in-process; for remote
    /// transports this must run after every parameter update (and after
    /// a failed step — it is also what respawns dead workers).
    pub fn sync(&self, net: &Network) -> anyhow::Result<()> {
        let _sp = crate::span!("transport.broadcast");
        crate::util::lock_ignore_poison(&self.transport).broadcast(net)
    }

    /// Run `engine` once per replica over `shards` (one shard per
    /// replica, replica order) and stream each layer's **reduced**
    /// gradients to `sink(layer, grads)` the moment the last replica
    /// emits that layer. `sink` is called from whichever replica (or
    /// transport reader) thread completes a layer — it must be `Sync`;
    /// calls for distinct layers never overlap a call for the same layer.
    ///
    /// This is the borrow-based **in-process** API (it always executes
    /// locally, regardless of the group's transport); the trainer's
    /// transport-routed twin is [`Self::step_streaming`].
    pub fn compute_streaming(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[Shard<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        transport::local::fanout_streaming(self.replicas, self.replicas, net, engine, shards, op, sink)
    }

    /// [`Self::compute_streaming`] collecting the reduced gradients.
    pub fn compute(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[Shard<'_>],
        op: ReduceOp,
    ) -> anyhow::Result<ReplicaResult> {
        let grads: Mutex<Vec<Vec<Tensor>>> =
            Mutex::new((0..net.depth()).map(|_| Vec::new()).collect());
        let step = self.compute_streaming(net, engine, shards, op, &|li, g| {
            crate::util::lock_ignore_poison(&grads)[li] = g;
        })?;
        let grads = match grads.into_inner() {
            Ok(v) => v,
            Err(poisoned) => poisoned.into_inner(),
        };
        Ok(ReplicaResult {
            loss: step.loss,
            replica_losses: step.replica_losses,
            grads,
            reduce_s: step.reduce_s,
        })
    }

    /// Transport-routed streaming step: like [`Self::compute_streaming`]
    /// but executing wherever the group's transport runs its replicas
    /// (in-process or worker subprocesses), with the loss given as a
    /// serializable [`transport::LossSpec`].
    pub fn step_streaming(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        let _sp = crate::span!("transport.step");
        crate::util::lock_ignore_poison(&self.transport).step(net, engine, shards, op, sink)
    }

    /// [`Self::step`] under a [`RetryPolicy`]: on failure, re-sync
    /// (respawn dead workers + re-upload the **unchanged** parameters)
    /// and replay the identical shards — each attempt rebuilds the
    /// reducer, so partial deliveries of failed attempts are discarded
    /// wholesale and a successful attempt is bit-identical to a run
    /// that never failed. With `policy.failover`, exhausted retry
    /// budgets shrink the membership onto survivors (one worker at a
    /// time, down to 1) and keep replaying, so even a permanently lost
    /// host costs retried steps, not the run.
    pub fn step_retrying(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        policy: RetryPolicy,
    ) -> anyhow::Result<(ReplicaResult, StepStats)> {
        let mut stats = StepStats::default();
        let mut last_err = match self.step(net, engine, shards, op) {
            Ok(res) => return Ok((res, stats)),
            Err(e) => e,
        };
        // Saturating: a huge --step-retries backoff base must not wrap
        // the ms counter; Backoff::new additionally clamps both ends to
        // supervisor::MAX_BACKOFF_MS.
        let base_ms = policy.backoff_ms.max(1);
        let mut backoff = Backoff::new(base_ms, base_ms.saturating_mul(8));
        loop {
            for _ in 0..policy.retries {
                stats.retries += 1;
                crate::obs::metrics::counter_add("step.retries", 1);
                crate::obs::span::instant(
                    "supervisor.retry",
                    Some(("attempt", stats.retries as i64)),
                );
                crate::log_warn!(
                    "step failed ({last_err:#}); retry {} after backoff",
                    stats.retries
                );
                let delay = backoff.delay();
                crate::obs::metrics::counter_add(
                    "supervisor.backoff_wait_ms",
                    delay.as_millis() as u64,
                );
                std::thread::sleep(delay);
                // Re-sync respawns whatever died and re-uploads params;
                // optimizer state was never touched, so the replay is
                // exact.
                if let Err(e) = self.sync(net) {
                    last_err = e.context("re-syncing for step retry");
                    continue;
                }
                match self.step(net, engine, shards, op) {
                    Ok(res) => return Ok((res, stats)),
                    Err(e) => last_err = e,
                }
            }
            if !policy.failover {
                return Err(last_err.context(format!(
                    "step failed after {} retr{}",
                    stats.retries,
                    if stats.retries == 1 { "y" } else { "ies" }
                )));
            }
            let members = self.members();
            if members <= 1 {
                return Err(last_err.context(format!(
                    "step failed after {} retries and {} failovers (1 member left)",
                    stats.retries, stats.failovers
                )));
            }
            stats.failovers += 1;
            crate::obs::metrics::counter_add("step.failovers", 1);
            crate::obs::span::instant(
                "supervisor.failover",
                Some(("survivors", (members - 1) as i64)),
            );
            crate::log_warn!(
                "step unrecoverable at {members} members; failing over to {} survivor(s)",
                members - 1
            );
            self.set_members(members - 1)?;
            if let Err(e) = self.sync(net) {
                last_err = e.context("re-syncing after failover");
                continue;
            }
            match self.step(net, engine, shards, op) {
                Ok(res) => return Ok((res, stats)),
                Err(e) => last_err = e,
            }
        }
    }

    /// [`Self::step_streaming`] collecting the reduced gradients.
    pub fn step(
        &self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
    ) -> anyhow::Result<ReplicaResult> {
        let grads: Mutex<Vec<Vec<Tensor>>> =
            Mutex::new((0..net.depth()).map(|_| Vec::new()).collect());
        let step = self.step_streaming(net, engine, shards, op, &|li, g| {
            crate::util::lock_ignore_poison(&grads)[li] = g;
        })?;
        let grads = match grads.into_inner() {
            Ok(v) => v,
            Err(poisoned) => poisoned.into_inner(),
        };
        Ok(ReplicaResult {
            loss: step.loss,
            replica_losses: step.replica_losses,
            grads,
            reduce_s: step.reduce_s,
        })
    }
}

/// Split a batched tensor into `parts` equal contiguous sub-batches along
/// axis 0 (the in-process shard materializer used by benches and tests;
/// the training path shards indices in [`pipeline::BatchPlan`] instead,
/// before tensors are ever built).
pub fn split_batch(x: &Tensor, parts: usize) -> anyhow::Result<Vec<Tensor>> {
    anyhow::ensure!(parts >= 1, "parts must be >= 1");
    anyhow::ensure!(x.rank() >= 1, "need a batch axis");
    let n = x.shape()[0];
    anyhow::ensure!(
        n % parts == 0 && n >= parts,
        "batch {n} is not divisible into {parts} shards"
    );
    let per = n / parts;
    let rec: usize = x.shape()[1..].iter().product();
    let mut shape = x.shape().to_vec();
    shape[0] = per;
    Ok((0..parts)
        .map(|r| {
            Tensor::from_vec(
                x.data()[r * per * rec..(r + 1) * per * rec].to_vec(),
                &shape,
            )
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_mlp, Network};
    use crate::nn::MeanLoss;
    use crate::util::Rng;

    fn tiny_net(seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        build_mlp(&[6, 5, 3], 0.1, &mut rng)
    }

    #[test]
    fn single_replica_matches_plain_engine() {
        let net = tiny_net(0);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let reference = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let group = ReplicaGroup::new(1).unwrap();
        assert_eq!(group.transport_name(), "local");
        let shards = [Shard {
            x: &x,
            loss: &MeanLoss,
        }];
        let got = group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .unwrap();
        assert_eq!(got.loss, reference.loss);
        for (a, b) in reference.grads.iter().zip(&got.grads) {
            assert_eq!(a.len(), b.len());
            for (ga, gb) in a.iter().zip(b) {
                assert_eq!(ga.data(), gb.data(), "1-replica group must be identity");
            }
        }
    }

    #[test]
    fn step_via_local_transport_matches_compute() {
        use crate::distributed::transport::LossSpec;
        let net = tiny_net(1);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let xs = split_batch(&x, 2).unwrap();
        let group = ReplicaGroup::new(2).unwrap();
        group.sync(&net).unwrap();
        let shards: Vec<Shard<'_>> = xs
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let reference = group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .unwrap();
        let specs: Vec<transport::ShardSpec<'_>> = xs
            .iter()
            .map(|x| transport::ShardSpec {
                x,
                loss: LossSpec::Mean,
            })
            .collect();
        let routed = group
            .step(&net, &Backprop, &specs, ReduceOp::Mean)
            .unwrap();
        assert_eq!(routed.loss.to_bits(), reference.loss.to_bits());
        for (a, b) in reference.grads.iter().zip(&routed.grads) {
            for (ga, gb) in a.iter().zip(b) {
                assert_eq!(ga.data(), gb.data(), "transport-routed step identical");
            }
        }
    }

    #[test]
    fn shard_count_mismatch_rejected() {
        let net = tiny_net(2);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 6], 1.0, &mut rng);
        let group = ReplicaGroup::new(2).unwrap();
        let shards = [Shard {
            x: &x,
            loss: &MeanLoss,
        }];
        assert!(group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .is_err());
    }

    #[test]
    fn split_batch_partitions() {
        let x = Tensor::from_vec((0..12).map(|v| v as f32).collect(), &[4, 3]);
        let parts = split_batch(&x, 2).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].shape(), &[2, 3]);
        assert_eq!(parts[0].data(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(parts[1].data(), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert!(split_batch(&x, 3).is_err());
    }

    #[test]
    fn broadcast_syncs_params() {
        let src = tiny_net(10);
        let mut locals = vec![tiny_net(11), tiny_net(12)];
        assert_ne!(
            locals[0].layers[0].params()[0].data(),
            src.layers[0].params()[0].data(),
            "independent seeds must start out of sync"
        );
        let group = ReplicaGroup::new_synced(&src, &mut locals).unwrap();
        assert_eq!(group.replicas(), 2);
        for local in &locals {
            for (ls, ld) in src.layers.iter().zip(&local.layers) {
                for (ps, pd) in ls.params().iter().zip(ld.params()) {
                    assert_eq!(ps.data(), pd.data(), "broadcast must be bit-exact");
                }
            }
        }
    }

    #[test]
    fn replica_count_resolution() {
        // set_replicas wins and clamps.
        let before = replicas();
        set_replicas(3);
        assert_eq!(replicas(), 3);
        set_replicas(0);
        assert_eq!(replicas(), 1);
        set_replicas(before);
    }
}
