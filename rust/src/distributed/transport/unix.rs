//! The multi-process transport: one worker **subprocess** per replica,
//! speaking the [`wire`](super::wire) format over unix-domain sockets.
//!
//! The coordinator binds a socket, spawns `replicas` workers — the
//! binary re-invoked with the hidden `--replica-worker` mode — and
//! multiplexes parameter broadcast and per-layer streamed gradient
//! upload over each worker's connection. The streamed all-reduce runs on
//! coordinator-side reader threads: the moment every worker has uploaded
//! a layer, that layer folds in replica order and lands in the caller's
//! sink, exactly as the in-process transport does on pool threads.
//!
//! **Determinism.** Workers run their engine with a single pool thread
//! (the `threads` field of the init blob, default 1), which executes the
//! same serial kernel paths as an in-process replica whose nested
//! parallelism is suppressed — and f32 payloads travel bit-exactly — so
//! the unix transport is **bit-identical** to the local transport at the
//! same replica count (`tests/transport.rs` proves it).
//!
//! **Failure semantics.** A worker that exits or drops its connection
//! mid-step fails that step with an error naming the replica (mirroring
//! the in-process panic path). A crash mid-step tears the whole group
//! down — surviving workers may hold half an aborted step in their
//! socket buffers, which no coordinator can drain exactly — and the
//! next [`Transport::broadcast`] respawns every replica and re-uploads
//! parameters, so the group keeps serving subsequent steps. A clean
//! worker-side engine error (`Err`, not a crash) fails the step the
//! same way but keeps the workers alive and in sync.
//!
//! **Memory.** Per-replica gradients park in the coordinator's reducer
//! until the last replica delivers each layer; workers themselves hold
//! only their engine's working set — the per-process memory budget that
//! makes this the scale-out half of the ROADMAP's north star.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::autodiff::GradEngine;
use crate::distributed::{ReduceOp, ReplicaStep};
use crate::model::Network;
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::wire::{self, Msg};
use super::{submit_to_sink, ShardSpec, Transport};

/// How a worker should instantiate its gradient engine — the arguments
/// of [`crate::autodiff::engine_by_name`], in serializable form.
#[derive(Clone, Debug)]
pub struct EngineSpec {
    /// Engine name (`"moonwalk"`, `"backprop"`, …).
    pub name: String,
    /// Fragmental block size (fragmental engines).
    pub block: usize,
    /// Checkpoint segment count (checkpointed engines); 0 = auto.
    pub checkpoint_segments: usize,
    /// Engine seed (stochastic engines).
    pub seed: u64,
}

impl EngineSpec {
    /// A spec with default hyperparameters for `name`.
    pub fn new(name: &str) -> EngineSpec {
        EngineSpec {
            name: name.to_string(),
            block: 4,
            checkpoint_segments: 0,
            seed: 0,
        }
    }
}

/// Construction options for [`UnixTransport::spawn`].
pub struct UnixTransportOpts {
    /// Worker subprocess count (one per replica).
    pub replicas: usize,
    /// JSON text of the [`crate::model::config::Config`] the workers
    /// build their network skeleton from (parameters are overwritten by
    /// the first broadcast; only the architecture must match).
    pub config_json: String,
    /// Engine each worker runs. The caller must keep this consistent
    /// with the engine it passes to [`Transport::step`].
    pub engine: EngineSpec,
    /// Worker pool threads. Keep the default **1** for bit-equality with
    /// the in-process transport, whose replicas run their kernels
    /// serially (nested parallelism suppressed).
    pub threads_per_worker: usize,
    /// Worker executable; `None` re-invokes the current binary
    /// (`std::env::current_exe`). Tests point this at the built
    /// `moonwalk` binary via `env!("CARGO_BIN_EXE_moonwalk")`.
    pub worker_bin: Option<PathBuf>,
    /// Directory for the coordinator socket; `None` creates (and later
    /// removes) a fresh directory under the system temp dir.
    pub socket_dir: Option<PathBuf>,
}

impl UnixTransportOpts {
    /// Options for `replicas` workers rebuilding `config_json` and
    /// running `engine`, with the bit-equality defaults (1 worker
    /// thread, current binary, temp socket dir).
    pub fn new(replicas: usize, config_json: String, engine: EngineSpec) -> UnixTransportOpts {
        UnixTransportOpts {
            replicas,
            config_json,
            engine,
            threads_per_worker: 1,
            worker_bin: None,
            socket_dir: None,
        }
    }
}

/// One live worker: subprocess handle plus its framed connection.
struct WorkerConn {
    child: Child,
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
}

/// Distinguishes "the worker process is gone" (respawn on next
/// broadcast) from a clean worker-side step error (worker still fine).
struct StepFailure {
    fatal: bool,
    err: anyhow::Error,
}

static SOCKET_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// The unix-socket multi-process transport (see module docs).
pub struct UnixTransport {
    opts: UnixTransportOpts,
    listener: UnixListener,
    socket_path: PathBuf,
    socket_dir: PathBuf,
    own_dir: bool,
    conns: Vec<Option<WorkerConn>>,
    synced: bool,
}

impl UnixTransport {
    /// Bind the coordinator socket, spawn one worker subprocess per
    /// replica, and complete the handshake + init exchange with each.
    pub fn spawn(opts: UnixTransportOpts) -> anyhow::Result<UnixTransport> {
        anyhow::ensure!(opts.replicas >= 1, "replica count must be >= 1");
        // Validate the config JSON up front: a worker failing to parse it
        // would otherwise surface as an opaque exit.
        Json::parse(&opts.config_json)
            .map_err(|e| anyhow::anyhow!("invalid worker config JSON: {e}"))?;
        let (socket_dir, own_dir) = match &opts.socket_dir {
            Some(d) => (d.clone(), false),
            None => (
                std::env::temp_dir().join(format!(
                    "moonwalk-unix-{}-{}",
                    std::process::id(),
                    SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed)
                )),
                true,
            ),
        };
        std::fs::create_dir_all(&socket_dir)?;
        let socket_path = socket_dir.join("coordinator.sock");
        // A stale socket file from a crashed previous run blocks bind.
        let _ = std::fs::remove_file(&socket_path);
        let listener = UnixListener::bind(&socket_path)?;
        listener.set_nonblocking(true)?;
        let replicas = opts.replicas;
        let mut transport = UnixTransport {
            opts,
            listener,
            socket_path,
            socket_dir,
            own_dir,
            conns: (0..replicas).map(|_| None).collect(),
            synced: false,
        };
        let all: Vec<usize> = (0..replicas).collect();
        transport.establish(&all)?;
        Ok(transport)
    }

    /// The worker executable to launch.
    fn worker_bin(&self) -> anyhow::Result<PathBuf> {
        match &self.opts.worker_bin {
            Some(p) => Ok(p.clone()),
            None => Ok(std::env::current_exe()?),
        }
    }

    /// The init blob every fresh worker receives.
    fn init_json(&self) -> String {
        let config = Json::parse(&self.opts.config_json).expect("validated at spawn");
        Json::from_pairs(vec![
            ("config", config),
            (
                "engine",
                Json::from_pairs(vec![
                    ("name", self.opts.engine.name.as_str().into()),
                    ("block", self.opts.engine.block.into()),
                    (
                        "checkpoint_segments",
                        self.opts.engine.checkpoint_segments.into(),
                    ),
                    ("seed", (self.opts.engine.seed as usize).into()),
                ]),
            ),
            ("threads", self.opts.threads_per_worker.max(1).into()),
        ])
        .to_string()
    }

    /// Spawn the given replicas' workers, accept their handshakes and
    /// send each its init blob. Used at construction and to respawn dead
    /// workers from [`Transport::broadcast`].
    fn establish(&mut self, replicas: &[usize]) -> anyhow::Result<()> {
        if replicas.is_empty() {
            return Ok(());
        }
        let bin = self.worker_bin()?;
        let mut pending: HashMap<usize, Child> = HashMap::new();
        for &r in replicas {
            anyhow::ensure!(
                self.conns[r].is_none(),
                "replica {r} already has a live worker"
            );
            let child = Command::new(&bin)
                .arg("--replica-worker")
                .arg("--connect")
                .arg(&self.socket_path)
                .arg("--replica")
                .arg(r.to_string())
                .stdin(Stdio::null())
                .spawn()
                .map_err(|e| anyhow::anyhow!("spawning worker for replica {r}: {e}"))?;
            pending.insert(r, child);
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        while !pending.is_empty() {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    // Bound the handshake read: the socket path is
                    // guessable, and a peer that connects but never
                    // sends its hello must not wedge the accept loop
                    // forever. Blocking reads are restored below for
                    // the step loop.
                    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
                    let mut reader = BufReader::new(stream.try_clone()?);
                    let (version, replica) = match wire::read_msg(&mut reader) {
                        Ok(Msg::Hello { version, replica }) => (version, replica as usize),
                        Ok(other) => anyhow::bail!("expected worker hello, got {other:?}"),
                        Err(e) => anyhow::bail!("peer connected but sent no hello: {e}"),
                    };
                    stream.set_read_timeout(None)?;
                    anyhow::ensure!(
                        version == wire::WIRE_VERSION,
                        "worker speaks wire version {version}, coordinator {}",
                        wire::WIRE_VERSION
                    );
                    let child = pending.remove(&replica).ok_or_else(|| {
                        anyhow::anyhow!("unexpected hello from replica {replica}")
                    })?;
                    let mut writer = BufWriter::new(stream);
                    wire::write_init(&mut writer, &self.init_json())?;
                    writer.flush()?;
                    self.conns[replica] = Some(WorkerConn {
                        child,
                        reader,
                        writer,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // While waiting, surface a worker that died before
                    // connecting (bad binary, immediate crash) instead of
                    // timing out opaquely.
                    for (&r, child) in pending.iter_mut() {
                        if let Ok(Some(status)) = child.try_wait() {
                            anyhow::bail!(
                                "replica {r} worker exited with {status} before connecting"
                            );
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "timed out waiting for {} worker(s) to connect",
                        pending.len()
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Indices of replicas whose worker is currently down.
    fn dead(&self) -> Vec<usize> {
        self.conns
            .iter()
            .enumerate()
            .filter_map(|(r, c)| c.is_none().then_some(r))
            .collect()
    }

    /// Send the full parameter set to one replica.
    fn send_params(&mut self, r: usize, layers: &[Vec<&Tensor>]) -> std::io::Result<()> {
        let conn = self.conns[r].as_mut().expect("caller checked liveness");
        wire::write_params(&mut conn.writer, layers)?;
        conn.writer.flush()
    }

    /// Kill one worker subprocess — fault injection for the
    /// worker-death recovery tests. The next [`Transport::broadcast`]
    /// respawns it.
    pub fn kill_worker(&mut self, replica: usize) -> anyhow::Result<()> {
        anyhow::ensure!(replica < self.conns.len(), "replica {replica} out of range");
        if let Some(mut conn) = self.conns[replica].take() {
            let _ = conn.child.kill();
            let _ = conn.child.wait();
        }
        self.synced = false;
        Ok(())
    }

    /// Kill one worker subprocess **without** marking it dead — fault
    /// injection that mimics an unnoticed crash: the coordinator only
    /// discovers the death when the next step's I/O hits EOF, exercising
    /// the mid-step failure path end to end.
    pub fn simulate_worker_crash(&mut self, replica: usize) -> anyhow::Result<()> {
        anyhow::ensure!(replica < self.conns.len(), "replica {replica} out of range");
        if let Some(conn) = self.conns[replica].as_mut() {
            let _ = conn.child.kill();
            let _ = conn.child.wait();
        }
        Ok(())
    }

    /// Tear down every worker and mark the group unsynced. Called after
    /// any step failure: a surviving worker may hold half of an aborted
    /// step in its socket buffers (gradients the coordinator never
    /// drained), so restarting the whole group is the only state the
    /// coordinator can re-establish exactly. The next broadcast respawns
    /// all replicas.
    fn reset_workers(&mut self) {
        for slot in self.conns.iter_mut() {
            if let Some(mut conn) = slot.take() {
                let _ = conn.child.kill();
                let _ = conn.child.wait();
            }
        }
        self.synced = false;
    }

    /// Worker subprocess ids, `None` for dead replicas (observability +
    /// tests).
    pub fn worker_ids(&self) -> Vec<Option<u32>> {
        self.conns
            .iter()
            .map(|c| c.as_ref().map(|c| c.child.id()))
            .collect()
    }
}

impl Transport for UnixTransport {
    fn name(&self) -> String {
        "unix".into()
    }

    fn replicas(&self) -> usize {
        self.conns.len()
    }

    fn broadcast(&mut self, net: &Network) -> anyhow::Result<()> {
        // Respawn anything that died since the last step, then upload the
        // parameter set to every worker; one retry per replica covers a
        // worker that died between the liveness check and the write.
        let dead = self.dead();
        self.establish(&dead)?;
        let layers: Vec<Vec<&Tensor>> = net.layers.iter().map(|l| l.params()).collect();
        for r in 0..self.conns.len() {
            if self.send_params(r, &layers).is_err() {
                // The worker is gone: reap it, respawn, resend once.
                if let Some(mut conn) = self.conns[r].take() {
                    let _ = conn.child.kill();
                    let _ = conn.child.wait();
                }
                self.establish(&[r])
                    .map_err(|e| e.context(format!("respawning replica {r} mid-broadcast")))?;
                self.send_params(r, &layers)
                    .map_err(|e| anyhow::anyhow!("replica {r}: param upload failed twice: {e}"))?;
            }
        }
        self.synced = true;
        Ok(())
    }

    fn step(
        &mut self,
        net: &Network,
        _engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        let replicas = self.conns.len();
        anyhow::ensure!(
            shards.len() == replicas,
            "group has {replicas} replicas but {} shards were supplied",
            shards.len()
        );
        anyhow::ensure!(
            self.synced,
            "parameters were never broadcast to the workers (call broadcast \
             after construction and after every parameter update or step error)"
        );
        // Dispatch the step to every worker first; gradients start
        // flowing back while later shards are still uploading.
        for (r, shard) in shards.iter().enumerate() {
            let send = (|| -> std::io::Result<()> {
                let conn = self.conns[r].as_mut().expect("synced implies alive");
                wire::write_step(&mut conn.writer, shard.x, &shard.loss.to_wire())?;
                conn.writer.flush()
            })();
            if let Err(e) = send {
                // Workers dispatched before this one now hold an aborted
                // half-step; reset the whole group so no stale frames
                // survive into the next step.
                self.reset_workers();
                anyhow::bail!("replica {r} worker died during step dispatch: {e}");
            }
        }
        // Drain all connections concurrently, feeding the shared
        // replica-ordered reducer (bucket-fused exactly like the local
        // transport's, so delivery batching matches across transports);
        // each bucket's fold fires on the reader thread that delivers
        // the last contribution.
        let reducer = super::reducer_for(net, replicas, op);
        let outcomes: Vec<Result<f32, StepFailure>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .enumerate()
                .map(|(r, slot)| {
                    let conn = slot.as_mut().expect("synced implies alive");
                    let reducer = &reducer;
                    scope.spawn(move || -> Result<f32, StepFailure> {
                        loop {
                            match wire::read_msg(&mut conn.reader) {
                                Ok(Msg::Grad { layer, grads }) => {
                                    submit_to_sink(reducer, layer as usize, r, grads, sink);
                                }
                                Ok(Msg::StepDone { loss }) => return Ok(loss),
                                Ok(Msg::Error { message }) => {
                                    return Err(StepFailure {
                                        fatal: false,
                                        err: anyhow::anyhow!("replica {r} failed: {message}"),
                                    })
                                }
                                Ok(other) => {
                                    return Err(StepFailure {
                                        fatal: true,
                                        err: anyhow::anyhow!(
                                            "replica {r}: unexpected {other:?} mid-step"
                                        ),
                                    })
                                }
                                Err(e) => {
                                    let what = if e.kind()
                                        == std::io::ErrorKind::UnexpectedEof
                                    {
                                        "worker died mid-step (connection closed)".into()
                                    } else {
                                        format!("transport error mid-step: {e}")
                                    };
                                    return Err(StepFailure {
                                        fatal: true,
                                        err: anyhow::anyhow!("replica {r} {what}"),
                                    });
                                }
                            }
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(StepFailure {
                            fatal: true,
                            err: anyhow::anyhow!("transport reader thread panicked"),
                        })
                    })
                })
                .collect()
        });
        let mut replica_losses = Vec::with_capacity(replicas);
        let mut first_err: Option<anyhow::Error> = None;
        let mut any_fatal = false;
        for outcome in outcomes {
            match outcome {
                Ok(l) => replica_losses.push(l),
                Err(f) => {
                    any_fatal |= f.fatal;
                    if first_err.is_none() {
                        first_err = Some(f.err);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            if any_fatal {
                // Surviving workers completed (their readers drained
                // through StepDone), but a fatal peer means the step is
                // torn; reset so the next broadcast rebuilds a clean
                // group. Clean (non-fatal) engine errors leave workers
                // parked at a frame boundary — no reset needed.
                self.reset_workers();
            }
            return Err(e);
        }
        let loss = replica_losses.iter().sum::<f32>() / replica_losses.len() as f32;
        Ok(ReplicaStep {
            loss,
            replica_losses,
            reduce_s: reducer.reduce_seconds(),
        })
    }
}

impl Drop for UnixTransport {
    fn drop(&mut self) {
        // Ask every live worker to exit, give them a moment, then make
        // sure nothing outlives the coordinator.
        for conn in self.conns.iter_mut().flatten() {
            let _ = wire::write_shutdown(&mut conn.writer);
            let _ = conn.writer.flush();
        }
        let deadline = Instant::now() + Duration::from_millis(500);
        for conn in self.conns.iter_mut().flatten() {
            loop {
                match conn.child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => {
                        let _ = conn.child.kill();
                        let _ = conn.child.wait();
                        break;
                    }
                }
            }
        }
        let _ = std::fs::remove_file(&self.socket_path);
        if self.own_dir {
            let _ = std::fs::remove_dir_all(&self.socket_dir);
        }
    }
}
