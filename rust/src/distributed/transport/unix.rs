//! The unix-socket multi-process transport: one worker **subprocess**
//! per replica on the same host, speaking the [`wire`](super::wire)
//! format over unix-domain sockets.
//!
//! The coordinator binds a socket, spawns workers — the binary
//! re-invoked with the hidden `--replica-worker` mode — and multiplexes
//! parameter broadcast and per-layer streamed gradient upload over each
//! worker's connection. Since the elastic fault-tolerance PR all of that
//! machinery is family-independent and lives in the shared
//! [`SocketCoordinator`](super::sock); this module is the unix-domain
//! adapter plus the public options type. The TCP twin is
//! [`TcpTransport`](super::TcpTransport).
//!
//! **Determinism.** Workers run their engine with a single pool thread
//! (the `threads` field of the init blob, default 1), which executes the
//! same serial kernel paths as an in-process replica whose nested
//! parallelism is suppressed — and f32 payloads travel bit-exactly — so
//! the unix transport is **bit-identical** to the local transport at the
//! same replica count (`tests/transport.rs` proves it).
//!
//! **Failure semantics.** A worker that exits, hangs past its heartbeat
//! grace, or drops its connection mid-step fails that step with an error
//! naming the replica; the whole group resets and the next
//! [`Transport::broadcast`] respawns every replica and re-uploads
//! parameters. A clean worker-side engine error (`Err`, not a crash)
//! fails the step but keeps the workers alive and in sync. Supervision
//! knobs (step/accept/hello deadlines, heartbeat interval) come from
//! [`supervisor`](super::supervisor); scripted fault injection from its
//! [`FaultPlan`](super::supervisor::FaultPlan).
//!
//! **Memory.** Per-replica gradients park in the coordinator's reducer
//! until the last replica delivers each layer; workers themselves hold
//! only their engine's working set — the per-process memory budget that
//! makes this the scale-out half of the ROADMAP's north star.

use std::path::PathBuf;

use crate::autodiff::GradEngine;
use crate::distributed::{ReduceOp, ReplicaStep};
use crate::model::Network;
use crate::tensor::Tensor;

use super::sock::{Endpoint, SocketCoordinator, SocketOpts};
use super::supervisor::{Deadlines, FaultPlan};
use super::{ShardSpec, Transport};

/// How a worker should instantiate its gradient engine — the arguments
/// of [`crate::autodiff::engine_by_name`], in serializable form.
#[derive(Clone, Debug)]
pub struct EngineSpec {
    /// Engine name (`"moonwalk"`, `"backprop"`, …).
    pub name: String,
    /// Fragmental block size (fragmental engines).
    pub block: usize,
    /// Checkpoint segment count (checkpointed engines); 0 = auto.
    pub checkpoint_segments: usize,
    /// Engine seed (stochastic engines).
    pub seed: u64,
}

impl EngineSpec {
    /// A spec with default hyperparameters for `name`.
    pub fn new(name: &str) -> EngineSpec {
        EngineSpec {
            name: name.to_string(),
            block: 4,
            checkpoint_segments: 0,
            seed: 0,
        }
    }
}

/// Construction options for [`UnixTransport::spawn`].
pub struct UnixTransportOpts {
    /// Worker subprocess count (one per replica).
    pub replicas: usize,
    /// JSON text of the [`crate::model::config::Config`] the workers
    /// build their network skeleton from (parameters are overwritten by
    /// the first broadcast; only the architecture must match).
    pub config_json: String,
    /// Engine each worker runs. The caller must keep this consistent
    /// with the engine it passes to [`Transport::step`].
    pub engine: EngineSpec,
    /// Worker pool threads. Keep the default **1** for bit-equality with
    /// the in-process transport, whose replicas run their kernels
    /// serially (nested parallelism suppressed).
    pub threads_per_worker: usize,
    /// Worker executable; `None` re-invokes the current binary
    /// (`std::env::current_exe`). Tests point this at the built
    /// `moonwalk` binary via `env!("CARGO_BIN_EXE_moonwalk")`.
    pub worker_bin: Option<PathBuf>,
    /// Directory for the coordinator socket; `None` creates (and later
    /// removes) a fresh directory under the system temp dir.
    pub socket_dir: Option<PathBuf>,
    /// Supervision deadlines + heartbeat interval. The default resolves
    /// the global knobs (CLI flags / `MOONWALK_*` env vars); tests set
    /// short explicit values for fast fault detection.
    pub deadlines: Deadlines,
    /// Scripted fault injections (empty in production).
    pub faults: FaultPlan,
}

impl UnixTransportOpts {
    /// Options for `replicas` workers rebuilding `config_json` and
    /// running `engine`, with the bit-equality defaults (1 worker
    /// thread, current binary, temp socket dir, globally resolved
    /// deadlines, no faults).
    pub fn new(replicas: usize, config_json: String, engine: EngineSpec) -> UnixTransportOpts {
        UnixTransportOpts {
            replicas,
            config_json,
            engine,
            threads_per_worker: 1,
            worker_bin: None,
            socket_dir: None,
            deadlines: Deadlines::resolve(),
            faults: FaultPlan::default(),
        }
    }
}

/// The unix-socket multi-process transport (see module docs).
pub struct UnixTransport {
    inner: SocketCoordinator,
}

impl UnixTransport {
    /// Bind the coordinator socket, spawn one worker subprocess per
    /// replica, and complete the handshake + init exchange with each.
    pub fn spawn(opts: UnixTransportOpts) -> anyhow::Result<UnixTransport> {
        let inner = SocketCoordinator::spawn(
            SocketOpts {
                replicas: opts.replicas,
                config_json: opts.config_json,
                engine: opts.engine,
                threads_per_worker: opts.threads_per_worker,
                worker_bin: opts.worker_bin,
                deadlines: opts.deadlines,
                faults: opts.faults,
            },
            Endpoint::Unix {
                socket_dir: opts.socket_dir,
            },
        )?;
        Ok(UnixTransport { inner })
    }

    /// Kill one worker subprocess — fault injection for the
    /// worker-death recovery tests. The next [`Transport::broadcast`]
    /// respawns it.
    pub fn kill_worker(&mut self, replica: usize) -> anyhow::Result<()> {
        self.inner.kill_worker(replica)
    }

    /// Kill one worker subprocess **without** marking it dead — fault
    /// injection that mimics an unnoticed crash: the coordinator only
    /// discovers the death when the next step's I/O hits EOF, exercising
    /// the mid-step failure path end to end.
    pub fn simulate_worker_crash(&mut self, replica: usize) -> anyhow::Result<()> {
        self.inner.simulate_worker_crash(replica)
    }

    /// Worker subprocess ids, `None` for dead replicas (observability +
    /// tests).
    pub fn worker_ids(&self) -> Vec<Option<u32>> {
        self.inner.worker_ids()
    }

    /// Replace the scripted fault schedule (chaos tests arm plans after
    /// spawn so the initial handshake stays clean).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.inner.set_fault_plan(plan)
    }
}

impl Transport for UnixTransport {
    fn name(&self) -> String {
        self.inner.family_name().into()
    }

    fn replicas(&self) -> usize {
        self.inner.replicas()
    }

    fn members(&self) -> usize {
        self.inner.members()
    }

    fn set_members(&mut self, members: usize) -> anyhow::Result<()> {
        self.inner.set_members(members)
    }

    fn heartbeat_ms(&self) -> u64 {
        self.inner.heartbeat_ms()
    }

    fn broadcast(&mut self, net: &Network) -> anyhow::Result<()> {
        self.inner.broadcast(net)
    }

    fn step(
        &mut self,
        net: &Network,
        _engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        self.inner.step(net, shards, op, sink)
    }
}
