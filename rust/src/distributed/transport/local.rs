//! The in-process transport: replicas fan out as one persistent-pool
//! region, exactly the PR 3 `ReplicaGroup` execution path, now behind
//! the [`Transport`] trait.
//!
//! Scheduling: with one replica the engine runs inline on the calling
//! thread with full internal kernel parallelism; with N replicas each
//! replica runs inside a pool share with nested kernel parallelism
//! suppressed (the batch axis *is* the parallel axis). The streamed
//! all-reduce fires on the last-delivering replica's thread, overlapped
//! with the other replicas' still-running sweeps.

use crate::autodiff::GradEngine;
use crate::distributed::{ReduceOp, ReplicaStep, Shard};
use crate::model::Network;
use crate::nn::Loss;
use crate::runtime::pool;
use crate::tensor::Tensor;

use super::{submit_to_sink, ShardSpec, Transport};

/// The in-process replica fan-out (see module docs). One [`GradEngine`]
/// execution per replica on the persistent pool, per-layer gradients
/// reduced in replica order the moment the last replica emits them.
///
/// This is the engine room shared by
/// [`ReplicaGroup::compute_streaming`](crate::distributed::ReplicaGroup::compute_streaming)
/// and [`LocalTransport::step`]: the borrow-based `Shard` API and the
/// transport's serializable [`ShardSpec`] API both land here, so the two
/// are bit-identical by construction.
///
/// `workers` caps the pool shares executing the replica set (elastic
/// membership: fewer live executors than logical shards). Because the
/// share-ordered merge concatenates outcomes back in replica order and
/// the reducer folds in replica order, the result is **bit-identical
/// for every worker count** — shares only change scheduling.
pub(crate) fn fanout_streaming(
    replicas: usize,
    workers: usize,
    net: &Network,
    engine: &dyn GradEngine,
    shards: &[Shard<'_>],
    op: ReduceOp,
    sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
) -> anyhow::Result<ReplicaStep> {
    anyhow::ensure!(
        shards.len() == replicas,
        "group has {} replicas but {} shards were supplied",
        replicas,
        shards.len()
    );
    if replicas == 1 {
        // Single replica: run on the calling thread with full internal
        // kernel parallelism (a region fan-out here would needlessly
        // serialize the engine's own kernels).
        let loss = engine.compute_streaming(net, shards[0].x, shards[0].loss, &mut |li, g| {
            sink(li, g)
        })?;
        return Ok(ReplicaStep {
            loss,
            replica_losses: vec![loss],
            reduce_s: 0.0,
        });
    }
    // Oversubscription caveat: with more replicas than pool workers, a
    // share runs its replicas *sequentially*, so an early replica's
    // whole gradient set parks in the reducer until the late replicas
    // deliver — peak memory degrades from one-layer-per-replica toward
    // full-model-per-early-replica. Correctness and determinism are
    // unaffected; warn once so the memory profile change is not silent.
    if replicas > pool::threads() {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            crate::log_warn!(
                "replicas ({}) exceed pool threads ({}): replicas run \
                 sequentially per worker and early replicas' gradients \
                 are parked until the reduce completes, raising peak \
                 memory; prefer replicas <= threads",
                replicas,
                pool::threads()
            );
        });
    }
    // Bucketed reducer: consecutive small-parameter layers coalesce into
    // one reduce bucket (bit-identical values, fewer round trips — see
    // `reduce` module docs); sized conv/dense layers stay
    // fire-on-last-contribution singletons.
    let reducer = super::reducer_for(net, replicas, op);
    // One pool region, one task per replica. Shares cover contiguous
    // replica ranges, so the share-ordered merge below concatenates
    // outcomes back in replica order.
    let outcomes: Vec<(usize, anyhow::Result<f32>)> = pool::run_reduce(
        replicas,
        pool::effective_threads(workers.clamp(1, replicas)),
        Vec::new,
        |range, acc: &mut Vec<(usize, anyhow::Result<f32>)>| {
            for r in range {
                let shard = &shards[r];
                let res = engine.compute_streaming(net, shard.x, shard.loss, &mut |li, g| {
                    submit_to_sink(&reducer, li, r, g, sink)
                });
                acc.push((r, res));
            }
        },
        |a, b| a.extend(b),
    );
    let mut replica_losses = Vec::with_capacity(replicas);
    for (r, res) in outcomes {
        match res {
            Ok(l) => replica_losses.push(l),
            Err(e) => return Err(e.context(format!("replica {r} failed"))),
        }
    }
    let loss = replica_losses.iter().sum::<f32>() / replica_losses.len() as f32;
    Ok(ReplicaStep {
        loss,
        replica_losses,
        reduce_s: reducer.reduce_seconds(),
    })
}

/// In-process transport: the current (PR 3) replica path. Replicas share
/// the caller's `&Network`, so [`Transport::broadcast`] is a no-op.
pub struct LocalTransport {
    replicas: usize,
    members: usize,
}

impl LocalTransport {
    /// A local transport executing `replicas` in-process replicas.
    pub fn new(replicas: usize) -> LocalTransport {
        LocalTransport {
            replicas: replicas.max(1),
            members: replicas.max(1),
        }
    }
}

impl Transport for LocalTransport {
    fn name(&self) -> String {
        "local".into()
    }

    fn replicas(&self) -> usize {
        self.replicas
    }

    fn members(&self) -> usize {
        self.members
    }

    fn set_members(&mut self, members: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            members >= 1 && members <= self.replicas,
            "member count {members} out of range 1..={}",
            self.replicas
        );
        // In-process "members" are pool shares; shrinking just narrows
        // the fan-out (bit-identical — see `fanout_streaming`).
        self.members = members;
        Ok(())
    }

    fn broadcast(&mut self, _net: &Network) -> anyhow::Result<()> {
        // In-process replicas read the live `&Network`; nothing to copy.
        Ok(())
    }

    fn step(
        &mut self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        // Materialize the loss heads, then run the exact borrow-based
        // fan-out `ReplicaGroup::compute_streaming` uses.
        let losses: Vec<Box<dyn Loss>> = shards.iter().map(|s| s.loss.build()).collect();
        let borrowed: Vec<Shard<'_>> = shards
            .iter()
            .zip(&losses)
            .map(|(s, l)| Shard {
                x: s.x,
                loss: l.as_ref(),
            })
            .collect();
        fanout_streaming(self.replicas, self.members, net, engine, &borrowed, op, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    use crate::autodiff::Backprop;
    use crate::distributed::transport::LossSpec;
    use crate::distributed::{split_batch, ReplicaGroup};
    use crate::model::build_mlp;
    use crate::nn::MeanLoss;
    use crate::util::Rng;

    #[test]
    fn local_transport_matches_replica_group_bitwise() {
        let mut rng = Rng::new(10);
        let net = build_mlp(&[6, 5, 3], 0.1, &mut rng);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let xs = split_batch(&x, 2).unwrap();
        // Reference: the borrow-based group API.
        let shards: Vec<Shard<'_>> = xs
            .iter()
            .map(|x| Shard {
                x,
                loss: &MeanLoss,
            })
            .collect();
        let group = ReplicaGroup::new(2).unwrap();
        let reference = group
            .compute(&net, &Backprop, &shards, ReduceOp::Mean)
            .unwrap();
        // Same step through the transport trait.
        let mut t = LocalTransport::new(2);
        t.broadcast(&net).unwrap();
        let specs: Vec<ShardSpec<'_>> = xs
            .iter()
            .map(|x| ShardSpec {
                x,
                loss: LossSpec::Mean,
            })
            .collect();
        let grads: Mutex<Vec<Vec<Tensor>>> =
            Mutex::new((0..net.depth()).map(|_| Vec::new()).collect());
        let step = t
            .step(&net, &Backprop, &specs, ReduceOp::Mean, &|li, g| {
                crate::util::lock_ignore_poison(&grads)[li] = g;
            })
            .unwrap();
        assert_eq!(step.loss.to_bits(), reference.loss.to_bits());
        let grads = grads.into_inner().unwrap();
        for (a, b) in reference.grads.iter().zip(&grads) {
            assert_eq!(a.len(), b.len());
            for (ga, gb) in a.iter().zip(b) {
                assert_eq!(ga.data(), gb.data(), "trait path must be bit-identical");
            }
        }
    }
}
