//! The TCP multi-process transport: the multi-host twin of
//! [`UnixTransport`](super::UnixTransport), speaking the identical
//! framed [`wire`](super::wire) format over TCP sockets.
//!
//! The coordinator binds a TCP listener (`--listen`, default
//! `127.0.0.1:0`) and, exactly like the unix transport, spawns local
//! worker subprocesses that dial back in — so `--transport tcp` works
//! out of the box on one host and is bit-identical to `--transport
//! unix` and `--transport local` at the same replica count (the wire
//! payloads are the same bytes; only the socket family differs).
//!
//! For **true multi-host runs**, the last `remote_workers` replica
//! slots are not spawned locally: the coordinator prints its resolved
//! listen address and waits (up to the accept deadline) for standalone
//! workers launched on other hosts via the hidden worker mode:
//!
//! ```text
//! moonwalk --replica-worker --connect-tcp <host:port> --replica <r>
//! ```
//!
//! The handshake (magic, wire version, replica id) is unchanged from
//! the unix transport, and every supervision feature — heartbeats,
//! step/accept/hello deadlines, fault injection, elastic membership —
//! comes from the shared [`SocketCoordinator`](super::sock) and behaves
//! identically on both families (`tests/fault_tolerance.rs` runs its
//! chaos grid over both).
//!
//! `TCP_NODELAY` is set on both ends: gradient frames are small and
//! latency-sensitive, and Nagle batching would serialize the streamed
//! all-reduce.

use std::path::PathBuf;

use crate::autodiff::GradEngine;
use crate::distributed::{ReduceOp, ReplicaStep};
use crate::model::Network;
use crate::tensor::Tensor;

use super::sock::{Endpoint, SocketCoordinator, SocketOpts};
use super::supervisor::{Deadlines, FaultPlan};
use super::unix::EngineSpec;
use super::{ShardSpec, Transport};

/// Construction options for [`TcpTransport::spawn`].
pub struct TcpTransportOpts {
    /// Logical replica count (fixed; defines sharding + reducer layout).
    pub replicas: usize,
    /// JSON text of the worker network config (see
    /// [`super::UnixTransportOpts::config_json`]).
    pub config_json: String,
    /// Engine each worker runs.
    pub engine: EngineSpec,
    /// Worker pool threads (keep 1 for bit-equality with local).
    pub threads_per_worker: usize,
    /// Worker executable; `None` re-invokes the current binary.
    pub worker_bin: Option<PathBuf>,
    /// Coordinator bind address; port 0 picks a free port (read it back
    /// via [`TcpTransport::local_addr`]).
    pub listen: String,
    /// How many of the replica slots (the last ones) expect standalone
    /// workers dialing in from other hosts instead of local spawns.
    pub remote_workers: usize,
    /// Supervision deadlines + heartbeat interval.
    pub deadlines: Deadlines,
    /// Scripted fault injections (empty in production).
    pub faults: FaultPlan,
}

impl TcpTransportOpts {
    /// Options for `replicas` local workers over loopback TCP with the
    /// bit-equality defaults (1 worker thread, current binary, ephemeral
    /// port, globally resolved deadlines, no faults).
    pub fn new(replicas: usize, config_json: String, engine: EngineSpec) -> TcpTransportOpts {
        TcpTransportOpts {
            replicas,
            config_json,
            engine,
            threads_per_worker: 1,
            worker_bin: None,
            listen: "127.0.0.1:0".to_string(),
            remote_workers: 0,
            deadlines: Deadlines::resolve(),
            faults: FaultPlan::default(),
        }
    }
}

/// The TCP multi-process transport (see module docs).
pub struct TcpTransport {
    inner: SocketCoordinator,
}

impl TcpTransport {
    /// Bind the listener, spawn the local workers, await any remote
    /// ones, and complete the handshake + init exchange with each.
    pub fn spawn(opts: TcpTransportOpts) -> anyhow::Result<TcpTransport> {
        let inner = SocketCoordinator::spawn(
            SocketOpts {
                replicas: opts.replicas,
                config_json: opts.config_json,
                engine: opts.engine,
                threads_per_worker: opts.threads_per_worker,
                worker_bin: opts.worker_bin,
                deadlines: opts.deadlines,
                faults: opts.faults,
            },
            Endpoint::Tcp {
                listen: opts.listen,
                remote_workers: opts.remote_workers,
            },
        )?;
        Ok(TcpTransport { inner })
    }

    /// The listener's resolved `host:port` — what remote workers pass to
    /// `--connect-tcp` (and the only way to learn an ephemeral port).
    pub fn local_addr(&self) -> String {
        self.inner.connect_addr().to_string()
    }

    /// Kill one worker subprocess (local slots only) — fault injection;
    /// the next [`Transport::broadcast`] respawns it.
    pub fn kill_worker(&mut self, replica: usize) -> anyhow::Result<()> {
        self.inner.kill_worker(replica)
    }

    /// Kill one worker subprocess **without** marking it dead (see
    /// [`super::UnixTransport::simulate_worker_crash`]).
    pub fn simulate_worker_crash(&mut self, replica: usize) -> anyhow::Result<()> {
        self.inner.simulate_worker_crash(replica)
    }

    /// Worker subprocess ids, `None` for dead slots and remote workers.
    pub fn worker_ids(&self) -> Vec<Option<u32>> {
        self.inner.worker_ids()
    }

    /// Replace the scripted fault schedule (chaos tests arm plans after
    /// spawn so the initial handshake stays clean).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.inner.set_fault_plan(plan)
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> String {
        self.inner.family_name().into()
    }

    fn replicas(&self) -> usize {
        self.inner.replicas()
    }

    fn members(&self) -> usize {
        self.inner.members()
    }

    fn set_members(&mut self, members: usize) -> anyhow::Result<()> {
        self.inner.set_members(members)
    }

    fn heartbeat_ms(&self) -> u64 {
        self.inner.heartbeat_ms()
    }

    fn broadcast(&mut self, net: &Network) -> anyhow::Result<()> {
        self.inner.broadcast(net)
    }

    fn step(
        &mut self,
        net: &Network,
        _engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        self.inner.step(net, shards, op, sink)
    }
}
