//! Pluggable replica transports: where a [`crate::distributed::ReplicaGroup`]'s
//! replicas actually execute.
//!
//! PR 3 built the data-parallel seams — parameter broadcast and a
//! replica-ordered streamed gradient all-reduce — entirely in-process.
//! This module makes both seams **transport-shaped**: a [`Transport`]
//! runs one gradient engine per replica *somewhere* (same process,
//! worker subprocesses, a future PJRT device mesh) and feeds per-layer
//! gradients back through the same [`StreamingAllReduce`] fold, so every
//! contract the in-process path established survives the process
//! boundary:
//!
//! * **Replica-ordered reduce ⇒ bit-determinism.** Partials fold in
//!   replica order, never arrival order, so a fixed replica count is
//!   bit-identical run-to-run on every transport.
//! * **[`ReduceOp::Mean`] fp-equivalence.** N replicas at batch B/N stay
//!   ≤ 1e-5 from one replica at batch B, transport-independent.
//! * **Streamed layers.** A layer reduces the moment its last
//!   contribution arrives — over a socket exactly as over a channel —
//!   so no transport ever buffers a full gradient set per replica.
//!
//! Three std-only implementations ship today: [`LocalTransport`] (the
//! in-process pool fan-out PR 3 landed, refactored behind the trait),
//! [`UnixTransport`] (one worker **subprocess** per replica, speaking
//! the [`wire`] format over `std::os::unix::net` sockets) and
//! [`TcpTransport`] (the same wire format over TCP, multi-host capable
//! via standalone `--replica-worker --connect-tcp` workers). The active
//! kind resolves like every other runtime knob: CLI `--transport` >
//! `MOONWALK_TRANSPORT` env var > `local`.
//!
//! Since the elastic fault-tolerance PR the two socket transports share
//! one supervised coordinator ([`sock`], private) governed by the
//! [`supervisor`] knobs: heartbeats, step/accept/hello deadlines,
//! scripted fault injection, and elastic membership
//! ([`Transport::set_members`]) that executes the fixed logical shard
//! set on fewer live workers — bit-identically, because the reducer
//! folds in logical shard order regardless of which worker computed a
//! shard.
//!
//! # Example
//!
//! The trait in action with the in-process transport (the unix transport
//! has the same shape but needs a spawned coordinator, see
//! [`UnixTransport`]):
//!
//! ```
//! use moonwalk::autodiff::Backprop;
//! use moonwalk::distributed::transport::{LocalTransport, LossSpec, ShardSpec, Transport};
//! use moonwalk::distributed::ReduceOp;
//! use moonwalk::model::build_mlp;
//! use moonwalk::tensor::Tensor;
//! use moonwalk::util::Rng;
//!
//! let mut rng = Rng::new(0);
//! let net = build_mlp(&[4, 3], 0.1, &mut rng);
//! let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
//! let mut transport = LocalTransport::new(1);
//! transport.broadcast(&net)?; // no-op in-process; param upload on unix
//! let shards = [ShardSpec { x: &x, loss: LossSpec::Mean }];
//! let step = transport.step(&net, &Backprop, &shards, ReduceOp::Mean, &|_layer, _grads| {})?;
//! assert!(step.loss.is_finite());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod local;
mod sock;
pub mod supervisor;
pub mod tcp;
pub mod unix;
pub mod wire;
pub mod worker;

pub use local::LocalTransport;
pub use supervisor::{Deadlines, FaultKind, FaultPlan};
pub use tcp::{TcpTransport, TcpTransportOpts};
pub use unix::{EngineSpec, UnixTransport, UnixTransportOpts};
pub use wire::WireLoss;

use std::sync::atomic::{AtomicU8, Ordering};

use crate::autodiff::GradEngine;
use crate::distributed::{ReduceOp, ReplicaStep, StreamingAllReduce};
use crate::model::Network;
use crate::tensor::Tensor;

/// A serializable description of one replica's loss head. The local
/// transport materializes it in-process; the unix transport ships it to
/// the worker as a [`WireLoss`].
#[derive(Clone, Debug)]
pub enum LossSpec<'a> {
    /// Mean of all network outputs ([`crate::nn::MeanLoss`]).
    Mean,
    /// Softmax cross-entropy against these integer targets
    /// ([`crate::nn::SoftmaxCrossEntropy`]).
    SoftmaxXent(&'a [usize]),
}

impl<'a> LossSpec<'a> {
    /// Materialize the concrete in-process loss head. Delegates to
    /// [`WireLoss::build`] so the local and remote paths construct the
    /// loss through one code path — a divergence here would break the
    /// local-vs-unix bit-equality contract.
    pub fn build(&self) -> Box<dyn crate::nn::Loss> {
        self.to_wire().build()
    }

    /// The owned wire-format twin of this spec.
    pub fn to_wire(&self) -> WireLoss {
        match self {
            LossSpec::Mean => WireLoss::Mean,
            LossSpec::SoftmaxXent(t) => WireLoss::SoftmaxXent(t.to_vec()),
        }
    }
}

/// One replica's slice of a global step in transport-portable form: the
/// input shard plus a loss *description* (rather than a live `&dyn Loss`,
/// which cannot cross a process boundary).
pub struct ShardSpec<'a> {
    /// The replica-local input batch.
    pub x: &'a Tensor,
    /// The loss head to evaluate on this shard.
    pub loss: LossSpec<'a>,
}

/// Where and how a replica group executes its replicas (see module docs).
///
/// Implementations must preserve the distributed contracts: per-layer
/// gradients reduced in replica order through [`StreamingAllReduce`]
/// semantics, `sink` invoked once per parameterized layer with the fully
/// reduced tensors, and failures surfaced as step errors that name the
/// replica.
pub trait Transport: Send {
    /// Human-readable transport name (`"local"`, `"unix"`), recorded in
    /// metrics so runs are attributable.
    fn name(&self) -> String;

    /// Fixed **logical** replica (shard) count of this transport — the
    /// data sharding and reducer layout never change, whatever the live
    /// worker count ([`Transport::members`]) currently is.
    fn replicas(&self) -> usize;

    /// Live executor count. Defaults to [`Transport::replicas`]; the
    /// socket transports may run degraded with fewer members after
    /// [`Transport::set_members`], executing several logical shards per
    /// worker.
    fn members(&self) -> usize {
        self.replicas()
    }

    /// Elastically resize the executor set (workers leave on shrink,
    /// join on grow; a re-[`broadcast`](Transport::broadcast) follows
    /// either way). The logical shard count is untouched, so the
    /// reduced gradient stays bit-identical at equal global batch.
    /// Transports without elastic membership reject any change.
    fn set_members(&mut self, members: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            members == self.replicas(),
            "the {} transport does not support elastic membership",
            self.name()
        );
        Ok(())
    }

    /// The supervised heartbeat interval in milliseconds (0 = no
    /// heartbeats — in-process transports need none), recorded in
    /// metrics.
    fn heartbeat_ms(&self) -> u64 {
        0
    }

    /// Synchronize every replica's parameters with `net` — the broadcast
    /// seam. In-process replicas share `net` by reference (no-op); remote
    /// transports upload the full parameter set and **must** be called
    /// again after every parameter update, and after any step error (a
    /// broadcast is also what respawns dead remote workers).
    fn broadcast(&mut self, net: &Network) -> anyhow::Result<()>;

    /// Run one replicated gradient step: one engine execution per
    /// replica over `shards` (replica order), per-layer gradients
    /// all-reduced with `op` and streamed to `sink(layer, grads)` the
    /// moment each layer's last contribution arrives. `sink` is called
    /// from transport-internal threads and must be `Sync`.
    ///
    /// `engine` is authoritative for the local transport; remote
    /// transports run the engine they were configured with at spawn time
    /// (the caller is responsible for keeping the two consistent).
    fn step(
        &mut self,
        net: &Network,
        engine: &dyn GradEngine,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep>;
}

// ----- transport-kind resolution ---------------------------------------------

/// Which transport family a run uses (CLI `--transport`, env
/// `MOONWALK_TRANSPORT`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process replicas on the persistent worker pool.
    Local,
    /// One worker subprocess per replica over unix-domain sockets.
    Unix,
    /// Socket workers over TCP — same wire format, multi-host capable.
    Tcp,
}

impl TransportKind {
    /// Parse a CLI/env spelling (`"local"` / `"unix"` / `"tcp"`).
    pub fn parse(s: &str) -> anyhow::Result<TransportKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "local" | "in-process" => Ok(TransportKind::Local),
            "unix" | "unix-socket" => Ok(TransportKind::Unix),
            "tcp" | "tcp-socket" => Ok(TransportKind::Tcp),
            other => anyhow::bail!("unknown transport `{other}` (local|unix|tcp)"),
        }
    }
}

/// Global transport selection; 0 = unresolved.
static KIND: AtomicU8 = AtomicU8::new(0);

fn resolve_default() -> TransportKind {
    if let Ok(v) = std::env::var("MOONWALK_TRANSPORT") {
        if let Ok(k) = TransportKind::parse(&v) {
            return k;
        }
        crate::log_warn!("MOONWALK_TRANSPORT=`{v}` not recognized (local|unix|tcp); using local");
    }
    TransportKind::Local
}

/// The configured transport kind (resolving lazily on first use):
/// [`set_kind`] > `MOONWALK_TRANSPORT` > [`TransportKind::Local`].
pub fn kind() -> TransportKind {
    match KIND.load(Ordering::Relaxed) {
        1 => TransportKind::Local,
        2 => TransportKind::Unix,
        3 => TransportKind::Tcp,
        _ => {
            let k = resolve_default();
            set_kind(k);
            k
        }
    }
}

/// Select the transport kind explicitly (the CLI's `--transport`).
pub fn set_kind(k: TransportKind) {
    KIND.store(
        match k {
            TransportKind::Local => 1,
            TransportKind::Unix => 2,
            TransportKind::Tcp => 3,
        },
        Ordering::Relaxed,
    );
}

/// Shared reducer-driving helper for transports: submit one replica's
/// layer gradients and forward every layer this submission completes to
/// the sink — one layer for singleton buckets, the full member list
/// (ascending layer order) when it closes a fused bucket.
pub(crate) fn submit_to_sink(
    reducer: &StreamingAllReduce,
    layer: usize,
    replica: usize,
    grads: Vec<Tensor>,
    sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
) {
    for (li, reduced) in reducer.submit_bucketed(layer, replica, grads) {
        sink(li, reduced);
    }
}

/// The transports' shared reducer construction: gradient-bucket fusion
/// over the network's per-layer parameter payloads at the default
/// threshold ([`crate::distributed::reduce::DEFAULT_BUCKET_BYTES`]).
/// Both transports build their per-step reducer here so the fusion map
/// — and therefore delivery batching — is identical across them.
pub(crate) fn reducer_for(
    net: &Network,
    replicas: usize,
    op: ReduceOp,
) -> StreamingAllReduce {
    let layer_bytes: Vec<usize> = net.layers.iter().map(|l| l.n_params() * 4).collect();
    StreamingAllReduce::with_buckets(
        &layer_bytes,
        replicas,
        op,
        crate::distributed::reduce::DEFAULT_BUCKET_BYTES,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_sets() {
        assert_eq!(TransportKind::parse("local").unwrap(), TransportKind::Local);
        assert_eq!(TransportKind::parse("UNIX").unwrap(), TransportKind::Unix);
        assert_eq!(
            TransportKind::parse("unix-socket").unwrap(),
            TransportKind::Unix
        );
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp);
        assert!(TransportKind::parse("pigeon").is_err());
        let before = kind();
        set_kind(TransportKind::Unix);
        assert_eq!(kind(), TransportKind::Unix);
        set_kind(before);
    }

    #[test]
    fn loss_spec_builds_and_converts() {
        let targets = [1usize, 0, 2];
        let spec = LossSpec::SoftmaxXent(&targets);
        assert_eq!(spec.to_wire(), WireLoss::SoftmaxXent(vec![1, 0, 2]));
        let head = spec.build();
        assert_eq!(head.name(), "softmax_xent");
        assert_eq!(LossSpec::Mean.build().name(), "mean");
    }
}
