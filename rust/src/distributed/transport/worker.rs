//! The replica worker: what runs inside each subprocess the
//! [`UnixTransport`](super::UnixTransport) spawns.
//!
//! The binary re-invokes itself as
//! `moonwalk --replica-worker --connect <socket> --replica <r>`; this
//! module is that mode's whole life: connect, handshake, build the
//! configured network + engine from the init blob, then serve
//! `Params` / `Step` frames until `Shutdown` or EOF.
//!
//! Per step the worker runs its engine's streaming API and uploads each
//! layer's gradients **the moment the engine emits them** (one flushed
//! frame per layer), so the coordinator's streamed all-reduce overlaps
//! this worker's still-running sweep. A clean engine `Err` is reported
//! as an `Error` frame (the worker keeps serving); a panic takes the
//! process down and surfaces coordinator-side as an EOF step error
//! naming this replica — the subprocess mirror of the in-process
//! panic-re-raise path.
//!
//! Determinism: the init blob pins the worker's pool thread count
//! (default 1), putting every kernel on the same serial code path an
//! in-process replica uses when its nested parallelism is suppressed —
//! this is what makes unix-vs-local gradients bit-identical.

use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::UnixStream;

use crate::autodiff::engine_by_name;
use crate::cli::Args;
use crate::model::config::Config;
use crate::runtime::pool;
use crate::util::json::Json;
use crate::util::Rng;

use super::wire::{self, Msg};

/// Run the worker protocol over an established stream until `Shutdown`
/// or EOF. Split from [`run`] so tests can drive a worker over an
/// in-process socketpair without spawning a subprocess.
pub fn serve(stream: UnixStream, replica: usize) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    wire::write_hello(&mut writer, replica as u32)?;
    writer.flush()?;

    // Init: architecture + engine + runtime configuration.
    let init = match wire::read_msg(&mut reader)? {
        Msg::Init { config } => config,
        other => anyhow::bail!("replica {replica}: expected init, got {other:?}"),
    };
    let init = Json::parse(&init).map_err(|e| anyhow::anyhow!("bad init JSON: {e}"))?;
    let cfg = Config::from_json(init.get("config"))?;
    let espec = init.get("engine");
    let engine = engine_by_name(
        espec.opt_str("name", &cfg.engine),
        espec.opt_usize("block", cfg.block),
        espec.opt_usize("checkpoint_segments", cfg.checkpoint_every),
        espec.opt_usize("seed", cfg.seed as usize) as u64,
    )?;
    // Pin the pool before any tensor work: serial kernels by default,
    // matching an in-process replica's suppressed nested parallelism.
    pool::set_threads(init.opt_usize("threads", 1).max(1));
    // Architecture skeleton only — the first Params frame overwrites
    // every parameter bit-exactly.
    let mut rng = Rng::new(cfg.seed);
    let mut net = cfg.build_network(&mut rng);

    loop {
        match wire::read_msg(&mut reader) {
            Ok(Msg::Params { layers }) => {
                net.import_params(&layers)
                    .map_err(|e| e.context(format!("replica {replica}: param import")))?;
            }
            Ok(Msg::Step { x, loss }) => {
                let head = loss.build();
                // Stream each layer's gradients as the engine emits
                // them; an I/O failure mid-stream aborts the step (the
                // coordinator is gone or closing).
                let mut io_err: Option<std::io::Error> = None;
                let result = engine.compute_streaming(&net, &x, head.as_ref(), &mut |li, g| {
                    if io_err.is_none() {
                        let send = wire::write_grad(&mut writer, li as u32, &g)
                            .and_then(|_| writer.flush());
                        if let Err(e) = send {
                            io_err = Some(e);
                        }
                    }
                });
                if let Some(e) = io_err {
                    return Err(anyhow::anyhow!(
                        "replica {replica}: gradient upload failed: {e}"
                    ));
                }
                match result {
                    Ok(loss_val) => wire::write_step_done(&mut writer, loss_val)?,
                    Err(e) => wire::write_error(&mut writer, &format!("{e:#}"))?,
                }
                writer.flush()?;
            }
            Ok(Msg::Shutdown) => return Ok(()),
            Ok(other) => anyhow::bail!("replica {replica}: unexpected {other:?}"),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                // Coordinator dropped the connection (e.g. its process
                // ended without a shutdown frame): exit quietly.
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// The `--replica-worker` subprocess entry point: connect to the
/// coordinator socket named by `--connect` and [`serve`] the protocol.
pub fn run(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("--replica-worker needs --connect <socket>"))?;
    let replica = args.get_usize("replica", 0)?;
    let stream = UnixStream::connect(path)
        .map_err(|e| anyhow::anyhow!("connecting to coordinator at {path}: {e}"))?;
    serve(stream, replica)
}
