//! The replica worker: what runs inside each subprocess a socket
//! transport ([`UnixTransport`](super::UnixTransport) /
//! [`TcpTransport`](super::TcpTransport)) spawns — or standalone on
//! another host for true multi-host TCP runs.
//!
//! The binary re-invokes itself as
//! `moonwalk --replica-worker --connect <socket> --replica <r>` (unix)
//! or `moonwalk --replica-worker --connect-tcp <host:port> --replica
//! <r>` (tcp); this module is that mode's whole life: connect (with
//! exponential backoff on the TCP path, where the coordinator may not
//! be listening yet), handshake, build the configured network + engine
//! from the init blob, then serve `Params` / `Step` frames until
//! `Shutdown` or EOF.
//!
//! Per step the worker runs its engine's streaming API and uploads each
//! layer's gradients **the moment the engine emits them** (one flushed
//! frame per layer), so the coordinator's streamed all-reduce overlaps
//! this worker's still-running sweep. A clean engine `Err` is reported
//! as an `Error` frame (the worker keeps serving); a panic takes the
//! process down and surfaces coordinator-side as an EOF step error
//! naming this replica — the subprocess mirror of the in-process
//! panic-re-raise path.
//!
//! **Heartbeats.** When the init blob carries a non-zero
//! `heartbeat_ms`, a ticker thread shares the frame writer and emits
//! [`Heartbeat`](super::wire::Msg::Heartbeat) frames — but **only while
//! a step is computing**. Between steps the coordinator is not reading
//! this connection, and unread ticks would silently fill the socket
//! buffer; during compute they are exactly the liveness signal the
//! supervisor's grace check needs.
//!
//! **Telemetry piggyback (wire v3).** After a step's last gradient
//! frame and before `StepDone`, the worker sends one
//! [`Metrics`](super::wire::Msg::Metrics) frame carrying the step's
//! counter deltas (diff of the registry before/after compute) and a
//! `step.seconds` observation. The coordinator folds these into
//! `replica="<logical shard>"`-labeled series so one `/metrics` scrape
//! shows the whole fleet (ISSUE 10). Purely observational: nothing the
//! engine computes reads any of it.
//!
//! **Fault injection.** The init blob may carry worker-side
//! [`FaultPlan`](super::supervisor::FaultPlan) events: `kill` aborts
//! the process right after flushing the first gradient frame of the
//! matched step (leaving the coordinator holding a partial delivery),
//! `hang` wedges the process silently — no heartbeats, no frames, no
//! exit. Events match the worker's *n*-th served step since (re)spawn;
//! one-shot events were consumed coordinator-side at arming, so a
//! respawned worker comes back clean unless the event was the `@*`
//! wildcard.
//!
//! Determinism: the init blob pins the worker's pool thread count
//! (default 1), putting every kernel on the same serial code path an
//! in-process replica uses when its nested parallelism is suppressed —
//! this is what makes socket-vs-local gradients bit-identical.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::autodiff::engine_by_name;
use crate::cli::Args;
use crate::model::config::Config;
use crate::runtime::pool;
use crate::util::json::Json;
use crate::util::lock_ignore_poison as lock;
use crate::util::Rng;

use super::sock::SockStream;
use super::supervisor::{Backoff, Deadlines};
use super::wire::{self, Msg};

/// A worker-side injected failure parsed from the init blob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Sabotage {
    /// Abort after flushing the first gradient frame of the step.
    Kill,
    /// Wedge silently: no heartbeats, no frames, no exit.
    Hang,
}

/// Pop the sabotage scheduled for the `served`-th step, if any.
/// One-shot events are consumed; `every` (wildcard) events persist.
fn take_sabotage(
    faults: &mut Vec<(Sabotage, Option<usize>)>,
    served: usize,
) -> Option<Sabotage> {
    let idx = faults
        .iter()
        .position(|(_, step)| step.map(|s| s == served).unwrap_or(true))?;
    let (kind, step) = faults[idx];
    if step.is_some() {
        faults.remove(idx);
    }
    Some(kind)
}

/// Run the worker protocol over an established unix stream until
/// `Shutdown` or EOF. Kept as the family-specific convenience so tests
/// can drive a worker over an in-process socketpair without spawning a
/// subprocess; the protocol itself is family-independent
/// ([`serve_framed`]).
pub fn serve(stream: UnixStream, replica: usize) -> anyhow::Result<()> {
    serve_stream(SockStream::Unix(stream), replica)
}

/// Family-generic entry: split the stream into reader + writer halves
/// and serve the protocol.
fn serve_stream(stream: SockStream, replica: usize) -> anyhow::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    serve_framed(reader, stream, replica)
}

/// The worker protocol proper (see module docs).
fn serve_framed(
    mut reader: BufReader<SockStream>,
    writer: SockStream,
    replica: usize,
) -> anyhow::Result<()> {
    // The writer is shared with the heartbeat ticker; the mutex scopes
    // whole frames, so ticks never interleave with a gradient frame.
    let writer = Arc::new(Mutex::new(BufWriter::new(writer)));
    {
        let mut w = lock(&writer);
        wire::write_hello(&mut *w, replica as u32)?;
        w.flush()?;
    }

    // Init: architecture + engine + runtime configuration + faults.
    let init = match wire::read_msg(&mut reader)? {
        Msg::Init { config } => config,
        other => anyhow::bail!("replica {replica}: expected init, got {other:?}"),
    };
    let init = Json::parse(&init).map_err(|e| anyhow::anyhow!("bad init JSON: {e}"))?;
    let cfg = Config::from_json(init.get("config"))?;
    let espec = init.get("engine");
    let engine = engine_by_name(
        espec.opt_str("name", &cfg.engine),
        espec.opt_usize("block", cfg.block),
        espec.opt_usize("checkpoint_segments", cfg.checkpoint_every),
        espec.opt_usize("seed", cfg.seed as usize) as u64,
    )?;
    // Pin the pool before any tensor work: serial kernels by default,
    // matching an in-process replica's suppressed nested parallelism.
    pool::set_threads(init.opt_usize("threads", 1).max(1));
    let heartbeat_ms = init.opt_usize("heartbeat_ms", 0) as u64;
    let mut faults: Vec<(Sabotage, Option<usize>)> = Vec::new();
    if let Some(events) = init.get("faults").as_arr() {
        for event in events {
            let kind = match event.opt_str("kind", "") {
                "kill" => Sabotage::Kill,
                "hang" => Sabotage::Hang,
                other => anyhow::bail!("replica {replica}: unknown worker fault `{other}`"),
            };
            let step = if event.opt_bool("every", false) {
                None
            } else {
                Some(event.opt_usize("step", 0))
            };
            faults.push((kind, step));
        }
    }
    // Architecture skeleton only — the first Params frame overwrites
    // every parameter bit-exactly.
    let mut rng = Rng::new(cfg.seed);
    let mut net = cfg.build_network(&mut rng);

    // `active` gates the ticker to compute windows (see module docs);
    // `stop` ends it when the serve loop exits.
    let active = AtomicBool::new(false);
    let stop = AtomicBool::new(false);
    let mut served = 0usize;
    std::thread::scope(|scope| {
        if heartbeat_ms > 0 {
            let writer = Arc::clone(&writer);
            let active = &active;
            let stop = &stop;
            scope.spawn(move || {
                let interval = Duration::from_millis(heartbeat_ms);
                let nap = Duration::from_millis(heartbeat_ms.clamp(1, 25));
                let mut last = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    if active.load(Ordering::Relaxed) && last.elapsed() >= interval {
                        let mut w = lock(&writer);
                        // Re-check under the lock: a step that just
                        // finished must not gain a trailing tick.
                        if active.load(Ordering::Relaxed) {
                            let _ = wire::write_heartbeat(&mut *w).and_then(|_| w.flush());
                        }
                        last = Instant::now();
                    }
                    std::thread::sleep(nap);
                }
            });
        }
        let out = (|| -> anyhow::Result<()> {
            loop {
                match wire::read_msg(&mut reader) {
                    Ok(Msg::Params { layers }) => {
                        net.import_params(&layers)
                            .map_err(|e| e.context(format!("replica {replica}: param import")))?;
                    }
                    Ok(Msg::Step { x, loss }) => {
                        let _ss = crate::span!("worker.step", step = served);
                        let sabotage = take_sabotage(&mut faults, served);
                        served += 1;
                        if sabotage == Some(Sabotage::Hang) {
                            // A wedged process: never heartbeats, never
                            // answers, never exits. Only the supervisor's
                            // grace/deadline (or a kill) ends this.
                            loop {
                                std::thread::sleep(Duration::from_secs(3600));
                            }
                        }
                        let kill = sabotage == Some(Sabotage::Kill);
                        let head = loss.build();
                        // Telemetry piggyback baseline: counter values
                        // before the step, diffed after compute so the
                        // Metrics frame carries this step's deltas only.
                        let counters_before: std::collections::BTreeMap<String, u64> =
                            crate::obs::metrics::counters().into_iter().collect();
                        let step_start = Instant::now();
                        // Stream each layer's gradients as the engine
                        // emits them; an I/O failure mid-stream aborts
                        // the step (the coordinator is gone or closing).
                        let mut io_err: Option<std::io::Error> = None;
                        let mut frames_sent = 0usize;
                        active.store(true, Ordering::Relaxed);
                        let result =
                            engine.compute_streaming(&net, &x, head.as_ref(), &mut |li, g| {
                                if io_err.is_none() {
                                    let mut w = lock(&writer);
                                    let send = wire::write_grad(&mut *w, li as u32, &g)
                                        .and_then(|_| w.flush());
                                    drop(w);
                                    match send {
                                        Ok(()) => {
                                            frames_sent += 1;
                                            if kill && frames_sent == 1 {
                                                // kill -9 mid-step: the
                                                // coordinator now holds a
                                                // partial delivery.
                                                std::process::abort();
                                            }
                                        }
                                        Err(e) => io_err = Some(e),
                                    }
                                }
                            });
                        active.store(false, Ordering::Relaxed);
                        if let Some(e) = io_err {
                            return Err(anyhow::anyhow!(
                                "replica {replica}: gradient upload failed: {e}"
                            ));
                        }
                        // Telemetry piggyback: this step's counter deltas
                        // plus the compute wall time, sent after the last
                        // gradient frame and before StepDone (wire v3).
                        // Pure observation — the coordinator folds it
                        // into `replica="…"`-labeled series.
                        let step_secs = step_start.elapsed().as_secs_f64();
                        let mut deltas: Vec<(String, u64)> = Vec::new();
                        for (name, value) in crate::obs::metrics::counters() {
                            let before = counters_before.get(&name).copied().unwrap_or(0);
                            if value > before {
                                deltas.push((name, value - before));
                            }
                        }
                        let observations = vec![("step.seconds".to_string(), step_secs)];
                        let mut w = lock(&writer);
                        wire::write_metrics(&mut *w, &deltas, &observations)?;
                        match result {
                            Ok(loss_val) => wire::write_step_done(&mut *w, loss_val)?,
                            Err(e) => wire::write_error(&mut *w, &format!("{e:#}"))?,
                        }
                        w.flush()?;
                    }
                    Ok(Msg::Shutdown) => return Ok(()),
                    Ok(Msg::Heartbeat) => {} // tolerated, not expected
                    Ok(other) => anyhow::bail!("replica {replica}: unexpected {other:?}"),
                    Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                        // Coordinator dropped the connection (e.g. its
                        // process ended without a shutdown frame): exit
                        // quietly.
                        return Ok(());
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        })();
        stop.store(true, Ordering::Relaxed);
        out
    })
}

/// The `--replica-worker` subprocess entry point: connect to the
/// coordinator named by `--connect` (unix socket path) or
/// `--connect-tcp` (`host:port`) and serve the protocol.
pub fn run(args: &Args) -> anyhow::Result<()> {
    let replica = args.get_usize("replica", 0)?;
    // Enable span capture when the coordinator is tracing (it exports
    // `MOONWALK_TRACE_DIR` before spawning us); the spool file written
    // on exit is merged into the coordinator's Chrome trace.
    crate::obs::export::worker_init_from_env();
    if let Some(addr) = args.get("connect-tcp") {
        // The coordinator may still be binding (or briefly down between
        // respawns on a multi-host run): retry with backoff for the
        // accept window instead of failing on the first refusal.
        let deadline = Instant::now() + Deadlines::resolve().accept;
        let mut backoff = Backoff::new(10, 500);
        let stream = loop {
            match TcpStream::connect(&addr) {
                Ok(s) => break s,
                Err(e) => {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "connecting to coordinator at {addr}: {e}"
                    );
                    std::thread::sleep(backoff.delay());
                }
            }
        };
        stream.set_nodelay(true)?;
        let res = serve_stream(SockStream::Tcp(stream), replica);
        let _ = crate::obs::export::write_worker_file(replica);
        return res;
    }
    let path = args
        .get("connect")
        .ok_or_else(|| {
            anyhow::anyhow!("--replica-worker needs --connect <socket> or --connect-tcp <addr>")
        })?;
    let stream = UnixStream::connect(path)
        .map_err(|e| anyhow::anyhow!("connecting to coordinator at {path}: {e}"))?;
    let res = serve_stream(SockStream::Unix(stream), replica);
    let _ = crate::obs::export::write_worker_file(replica);
    res
}
