//! The multi-process wire format: length-prefixed framed messages with a
//! version byte, carrying tensors as `layer id + shape + little-endian
//! f32 payload`.
//!
//! Design constraints, in order:
//!
//! 1. **Exactness.** f32 payloads travel as raw little-endian bits, so a
//!    round trip is the identity on every value (including NaN payload
//!    bits) — the precondition for the transport bit-equality contract
//!    in `tests/transport.rs`.
//! 2. **Std-only.** No serde on the offline image; the codec is a small
//!    hand-rolled cursor over `[tag u8][len u32 LE][payload]` frames.
//! 3. **Streaming.** Gradient uploads are one frame per layer, flushed
//!    as the engine emits them, so the coordinator's streamed all-reduce
//!    overlaps the worker's still-running sweep exactly like the
//!    in-process path.
//!
//! Writers borrow ([`write_params`], [`write_step`], [`write_grad`]);
//! the reader returns an owned [`Msg`]. Every reader validates frame
//! length against [`MAX_FRAME`] so a corrupt peer cannot trigger an
//! unbounded allocation.

use std::io::{self, Read, Write};

use crate::tensor::Tensor;

/// Protocol version; bumped on any incompatible framing change. Carried
/// in the [`Msg::Hello`] handshake and checked by both peers. Version 2
/// added the [`Msg::Heartbeat`] liveness frame; version 3 added the
/// [`Msg::Metrics`] telemetry frame workers piggyback after their last
/// gradient frame each step.
pub const WIRE_VERSION: u8 = 3;

/// Handshake magic preceding the version byte (`b"MWTP"` — MoonWalk
/// TransPort), so a stray connection is rejected immediately.
pub const MAGIC: [u8; 4] = *b"MWTP";

/// Upper bound on a single frame's payload (1 GiB). Step and gradient
/// frames scale with one shard / one layer's tensors; the parameter
/// broadcast is one frame for the whole model, so **writers enforce the
/// bound too** (the framing layer errors cleanly instead of truncating
/// the length prefix and desyncing the stream) — a > 1 GiB-parameter model
/// needs a chunked params frame before it can use this transport.
pub const MAX_FRAME: u32 = 1 << 30;

// Frame tags (one byte on the wire). Public so the supervision layer can
// classify frames (e.g. target a fault at the first gradient frame)
// without decoding them.
/// [`Msg::Hello`] frame tag.
pub const TAG_HELLO: u8 = 1;
/// [`Msg::Init`] frame tag.
pub const TAG_INIT: u8 = 2;
/// [`Msg::Params`] frame tag.
pub const TAG_PARAMS: u8 = 3;
/// [`Msg::Step`] frame tag.
pub const TAG_STEP: u8 = 4;
/// [`Msg::Grad`] frame tag.
pub const TAG_GRAD: u8 = 5;
/// [`Msg::StepDone`] frame tag.
pub const TAG_STEP_DONE: u8 = 6;
/// [`Msg::Error`] frame tag.
pub const TAG_ERROR: u8 = 7;
/// [`Msg::Shutdown`] frame tag.
pub const TAG_SHUTDOWN: u8 = 8;
/// [`Msg::Heartbeat`] frame tag (wire version 2).
pub const TAG_HEARTBEAT: u8 = 9;
/// [`Msg::Metrics`] frame tag (wire version 3).
pub const TAG_METRICS: u8 = 10;

/// A serializable loss head — the subset of [`crate::nn::Loss`] choices
/// a remote replica can reconstruct from bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireLoss {
    /// [`crate::nn::MeanLoss`]: mean of all network outputs.
    Mean,
    /// [`crate::nn::SoftmaxCrossEntropy`] with these integer targets.
    SoftmaxXent(Vec<usize>),
}

impl WireLoss {
    /// Materialize the concrete loss head this spec describes.
    pub fn build(&self) -> Box<dyn crate::nn::Loss> {
        match self {
            WireLoss::Mean => Box::new(crate::nn::MeanLoss),
            WireLoss::SoftmaxXent(targets) => {
                Box::new(crate::nn::SoftmaxCrossEntropy::new(targets.clone()))
            }
        }
    }
}

/// One decoded protocol message (the owned, reader-side view).
#[derive(Debug)]
pub enum Msg {
    /// Worker → coordinator handshake: protocol version + replica id.
    Hello {
        /// The worker's [`WIRE_VERSION`].
        version: u8,
        /// Which replica slot this connection serves.
        replica: u32,
    },
    /// Coordinator → worker: one JSON blob with the model/engine/runtime
    /// configuration the worker should build before its first step.
    Init {
        /// JSON text (`{"config": {..}, "engine": {..}, "threads": n}`).
        config: String,
    },
    /// Coordinator → worker: full parameter broadcast, one tensor list
    /// per layer in layer order (empty lists for parameter-free layers).
    Params {
        /// `layers[layer][param]`, aligned with the network's layers.
        layers: Vec<Vec<Tensor>>,
    },
    /// Coordinator → worker: one gradient step over one shard.
    Step {
        /// The replica-local input shard.
        x: Tensor,
        /// The loss head to evaluate on this shard.
        loss: WireLoss,
    },
    /// Worker → coordinator: one layer's parameter gradients, streamed
    /// the moment the worker's engine emits them.
    Grad {
        /// Layer index the gradients belong to.
        layer: u32,
        /// One tensor per parameter of that layer.
        grads: Vec<Tensor>,
    },
    /// Worker → coordinator: the step finished; every `Grad` frame for
    /// it has already been sent.
    StepDone {
        /// The shard-local loss value.
        loss: f32,
    },
    /// Worker → coordinator: the step failed cleanly (engine error).
    Error {
        /// Human-readable failure description.
        message: String,
    },
    /// Coordinator → worker: exit the serve loop and terminate.
    Shutdown,
    /// Worker → coordinator liveness tick, sent every `heartbeat_ms`
    /// while the worker is computing a step. Carries no payload; the
    /// supervision layer only cares that bytes keep arriving.
    Heartbeat,
    /// Worker → coordinator telemetry, piggybacked once per step after
    /// the last gradient frame (wire version 3). Carries the worker's
    /// per-step counter deltas and histogram observations under their
    /// flat registry keys; the coordinator folds them into
    /// `replica="<logical shard>"`-labeled series so one `/metrics`
    /// scrape shows the whole fleet. Purely observational — losing or
    /// reordering a metrics frame can never change a computed value.
    Metrics {
        /// `(registry key, delta)` counter increments for this step.
        counters: Vec<(String, u64)>,
        /// `(registry key, value)` histogram observations for this step.
        observations: Vec<(String, f64)>,
    },
}

// ----- primitive encoders ----------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    buf.push(t.rank() as u8);
    for &d in t.shape() {
        put_u32(buf, d as u32);
    }
    buf.reserve(t.len() * 4);
    for &v in t.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

// ----- primitive decoders ----------------------------------------------------

/// Bounds-checked little-endian reader over one frame's payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "wire frame truncated",
            ));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32(&mut self) -> io::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> io::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> io::Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "wire string is not UTF-8"))
    }

    fn tensor(&mut self) -> io::Result<Tensor> {
        let rank = self.u8()? as usize;
        if rank > 8 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "tensor rank exceeds the wire limit",
            ));
        }
        let mut shape = Vec::with_capacity(rank);
        let mut n: usize = 1;
        for _ in 0..rank {
            let d = self.u32()? as usize;
            n = n.saturating_mul(d);
            shape.push(d);
        }
        if n.saturating_mul(4) > MAX_FRAME as usize {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "tensor payload exceeds the frame limit",
            ));
        }
        let raw = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(Tensor::from_vec(data, &shape))
    }

    fn finish(self) -> io::Result<()> {
        if self.pos != self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "wire frame has trailing bytes",
            ));
        }
        Ok(())
    }
}

// ----- framing ---------------------------------------------------------------

fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME as usize {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "frame tag {tag} of {} bytes exceeds the {MAX_FRAME}-byte wire limit",
                payload.len()
            ),
        ));
    }
    w.write_all(&[tag])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)
}

/// The oversized-length error every reader raises, naming the connection
/// (`peer`), the frame tag and the offending length — the context the
/// supervision layer needs to attribute a corrupt peer.
fn oversized(peer: &str, tag: u8, len: u32) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("{peer}: wire frame tag {tag} of {len} bytes exceeds the {MAX_FRAME}-byte limit"),
    )
}

/// Read one message, blocking. A clean EOF before any byte of a frame
/// surfaces as [`io::ErrorKind::UnexpectedEof`] — the coordinator maps
/// that onto "worker died" / the worker onto "coordinator gone".
/// Decode failures are labeled with the anonymous peer name `"peer"`;
/// supervised connections use [`read_msg_from`] to attribute errors.
pub fn read_msg(r: &mut impl Read) -> io::Result<Msg> {
    read_msg_from(r, "peer")
}

/// [`read_msg`] for a named connection: every framing/decoding error
/// names `peer` (e.g. `"replica 3 (tcp)"`), the frame tag and the
/// offending length, so a supervisor can attribute the failure without
/// guessing which reader thread raised it.
pub fn read_msg_from(r: &mut impl Read, peer: &str) -> io::Result<Msg> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let tag = head[0];
    let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]);
    if len > MAX_FRAME {
        return Err(oversized(peer, tag, len));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| io::Error::new(e.kind(), format!("{peer}: frame tag {tag}: {e}")))?;
    decode_frame(tag, &payload, peer)
}

/// Decode one complete frame's payload into a [`Msg`]. Every decode
/// error is labeled with `peer`, the frame tag and the payload length —
/// a corrupt frame must name the connection it arrived on.
pub fn decode_frame(tag: u8, payload: &[u8], peer: &str) -> io::Result<Msg> {
    decode_frame_inner(tag, payload).map_err(|e| {
        io::Error::new(
            e.kind(),
            format!(
                "{peer}: corrupt frame tag {tag} ({} bytes): {e}",
                payload.len()
            ),
        )
    })
}

fn decode_frame_inner(tag: u8, payload: &[u8]) -> io::Result<Msg> {
    let len = payload.len();
    let mut c = Cursor::new(payload);
    let msg = match tag {
        TAG_HELLO => {
            let magic = c.take(4)?;
            if magic != MAGIC {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "bad handshake magic",
                ));
            }
            Msg::Hello {
                version: c.u8()?,
                replica: c.u32()?,
            }
        }
        TAG_INIT => {
            let raw = c.take(len)?;
            Msg::Init {
                config: String::from_utf8(raw.to_vec()).map_err(|_| {
                    io::Error::new(io::ErrorKind::InvalidData, "init config is not UTF-8")
                })?,
            }
        }
        TAG_PARAMS => {
            let n_layers = c.u32()? as usize;
            let mut layers = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let n_params = c.u32()? as usize;
                let mut params = Vec::with_capacity(n_params);
                for _ in 0..n_params {
                    params.push(c.tensor()?);
                }
                layers.push(params);
            }
            Msg::Params { layers }
        }
        TAG_STEP => {
            let kind = c.u8()?;
            let loss = match kind {
                0 => WireLoss::Mean,
                1 => {
                    let n = c.u32()? as usize;
                    let mut targets = Vec::with_capacity(n);
                    for _ in 0..n {
                        targets.push(c.u32()? as usize);
                    }
                    WireLoss::SoftmaxXent(targets)
                }
                other => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("unknown loss kind {other}"),
                    ))
                }
            };
            Msg::Step {
                x: c.tensor()?,
                loss,
            }
        }
        TAG_GRAD => {
            let layer = c.u32()?;
            let n = c.u32()? as usize;
            let mut grads = Vec::with_capacity(n);
            for _ in 0..n {
                grads.push(c.tensor()?);
            }
            Msg::Grad { layer, grads }
        }
        TAG_STEP_DONE => Msg::StepDone { loss: c.f32()? },
        TAG_ERROR => {
            let raw = c.take(len)?;
            Msg::Error {
                message: String::from_utf8_lossy(raw).into_owned(),
            }
        }
        TAG_SHUTDOWN => Msg::Shutdown,
        TAG_HEARTBEAT => Msg::Heartbeat,
        TAG_METRICS => {
            let n_counters = c.u32()? as usize;
            let mut counters = Vec::with_capacity(n_counters.min(1024));
            for _ in 0..n_counters {
                let name = c.str()?;
                let delta = c.u64()?;
                counters.push((name, delta));
            }
            let n_obs = c.u32()? as usize;
            let mut observations = Vec::with_capacity(n_obs.min(1024));
            for _ in 0..n_obs {
                let name = c.str()?;
                let v = c.f64()?;
                observations.push((name, v));
            }
            Msg::Metrics {
                counters,
                observations,
            }
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unknown wire tag {other}"),
            ))
        }
    };
    // Error frames may legitimately consume everything; others must too.
    match &msg {
        Msg::Init { .. } | Msg::Error { .. } => Ok(msg),
        _ => {
            c.finish()?;
            Ok(msg)
        }
    }
}

/// Write the worker→coordinator handshake.
pub fn write_hello(w: &mut impl Write, replica: u32) -> io::Result<()> {
    let mut buf = Vec::with_capacity(9);
    buf.extend_from_slice(&MAGIC);
    buf.push(WIRE_VERSION);
    put_u32(&mut buf, replica);
    write_frame(w, TAG_HELLO, &buf)
}

/// Write the coordinator→worker init blob (JSON text).
pub fn write_init(w: &mut impl Write, config_json: &str) -> io::Result<()> {
    write_frame(w, TAG_INIT, config_json.as_bytes())
}

/// Write a full parameter broadcast: one tensor list per layer, aligned
/// with the network's layers (empty for parameter-free layers).
pub fn write_params(w: &mut impl Write, layers: &[Vec<&Tensor>]) -> io::Result<()> {
    let mut buf = Vec::new();
    put_u32(&mut buf, layers.len() as u32);
    for params in layers {
        put_u32(&mut buf, params.len() as u32);
        for p in params {
            put_tensor(&mut buf, p);
        }
    }
    write_frame(w, TAG_PARAMS, &buf)
}

/// Write one gradient-step request: the replica's input shard and the
/// loss head it should evaluate.
pub fn write_step(w: &mut impl Write, x: &Tensor, loss: &WireLoss) -> io::Result<()> {
    let mut buf = Vec::new();
    match loss {
        WireLoss::Mean => buf.push(0),
        WireLoss::SoftmaxXent(targets) => {
            buf.push(1);
            put_u32(&mut buf, targets.len() as u32);
            for &t in targets {
                put_u32(&mut buf, t as u32);
            }
        }
    }
    put_tensor(&mut buf, x);
    write_frame(w, TAG_STEP, &buf)
}

/// Write one layer's streamed gradient upload.
pub fn write_grad(w: &mut impl Write, layer: u32, grads: &[Tensor]) -> io::Result<()> {
    let mut buf = Vec::new();
    put_u32(&mut buf, layer);
    put_u32(&mut buf, grads.len() as u32);
    for g in grads {
        put_tensor(&mut buf, g);
    }
    write_frame(w, TAG_GRAD, &buf)
}

/// Write the step-completion record carrying the shard-local loss.
pub fn write_step_done(w: &mut impl Write, loss: f32) -> io::Result<()> {
    write_frame(w, TAG_STEP_DONE, &loss.to_le_bytes())
}

/// Write a clean worker-side failure report.
pub fn write_error(w: &mut impl Write, message: &str) -> io::Result<()> {
    write_frame(w, TAG_ERROR, message.as_bytes())
}

/// Write the shutdown request that ends a worker's serve loop.
pub fn write_shutdown(w: &mut impl Write) -> io::Result<()> {
    write_frame(w, TAG_SHUTDOWN, &[])
}

/// Write a liveness heartbeat (worker → coordinator, mid-compute).
pub fn write_heartbeat(w: &mut impl Write) -> io::Result<()> {
    write_frame(w, TAG_HEARTBEAT, &[])
}

/// Write one step's telemetry piggyback: counter deltas and histogram
/// observations under their flat registry keys. f64 values travel as
/// raw bits, so NaN/±inf observations survive the trip unchanged.
pub fn write_metrics(
    w: &mut impl Write,
    counters: &[(String, u64)],
    observations: &[(String, f64)],
) -> io::Result<()> {
    let mut buf = Vec::new();
    put_u32(&mut buf, counters.len() as u32);
    for (name, delta) in counters {
        put_str(&mut buf, name);
        buf.extend_from_slice(&delta.to_le_bytes());
    }
    put_u32(&mut buf, observations.len() as u32);
    for (name, v) in observations {
        put_str(&mut buf, name);
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    write_frame(w, TAG_METRICS, &buf)
}

// ----- resumable (deadline-aware) frame reading ------------------------------

/// Outcome of one [`FrameReader::poll_frame`] call.
#[derive(Debug)]
pub enum FramePoll {
    /// A complete frame arrived: `(tag, payload)`. Decode it with
    /// [`decode_frame`].
    Frame(u8, Vec<u8>),
    /// The read timed out before the frame completed. `progressed` is
    /// true when at least one new byte arrived during this call — a slow
    /// large frame in flight, not a silent peer — so supervisors reset
    /// their liveness clock on progress, not only on whole frames.
    Pending {
        /// Whether any bytes arrived this call.
        progressed: bool,
    },
}

/// An incremental frame reader for sockets with a read timeout.
///
/// `Read::read_exact` is unusable under read timeouts: a timeout
/// mid-frame loses the bytes already consumed and desyncs the stream.
/// `FrameReader` retains partial header/payload progress across
/// `WouldBlock`/`TimedOut` returns, so a supervisor can poll a
/// connection on a short timeout — checking heartbeat grace and step
/// deadlines between polls — without ever corrupting the framing.
pub struct FrameReader {
    head: [u8; 5],
    head_got: usize,
    payload: Vec<u8>,
    payload_got: usize,
    in_payload: bool,
}

impl Default for FrameReader {
    fn default() -> FrameReader {
        FrameReader::new()
    }
}

impl FrameReader {
    /// A reader positioned at a frame boundary.
    pub fn new() -> FrameReader {
        FrameReader {
            head: [0u8; 5],
            head_got: 0,
            payload: Vec::new(),
            payload_got: 0,
            in_payload: false,
        }
    }

    /// Whether a partially received frame is in flight (the stream must
    /// not be abandoned at a non-boundary if it is to be reused).
    pub fn mid_frame(&self) -> bool {
        self.head_got > 0 || self.in_payload
    }

    /// Drive the frame forward with whatever bytes `r` can deliver
    /// before its read timeout. Returns [`FramePoll::Frame`] when a
    /// frame completes, [`FramePoll::Pending`] on timeout (progress
    /// retained for the next call). EOF and oversized lengths are
    /// errors naming `peer`.
    pub fn poll_frame(&mut self, r: &mut impl Read, peer: &str) -> io::Result<FramePoll> {
        let mut progressed = false;
        loop {
            if !self.in_payload {
                while self.head_got < 5 {
                    match r.read(&mut self.head[self.head_got..]) {
                        Ok(0) => {
                            return Err(io::Error::new(
                                io::ErrorKind::UnexpectedEof,
                                format!("{peer}: connection closed mid-stream"),
                            ))
                        }
                        Ok(n) => {
                            self.head_got += n;
                            progressed = true;
                        }
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                        Err(e)
                            if e.kind() == io::ErrorKind::WouldBlock
                                || e.kind() == io::ErrorKind::TimedOut =>
                        {
                            return Ok(FramePoll::Pending { progressed })
                        }
                        Err(e) => return Err(e),
                    }
                }
                let tag = self.head[0];
                let len =
                    u32::from_le_bytes([self.head[1], self.head[2], self.head[3], self.head[4]]);
                if len > MAX_FRAME {
                    return Err(oversized(peer, tag, len));
                }
                self.payload = vec![0u8; len as usize];
                self.payload_got = 0;
                self.in_payload = true;
            }
            while self.payload_got < self.payload.len() {
                match r.read(&mut self.payload[self.payload_got..]) {
                    Ok(0) => {
                        return Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            format!(
                                "{peer}: connection closed mid-frame (tag {}, {} of {} bytes)",
                                self.head[0],
                                self.payload_got,
                                self.payload.len()
                            ),
                        ))
                    }
                    Ok(n) => {
                        self.payload_got += n;
                        progressed = true;
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        return Ok(FramePoll::Pending { progressed })
                    }
                    Err(e) => return Err(e),
                }
            }
            let tag = self.head[0];
            let payload = std::mem::take(&mut self.payload);
            self.head_got = 0;
            self.payload_got = 0;
            self.in_payload = false;
            return Ok(FramePoll::Frame(tag, payload));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(write: impl FnOnce(&mut Vec<u8>)) -> Msg {
        let mut buf = Vec::new();
        write(&mut buf);
        let mut r = buf.as_slice();
        let msg = read_msg(&mut r).expect("decode");
        assert!(r.is_empty(), "frame fully consumed");
        msg
    }

    #[test]
    fn hello_roundtrip() {
        match roundtrip(|w| write_hello(w, 7).unwrap()) {
            Msg::Hello { version, replica } => {
                assert_eq!(version, WIRE_VERSION);
                assert_eq!(replica, 7);
            }
            other => panic!("wrong msg {other:?}"),
        }
    }

    #[test]
    fn params_roundtrip_exact_bits() {
        let t1 = Tensor::from_vec(vec![1.5, -0.0, f32::MIN_POSITIVE], &[3]);
        let t2 = Tensor::from_vec(vec![4.0; 6], &[2, 3]);
        let layers: Vec<Vec<&Tensor>> = vec![vec![&t1, &t2], vec![]];
        match roundtrip(|w| write_params(w, &layers).unwrap()) {
            Msg::Params { layers } => {
                assert_eq!(layers.len(), 2);
                assert_eq!(layers[0].len(), 2);
                assert!(layers[1].is_empty());
                assert_eq!(layers[0][0].shape(), &[3]);
                for (a, b) in layers[0][0].data().iter().zip(t1.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bit-exact payload");
                }
                assert_eq!(layers[0][1].shape(), &[2, 3]);
            }
            other => panic!("wrong msg {other:?}"),
        }
    }

    #[test]
    fn step_roundtrip_with_losses() {
        let x = Tensor::from_vec(vec![0.25; 8], &[2, 4]);
        for loss in [WireLoss::Mean, WireLoss::SoftmaxXent(vec![0, 3, 1])] {
            match roundtrip(|w| write_step(w, &x, &loss).unwrap()) {
                Msg::Step { x: got, loss: gl } => {
                    assert_eq!(got.shape(), x.shape());
                    assert_eq!(got.data(), x.data());
                    assert_eq!(gl, loss);
                }
                other => panic!("wrong msg {other:?}"),
            }
        }
    }

    #[test]
    fn grad_and_done_roundtrip() {
        let g = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        match roundtrip(|w| write_grad(w, 5, std::slice::from_ref(&g)).unwrap()) {
            Msg::Grad { layer, grads } => {
                assert_eq!(layer, 5);
                assert_eq!(grads.len(), 1);
                assert_eq!(grads[0].data(), g.data());
            }
            other => panic!("wrong msg {other:?}"),
        }
        match roundtrip(|w| write_step_done(w, -0.5).unwrap()) {
            Msg::StepDone { loss } => assert_eq!(loss, -0.5),
            other => panic!("wrong msg {other:?}"),
        }
    }

    #[test]
    fn error_and_shutdown_roundtrip() {
        match roundtrip(|w| write_error(w, "boom").unwrap()) {
            Msg::Error { message } => assert_eq!(message, "boom"),
            other => panic!("wrong msg {other:?}"),
        }
        assert!(matches!(
            roundtrip(|w| write_shutdown(w).unwrap()),
            Msg::Shutdown
        ));
    }

    #[test]
    fn truncated_and_oversized_frames_rejected() {
        let mut buf = Vec::new();
        write_hello(&mut buf, 1).unwrap();
        buf.pop(); // truncate
        assert!(read_msg(&mut buf.as_slice()).is_err());
        // Oversized length prefix.
        let bad = [TAG_GRAD, 0xff, 0xff, 0xff, 0xff];
        assert!(read_msg(&mut bad.as_slice()).is_err());
        // Unknown tag.
        let unk = [99u8, 0, 0, 0, 0];
        assert!(read_msg(&mut unk.as_slice()).is_err());
    }

    #[test]
    fn heartbeat_roundtrip() {
        assert!(matches!(
            roundtrip(|w| write_heartbeat(w).unwrap()),
            Msg::Heartbeat
        ));
    }

    #[test]
    fn metrics_roundtrip_exact_bits() {
        let counters = vec![
            ("engine.steps".to_string(), 1u64),
            ("arena.hits".to_string(), u64::MAX),
        ];
        let observations = vec![
            ("step.seconds".to_string(), 0.012345),
            ("weird.values".to_string(), f64::NAN),
            ("more.weird".to_string(), f64::NEG_INFINITY),
        ];
        match roundtrip(|w| write_metrics(w, &counters, &observations).unwrap()) {
            Msg::Metrics {
                counters: gc,
                observations: go,
            } => {
                assert_eq!(gc, counters);
                assert_eq!(go.len(), observations.len());
                for ((gn, gv), (n, v)) in go.iter().zip(&observations) {
                    assert_eq!(gn, n);
                    assert_eq!(gv.to_bits(), v.to_bits(), "f64 bits survive the wire");
                }
            }
            other => panic!("wrong msg {other:?}"),
        }
        // Empty piggyback is legal (a step with nothing to report).
        match roundtrip(|w| write_metrics(w, &[], &[]).unwrap()) {
            Msg::Metrics {
                counters,
                observations,
            } => {
                assert!(counters.is_empty());
                assert!(observations.is_empty());
            }
            other => panic!("wrong msg {other:?}"),
        }
    }

    #[test]
    fn errors_name_peer_tag_and_length() {
        // Oversized length prefix: the error must name the connection,
        // the frame tag and the offending length.
        let bad = [TAG_GRAD, 0xff, 0xff, 0xff, 0xff];
        let err = read_msg_from(&mut bad.as_slice(), "replica 3 (tcp)").unwrap_err();
        let text = err.to_string();
        assert!(text.contains("replica 3 (tcp)"), "names the peer: {text}");
        assert!(text.contains("tag 5"), "names the tag: {text}");
        assert!(text.contains("4294967295 bytes"), "names the length: {text}");
        // Corrupt payload: decode errors carry the same context.
        let err = decode_frame(TAG_STEP_DONE, &[1, 2], "replica 0 (unix)").unwrap_err();
        let text = err.to_string();
        assert!(text.contains("replica 0 (unix)"), "names the peer: {text}");
        assert!(text.contains("tag 6"), "names the tag: {text}");
        assert!(text.contains("2 bytes"), "names the length: {text}");
        // Unknown tag through the same labeled path.
        let err = decode_frame(0xEE, &[], "replica 1 (unix)").unwrap_err();
        assert!(err.to_string().contains("replica 1 (unix)"));
        assert!(err.to_string().contains("unknown wire tag"));
    }

    /// A reader that yields at most `chunk` bytes per call and returns
    /// `WouldBlock` every other call — the worst-case trickle a read
    /// timeout produces.
    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
        block_next: bool,
    }

    impl<'a> std::io::Read for Trickle<'a> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.block_next {
                self.block_next = false;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WouldBlock,
                    "trickle",
                ));
            }
            self.block_next = true;
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn frame_reader_survives_timeouts_mid_frame() {
        // Two frames back to back, delivered one byte at a time with a
        // timeout between every byte: the resumable reader must retain
        // partial progress and decode both bit-exactly.
        let mut buf = Vec::new();
        let g = Tensor::from_vec(vec![1.5, -0.0, 42.0], &[3]);
        write_grad(&mut buf, 7, std::slice::from_ref(&g)).unwrap();
        write_step_done(&mut buf, 0.25).unwrap();
        let mut src = Trickle {
            data: &buf,
            pos: 0,
            chunk: 1,
            block_next: false,
        };
        let mut fr = FrameReader::new();
        let mut msgs = Vec::new();
        let mut progressed_any = false;
        while msgs.len() < 2 {
            match fr.poll_frame(&mut src, "replica 0 (test)").unwrap() {
                FramePoll::Frame(tag, payload) => {
                    msgs.push(decode_frame(tag, &payload, "replica 0 (test)").unwrap());
                }
                FramePoll::Pending { progressed } => progressed_any |= progressed,
            }
        }
        assert!(progressed_any, "trickle must report byte progress");
        assert!(!fr.mid_frame(), "reader parked at a frame boundary");
        match &msgs[0] {
            Msg::Grad { layer, grads } => {
                assert_eq!(*layer, 7);
                assert_eq!(grads[0].data()[0].to_bits(), 1.5f32.to_bits());
                assert_eq!(grads[0].data()[1].to_bits(), (-0.0f32).to_bits());
            }
            other => panic!("wrong msg {other:?}"),
        }
        assert!(matches!(msgs[1], Msg::StepDone { loss } if loss == 0.25));
    }

    #[test]
    fn frame_reader_reports_idle_timeouts() {
        let mut src = Trickle {
            data: &[],
            pos: 0,
            chunk: 1,
            block_next: true,
        };
        let mut fr = FrameReader::new();
        match fr.poll_frame(&mut src, "replica 0 (test)").unwrap() {
            FramePoll::Pending { progressed } => assert!(!progressed),
            other => panic!("expected pending, got {other:?}"),
        }
        assert!(!fr.mid_frame());
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        // Rank-0 tensors must survive (shape [], one element).
        let x = Tensor::scalar(2.5);
        match roundtrip(|w| write_step(w, &x, &WireLoss::Mean).unwrap()) {
            Msg::Step { x: got, .. } => {
                assert_eq!(got.rank(), 0);
                assert_eq!(got.item(), 2.5);
            }
            other => panic!("wrong msg {other:?}"),
        }
    }
}
