//! The shared socket coordinator behind [`UnixTransport`] and
//! [`TcpTransport`]: everything about supervising worker subprocesses
//! over a framed stream that does **not** depend on the socket family.
//!
//! [`UnixTransport`]: super::UnixTransport
//! [`TcpTransport`]: super::TcpTransport
//!
//! PR 4's unix transport owned this logic directly; the TCP transport
//! would have duplicated all of it, so it moved here and both transports
//! became thin family adapters. On top of the PR 4 behavior this
//! coordinator adds the supervision layer (see
//! [`supervisor`](super::supervisor)):
//!
//! * **Deadline-bounded reads.** Connections poll on a short read
//!   timeout through a resumable [`FrameReader`], so a hung worker can
//!   no longer block the coordinator forever — byte-silence beyond the
//!   heartbeat grace (or the step deadline, with heartbeats off) fails
//!   the step with an error naming the replica.
//! * **Elastic membership.** The *logical* shard count `R` is fixed at
//!   spawn (it defines the data sharding and the reducer layout), but
//!   the group may execute on any `1 ≤ members ≤ R` live workers:
//!   logical shard `q` runs on connection slot `q % members`, each slot
//!   serving its queue of shards serially. Because shard execution is
//!   stateless between frames and the reducer folds in **logical** shard
//!   order, the reduced gradient is bit-identical for every member
//!   count — degradation and elastic join/leave never perturb training.
//! * **Fault injection.** A [`FaultPlan`] schedules worker-side events
//!   (kill/hang, shipped in the init blob) and coordinator-side events
//!   (drop/delay/corrupt a gradient frame, applied in the reader loop),
//!   keyed deterministically on `(replica slot, global step)`.
//! * **Fleet telemetry (ISSUE 10).** Each worker piggybacks a
//!   [`Msg::Metrics`] frame per step (wire v3); the reader loop folds
//!   its counter deltas and observations into the coordinator registry
//!   under a `replica="<logical shard>"` label, so one `/metrics`
//!   scrape shows every replica — including respawned incarnations,
//!   which keep their logical shard's label. The coordinator also
//!   times each shard wall-clock (dispatch → `StepDone`) into
//!   `transport.step_seconds{replica=…}` and feeds a shared
//!   [`StragglerTracker`]: a shard beyond the configured z-score bumps
//!   `supervisor.stragglers` (total + per-replica) and drops a
//!   `supervisor.straggler` trace instant.

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::distributed::{ReduceOp, ReplicaStep, StreamingAllReduce};
use crate::model::Network;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::lock_ignore_poison as lock;

use super::supervisor::{Deadlines, FaultKind, FaultPlan, StragglerTracker};
use super::unix::EngineSpec;
use super::wire::{self, FramePoll, FrameReader, Msg};
use super::{submit_to_sink, ShardSpec};

/// Which socket family a coordinator speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Family {
    /// Unix-domain sockets (single host).
    Unix,
    /// TCP sockets (multi-host capable).
    Tcp,
}

impl Family {
    fn as_str(self) -> &'static str {
        match self {
            Family::Unix => "unix",
            Family::Tcp => "tcp",
        }
    }
}

/// A stream of either family. All clones share one socket, so timeouts
/// set through any handle govern every handle.
pub(crate) enum SockStream {
    /// A unix-domain stream.
    Unix(UnixStream),
    /// A TCP stream.
    Tcp(TcpStream),
}

impl SockStream {
    pub(crate) fn try_clone(&self) -> io::Result<SockStream> {
        Ok(match self {
            SockStream::Unix(s) => SockStream::Unix(s.try_clone()?),
            SockStream::Tcp(s) => SockStream::Tcp(s.try_clone()?),
        })
    }

    fn set_nonblocking(&self, v: bool) -> io::Result<()> {
        match self {
            SockStream::Unix(s) => s.set_nonblocking(v),
            SockStream::Tcp(s) => s.set_nonblocking(v),
        }
    }

    pub(crate) fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            SockStream::Unix(s) => s.set_read_timeout(t),
            SockStream::Tcp(s) => s.set_read_timeout(t),
        }
    }

    fn set_write_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            SockStream::Unix(s) => s.set_write_timeout(t),
            SockStream::Tcp(s) => s.set_write_timeout(t),
        }
    }
}

impl Read for SockStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            SockStream::Unix(s) => s.read(buf),
            SockStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for SockStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            SockStream::Unix(s) => s.write(buf),
            SockStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            SockStream::Unix(s) => s.flush(),
            SockStream::Tcp(s) => s.flush(),
        }
    }
}

/// A listener of either family.
enum SockListener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl SockListener {
    fn set_nonblocking(&self, v: bool) -> io::Result<()> {
        match self {
            SockListener::Unix(l) => l.set_nonblocking(v),
            SockListener::Tcp(l) => l.set_nonblocking(v),
        }
    }

    fn accept(&self) -> io::Result<SockStream> {
        match self {
            SockListener::Unix(l) => l.accept().map(|(s, _)| SockStream::Unix(s)),
            SockListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                // Gradient frames are small and latency-sensitive;
                // Nagle batching would serialize the streamed reduce.
                s.set_nodelay(true)?;
                Ok(SockStream::Tcp(s))
            }
        }
    }
}

/// Family-specific construction input for [`SocketCoordinator::spawn`].
pub(crate) enum Endpoint {
    /// Bind a unix socket under `socket_dir` (`None` = fresh temp dir).
    Unix {
        /// Directory for the coordinator socket.
        socket_dir: Option<PathBuf>,
    },
    /// Bind a TCP listener on `listen`; the **last** `remote_workers`
    /// replica slots are not spawned locally — standalone workers
    /// (`--replica-worker --connect-tcp`) are expected to dial in.
    Tcp {
        /// Bind address, e.g. `127.0.0.1:0`.
        listen: String,
        /// How many replica slots expect external workers.
        remote_workers: usize,
    },
}

/// Family-independent construction options for [`SocketCoordinator`].
pub(crate) struct SocketOpts {
    /// Logical replica (shard) count — fixed for the group's lifetime.
    pub replicas: usize,
    /// JSON text of the worker network config.
    pub config_json: String,
    /// Engine each worker runs.
    pub engine: EngineSpec,
    /// Worker pool threads (keep 1 for bit-equality with local).
    pub threads_per_worker: usize,
    /// Worker executable; `None` re-invokes the current binary.
    pub worker_bin: Option<PathBuf>,
    /// Timing knobs for every connection.
    pub deadlines: Deadlines,
    /// Scheduled fault injections (empty in production).
    pub faults: FaultPlan,
}

/// One live worker connection: optional subprocess handle (external TCP
/// workers have none), buffered reader/writer clones of one socket
/// (timeouts set on either govern both), and the resumable frame
/// decoder that survives poll timeouts mid-frame.
struct WorkerConn {
    child: Option<Child>,
    reader: BufReader<SockStream>,
    writer: BufWriter<SockStream>,
    frame: FrameReader,
}

impl WorkerConn {
    fn kill(&mut self) {
        if let Some(child) = self.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Distinguishes "the worker process/connection is gone" (reset + respawn
/// on next broadcast) from a clean worker-side step error (worker fine).
struct StepFailure {
    fatal: bool,
    err: anyhow::Error,
}

static SOCKET_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// The shared multi-process coordinator (see module docs). Both public
/// transports deref their behavior onto this type.
pub(crate) struct SocketCoordinator {
    config_json: String,
    engine: EngineSpec,
    threads_per_worker: usize,
    worker_bin: Option<PathBuf>,
    deadlines: Deadlines,
    faults: Mutex<FaultPlan>,
    listener: SockListener,
    family: Family,
    /// What spawned workers pass to `--connect`/`--connect-tcp`.
    connect_arg: String,
    socket_path: Option<PathBuf>,
    /// `(dir, created_by_us)` for unix-socket cleanup.
    socket_dir: Option<(PathBuf, bool)>,
    /// External (non-spawned) worker slots: the last `remote` of `R`.
    remote: usize,
    conns: Vec<Option<WorkerConn>>,
    members: usize,
    synced: bool,
    step_idx: usize,
    /// Streaming step-time moments for straggler detection, shared by
    /// the per-slot reader threads (each records its shard's wall time
    /// as `StepDone` arrives).
    stragglers: Mutex<StragglerTracker>,
}

impl SocketCoordinator {
    /// Bind the listener, spawn local workers, and complete the
    /// handshake + init exchange with every replica slot.
    pub(crate) fn spawn(opts: SocketOpts, endpoint: Endpoint) -> anyhow::Result<SocketCoordinator> {
        anyhow::ensure!(opts.replicas >= 1, "replica count must be >= 1");
        // Validate the config JSON up front: a worker failing to parse it
        // would otherwise surface as an opaque exit.
        Json::parse(&opts.config_json)
            .map_err(|e| anyhow::anyhow!("invalid worker config JSON: {e}"))?;
        let (listener, family, connect_arg, socket_path, socket_dir, remote) = match endpoint {
            Endpoint::Unix { socket_dir } => {
                let (dir, own) = match socket_dir {
                    Some(d) => (d, false),
                    None => (
                        std::env::temp_dir().join(format!(
                            "moonwalk-unix-{}-{}",
                            std::process::id(),
                            SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed)
                        )),
                        true,
                    ),
                };
                std::fs::create_dir_all(&dir)?;
                let path = dir.join("coordinator.sock");
                // A stale socket file from a crashed previous run blocks
                // bind.
                let _ = std::fs::remove_file(&path);
                let listener = SockListener::Unix(UnixListener::bind(&path)?);
                let arg = path.to_string_lossy().into_owned();
                (listener, Family::Unix, arg, Some(path), Some((dir, own)), 0)
            }
            Endpoint::Tcp {
                listen,
                remote_workers,
            } => {
                anyhow::ensure!(
                    remote_workers <= opts.replicas,
                    "{remote_workers} remote workers exceed {} replicas",
                    opts.replicas
                );
                let listener = TcpListener::bind(&listen)
                    .map_err(|e| anyhow::anyhow!("binding tcp listener on {listen}: {e}"))?;
                // Bind may have been to port 0; workers (and the user,
                // for external ones) need the resolved address.
                let arg = listener.local_addr()?.to_string();
                (
                    SockListener::Tcp(listener),
                    Family::Tcp,
                    arg,
                    None,
                    None,
                    remote_workers,
                )
            }
        };
        listener.set_nonblocking(true)?;
        let replicas = opts.replicas;
        let mut coord = SocketCoordinator {
            config_json: opts.config_json,
            engine: opts.engine,
            threads_per_worker: opts.threads_per_worker,
            worker_bin: opts.worker_bin,
            deadlines: opts.deadlines,
            faults: Mutex::new(opts.faults),
            listener,
            family,
            connect_arg,
            socket_path,
            socket_dir,
            remote,
            conns: (0..replicas).map(|_| None).collect(),
            members: replicas,
            synced: false,
            step_idx: 0,
            stragglers: Mutex::new(StragglerTracker::new()),
        };
        let all: Vec<usize> = (0..replicas).collect();
        coord.establish(&all)?;
        Ok(coord)
    }

    /// Human-readable family name (`"unix"` / `"tcp"`).
    pub(crate) fn family_name(&self) -> &'static str {
        self.family.as_str()
    }

    /// The address workers connect to: the unix socket path, or the TCP
    /// listener's resolved `host:port` (useful when binding port 0).
    pub(crate) fn connect_addr(&self) -> &str {
        &self.connect_arg
    }

    /// Fixed logical shard count `R`.
    pub(crate) fn replicas(&self) -> usize {
        self.conns.len()
    }

    /// Live executor count `members ≤ R`.
    pub(crate) fn members(&self) -> usize {
        self.members
    }

    /// The resolved heartbeat interval (ms); 0 = disabled.
    pub(crate) fn heartbeat_ms(&self) -> u64 {
        self.deadlines.heartbeat_ms
    }

    /// Replace the fault schedule (tests and the bench harness inject
    /// plans after spawn).
    pub(crate) fn set_fault_plan(&mut self, plan: FaultPlan) {
        *lock(&self.faults) = plan;
    }

    /// Resize the executor set to `members` live workers (logical shard
    /// count unchanged — see module docs for the bit-identity argument).
    /// Shrinking kills the excess workers; growing marks the new slots
    /// for respawn. Either way the group needs a re-broadcast.
    pub(crate) fn set_members(&mut self, members: usize) -> anyhow::Result<()> {
        let replicas = self.conns.len();
        anyhow::ensure!(
            members >= 1 && members <= replicas,
            "member count {members} out of range 1..={replicas}"
        );
        if members == self.members {
            return Ok(());
        }
        for slot in members..self.members {
            if let Some(mut conn) = self.conns[slot].take() {
                conn.kill();
            }
        }
        crate::log_warn!(
            "transport membership now {members}/{replicas} worker(s); re-broadcast to resume"
        );
        self.members = members;
        self.synced = false;
        Ok(())
    }

    /// The worker executable to launch.
    fn bin(&self) -> anyhow::Result<PathBuf> {
        match &self.worker_bin {
            Some(p) => Ok(p.clone()),
            None => Ok(std::env::current_exe()?),
        }
    }

    /// Whether a replica slot expects an external (non-spawned) worker.
    fn is_external(&self, replica: usize) -> bool {
        self.remote > 0 && replica >= self.conns.len() - self.remote
    }

    /// The init blob for one fresh worker: config + engine + runtime
    /// knobs + its armed worker-side fault events.
    fn init_json(&self, replica: usize) -> String {
        let config = Json::parse(&self.config_json).expect("validated at spawn");
        let armed: Vec<Json> = lock(&self.faults)
            .arm_worker(replica)
            .into_iter()
            .map(|e| {
                let mut pairs = vec![("kind", Json::from(e.kind.label()))];
                match e.step {
                    Some(s) => pairs.push(("step", s.into())),
                    None => pairs.push(("every", true.into())),
                }
                Json::from_pairs(pairs)
            })
            .collect();
        Json::from_pairs(vec![
            ("config", config),
            (
                "engine",
                Json::from_pairs(vec![
                    ("name", self.engine.name.as_str().into()),
                    ("block", self.engine.block.into()),
                    ("checkpoint_segments", self.engine.checkpoint_segments.into()),
                    ("seed", (self.engine.seed as usize).into()),
                ]),
            ),
            ("threads", self.threads_per_worker.max(1).into()),
            (
                "heartbeat_ms",
                (self.deadlines.heartbeat_ms as usize).into(),
            ),
            ("faults", Json::Arr(armed)),
        ])
        .to_string()
    }

    /// Spawn (or, for external slots, await) the given replicas'
    /// workers, accept their handshakes and send each its init blob.
    fn establish(&mut self, replicas: &[usize]) -> anyhow::Result<()> {
        if replicas.is_empty() {
            return Ok(());
        }
        let mut pending: HashMap<usize, Option<Child>> = HashMap::new();
        for &r in replicas {
            anyhow::ensure!(
                self.conns[r].is_none(),
                "replica {r} already has a live worker"
            );
            if self.is_external(r) {
                // A standalone worker must dial in within the accept
                // deadline: moonwalk --replica-worker --connect-tcp ...
                pending.insert(r, None);
                continue;
            }
            let bin = self.bin()?;
            let mut cmd = Command::new(&bin);
            cmd.arg("--replica-worker");
            match self.family {
                Family::Unix => cmd.arg("--connect").arg(&self.connect_arg),
                Family::Tcp => cmd.arg("--connect-tcp").arg(&self.connect_arg),
            };
            let child = cmd
                .arg("--replica")
                .arg(r.to_string())
                .stdin(Stdio::null())
                .spawn()
                .map_err(|e| anyhow::anyhow!("spawning worker for replica {r}: {e}"))?;
            pending.insert(r, Some(child));
        }
        let deadline = Instant::now() + self.deadlines.accept;
        while !pending.is_empty() {
            match self.listener.accept() {
                Ok(stream) => {
                    stream.set_nonblocking(false)?;
                    // Bound the handshake read: a peer that connects but
                    // never sends its hello must not wedge the accept
                    // loop. The write timeout stays for the connection's
                    // whole life — a hung worker must not block param
                    // uploads forever either.
                    stream.set_read_timeout(Some(self.deadlines.hello))?;
                    stream.set_write_timeout(Some(self.deadlines.accept))?;
                    let mut reader = BufReader::new(stream.try_clone()?);
                    let (version, replica) =
                        match wire::read_msg_from(&mut reader, "connecting peer") {
                            Ok(Msg::Hello { version, replica }) => (version, replica as usize),
                            Ok(other) => anyhow::bail!("expected worker hello, got {other:?}"),
                            Err(e) => anyhow::bail!("peer connected but sent no hello: {e}"),
                        };
                    anyhow::ensure!(
                        version == wire::WIRE_VERSION,
                        "worker speaks wire version {version}, coordinator {}",
                        wire::WIRE_VERSION
                    );
                    anyhow::ensure!(
                        replica < self.conns.len(),
                        "hello from replica {replica}, but the group has {} slots",
                        self.conns.len()
                    );
                    let child = pending
                        .remove(&replica)
                        .ok_or_else(|| anyhow::anyhow!("unexpected hello from replica {replica}"))?;
                    let mut writer = BufWriter::new(stream.try_clone()?);
                    wire::write_init(&mut writer, &self.init_json(replica))?;
                    writer.flush()?;
                    // Step-loop reads poll on a short timeout and resume
                    // through the FrameReader; liveness is enforced by
                    // heartbeat grace and the step deadline, not here.
                    stream.set_read_timeout(Some(self.deadlines.poll()))?;
                    self.conns[replica] = Some(WorkerConn {
                        child,
                        reader,
                        writer,
                        frame: FrameReader::new(),
                    });
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // While waiting, surface a worker that died before
                    // connecting (bad binary, immediate crash) instead of
                    // timing out opaquely.
                    for (&r, child) in pending.iter_mut() {
                        if let Some(child) = child.as_mut() {
                            if let Ok(Some(status)) = child.try_wait() {
                                anyhow::bail!(
                                    "replica {r} worker exited with {status} before connecting"
                                );
                            }
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "timed out after {:.0?} waiting for {} worker(s) to connect \
                         (accept deadline; --accept-timeout / MOONWALK_ACCEPT_TIMEOUT)",
                        self.deadlines.accept,
                        pending.len()
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Active member slots whose worker is currently down.
    fn dead(&self) -> Vec<usize> {
        (0..self.members)
            .filter(|&s| self.conns[s].is_none())
            .collect()
    }

    /// Send the full parameter set to one replica slot.
    fn send_params(&mut self, r: usize, layers: &[Vec<&Tensor>]) -> io::Result<()> {
        let conn = self.conns[r].as_mut().expect("caller checked liveness");
        wire::write_params(&mut conn.writer, layers)?;
        conn.writer.flush()
    }

    /// Kill one worker — fault injection for the worker-death
    /// recovery tests. The next broadcast respawns it.
    pub(crate) fn kill_worker(&mut self, replica: usize) -> anyhow::Result<()> {
        anyhow::ensure!(replica < self.conns.len(), "replica {replica} out of range");
        if let Some(mut conn) = self.conns[replica].take() {
            conn.kill();
        }
        self.synced = false;
        Ok(())
    }

    /// Kill one worker **without** marking it dead — mimics an unnoticed
    /// crash discovered only when the next step's I/O hits EOF.
    pub(crate) fn simulate_worker_crash(&mut self, replica: usize) -> anyhow::Result<()> {
        anyhow::ensure!(replica < self.conns.len(), "replica {replica} out of range");
        if let Some(conn) = self.conns[replica].as_mut() {
            anyhow::ensure!(
                conn.child.is_some(),
                "replica {replica} is an external worker; cannot kill its process"
            );
            conn.kill();
        }
        Ok(())
    }

    /// Tear down every worker and mark the group unsynced — the
    /// whole-group reset after any fatal step failure (surviving workers
    /// may hold half an aborted step in their socket buffers).
    pub(crate) fn reset_workers(&mut self) {
        for slot in self.conns.iter_mut() {
            if let Some(mut conn) = slot.take() {
                conn.kill();
            }
        }
        self.synced = false;
    }

    /// Worker subprocess ids, `None` for dead slots and external workers.
    pub(crate) fn worker_ids(&self) -> Vec<Option<u32>> {
        self.conns
            .iter()
            .map(|c| c.as_ref().and_then(|c| c.child.as_ref().map(|ch| ch.id())))
            .collect()
    }

    /// Respawn dead members and upload the parameter set to every live
    /// member; one retry per slot covers a worker that died between the
    /// liveness check and the write.
    pub(crate) fn broadcast(&mut self, net: &Network) -> anyhow::Result<()> {
        let dead = self.dead();
        if !dead.is_empty() {
            crate::obs::metrics::counter_add("supervisor.respawns", dead.len() as u64);
            crate::obs::span::instant(
                "supervisor.respawn",
                Some(("workers", dead.len() as i64)),
            );
        }
        self.establish(&dead)?;
        let layers: Vec<Vec<&Tensor>> = net.layers.iter().map(|l| l.params()).collect();
        for r in 0..self.members {
            if self.send_params(r, &layers).is_err() {
                // The worker is gone: reap it, respawn, resend once.
                if let Some(mut conn) = self.conns[r].take() {
                    conn.kill();
                }
                crate::obs::metrics::counter_add("supervisor.respawns", 1);
                crate::obs::span::instant("supervisor.respawn", Some(("workers", 1)));
                self.establish(&[r])
                    .map_err(|e| e.context(format!("respawning replica {r} mid-broadcast")))?;
                self.send_params(r, &layers)
                    .map_err(|e| anyhow::anyhow!("replica {r}: param upload failed twice: {e}"))?;
            }
        }
        self.synced = true;
        Ok(())
    }

    /// One supervised replicated step over `shards` (see module docs for
    /// the logical-shard → member mapping).
    pub(crate) fn step(
        &mut self,
        net: &Network,
        shards: &[ShardSpec<'_>],
        op: ReduceOp,
        sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    ) -> anyhow::Result<ReplicaStep> {
        let replicas = self.conns.len();
        anyhow::ensure!(
            shards.len() == replicas,
            "group has {replicas} replicas but {} shards were supplied",
            shards.len()
        );
        anyhow::ensure!(
            self.synced,
            "parameters were never broadcast to the workers (call broadcast \
             after construction and after every parameter update or step error)"
        );
        let members = self.members;
        let step_idx = self.step_idx;
        self.step_idx += 1;
        // Pull this step's coordinator-side faults up front (one lock,
        // deterministic order) before the reader threads start.
        let slot_faults: Vec<Option<FaultKind>> = {
            let mut plan = lock(&self.faults);
            (0..members).map(|s| plan.take_coord(s, step_idx)).collect()
        };
        // The reducer is rebuilt per step (bucket-fused exactly like the
        // local transport's) so a failed attempt's partial deliveries
        // are discarded wholesale — the retry starts from a clean fold.
        let reducer = super::reducer_for(net, replicas, op);
        let losses: Mutex<Vec<Option<f32>>> = Mutex::new(vec![None; replicas]);
        let family = self.family;
        let dl = self.deadlines;
        let outcomes: Vec<Result<(), StepFailure>> = std::thread::scope(|scope| {
            let reducer = &reducer;
            let losses = &losses;
            let stragglers = &self.stragglers;
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .take(members)
                .enumerate()
                .map(|(slot, conn_slot)| {
                    let conn = conn_slot.as_mut().expect("synced implies alive");
                    // Slot `s` serially executes logical shards s, s+M,
                    // s+2M, … — the full set at M = R, a longer queue as
                    // the group degrades.
                    let queue: Vec<usize> = (slot..replicas).step_by(members).collect();
                    let fault = slot_faults[slot];
                    scope.spawn(move || {
                        drive_slot(
                            conn, &queue, shards, reducer, losses, stragglers, sink, dl, fault,
                            family,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(StepFailure {
                            fatal: true,
                            err: anyhow::anyhow!("transport reader thread panicked"),
                        })
                    })
                })
                .collect()
        });
        let mut first_err: Option<anyhow::Error> = None;
        let mut any_fatal = false;
        for outcome in outcomes {
            if let Err(f) = outcome {
                any_fatal |= f.fatal;
                if first_err.is_none() {
                    first_err = Some(f.err);
                }
            }
        }
        // The partial-delivery guard: every slot reported success, yet
        // the reducer still holds unfinished layers — a gradient frame
        // was lost in flight (e.g. the drop-frame fault). Silently
        // continuing would skip those layers' optimizer update.
        if first_err.is_none() {
            let pending = reducer.pending_layers();
            if pending > 0 {
                any_fatal = true;
                first_err = Some(anyhow::anyhow!(
                    "step {step_idx} completed but {pending} layer reduction(s) never \
                     finished (a gradient frame was lost); discarding partial deliveries"
                ));
            }
        }
        if let Some(e) = first_err {
            if any_fatal {
                // Surviving workers completed, but a fatal peer means the
                // step is torn; reset so the next broadcast rebuilds a
                // clean group. Clean (non-fatal) engine errors leave
                // workers parked at a frame boundary — no reset needed.
                self.reset_workers();
            }
            return Err(e);
        }
        let replica_losses: Vec<f32> = lock(&losses)
            .iter()
            .map(|l| l.expect("all slots succeeded"))
            .collect();
        let loss = replica_losses.iter().sum::<f32>() / replica_losses.len() as f32;
        Ok(ReplicaStep {
            loss,
            replica_losses,
            reduce_s: reducer.reduce_seconds(),
        })
    }
}

/// Drive one connection slot through its queue of logical shards:
/// dispatch a shard, drain its gradient stream through the resumable
/// frame reader under heartbeat-grace and step-deadline supervision,
/// then move to the next queued shard. Telemetry side effects per
/// shard: the worker's piggybacked [`Msg::Metrics`] deltas fold into
/// `replica="q"`-labeled series, and the dispatch → `StepDone` wall
/// time feeds `transport.step_seconds{replica=…}` plus the shared
/// straggler tracker.
#[allow(clippy::too_many_arguments)]
fn drive_slot(
    conn: &mut WorkerConn,
    queue: &[usize],
    shards: &[ShardSpec<'_>],
    reducer: &StreamingAllReduce,
    losses: &Mutex<Vec<Option<f32>>>,
    stragglers: &Mutex<StragglerTracker>,
    sink: &(dyn Fn(usize, Vec<Tensor>) + Sync),
    dl: Deadlines,
    mut fault: Option<FaultKind>,
    family: Family,
) -> Result<(), StepFailure> {
    for &q in queue {
        let peer = format!("replica {q} ({})", family.as_str());
        let shard = &shards[q];
        wire::write_step(&mut conn.writer, shard.x, &shard.loss.to_wire())
            .and_then(|_| conn.writer.flush())
            .map_err(|e| StepFailure {
                fatal: true,
                err: anyhow::anyhow!("{peer} worker died during step dispatch: {e}"),
            })?;
        let started = Instant::now();
        let mut last_activity = Instant::now();
        loop {
            match conn.frame.poll_frame(&mut conn.reader, &peer) {
                Ok(FramePoll::Frame(mut tag, payload)) => {
                    last_activity = Instant::now();
                    if tag == wire::TAG_GRAD {
                        // Coordinator-side fault injection targets the
                        // slot's first *gradient* frame of the step (a
                        // deterministic anchor; heartbeats don't count).
                        match fault.take() {
                            Some(FaultKind::DropFrame) => {
                                crate::log_warn!(
                                    "fault injection: dropping a gradient frame from {peer}"
                                );
                                continue;
                            }
                            Some(FaultKind::DelayFrame(ms)) => {
                                crate::log_warn!(
                                    "fault injection: delaying a gradient frame from {peer} \
                                     by {ms}ms"
                                );
                                std::thread::sleep(Duration::from_millis(ms));
                            }
                            Some(FaultKind::CorruptFrame) => {
                                crate::log_warn!(
                                    "fault injection: corrupting a gradient frame from {peer}"
                                );
                                tag = 0xEE;
                            }
                            _ => {}
                        }
                    }
                    let msg = wire::decode_frame(tag, &payload, &peer).map_err(|e| StepFailure {
                        fatal: true,
                        err: anyhow::anyhow!(e),
                    })?;
                    match msg {
                        Msg::Heartbeat => {}
                        Msg::Grad { layer, grads } => {
                            submit_to_sink(reducer, layer as usize, q, grads, sink);
                        }
                        Msg::Metrics {
                            counters,
                            observations,
                        } => {
                            // Fold the worker's per-step telemetry into
                            // the coordinator registry under the logical
                            // shard's label: one scrape, whole fleet.
                            // with_label merges into any label block the
                            // worker already shipped rather than
                            // appending a second, malformed one.
                            let replica = q.to_string();
                            for (name, delta) in counters {
                                crate::obs::metrics::counter_add(
                                    &crate::obs::metrics::with_label(&name, "replica", &replica),
                                    delta,
                                );
                            }
                            for (name, v) in observations {
                                crate::obs::metrics::observe(
                                    &crate::obs::metrics::with_label(&name, "replica", &replica),
                                    v,
                                );
                            }
                        }
                        Msg::StepDone { loss } => {
                            lock(losses)[q] = Some(loss);
                            let secs = started.elapsed().as_secs_f64();
                            let replica = q.to_string();
                            crate::obs::metrics::observe_labeled(
                                "transport.step_seconds",
                                &[("replica", replica.as_str())],
                                secs,
                            );
                            // One lock covers record + the stats the
                            // warning needs: log_warn! formats eagerly,
                            // so a second lock(stragglers) inside the
                            // same statement would self-deadlock the
                            // non-reentrant mutex.
                            let (flagged, fleet_mean, fleet_samples) = {
                                let mut t = lock(stragglers);
                                (t.record(q, secs), t.mean(), t.samples())
                            };
                            if flagged {
                                crate::obs::metrics::counter_add("supervisor.stragglers", 1);
                                crate::obs::metrics::counter_add_labeled(
                                    "supervisor.stragglers",
                                    &[("replica", replica.as_str())],
                                    1,
                                );
                                crate::obs::span::instant(
                                    "supervisor.straggler",
                                    Some(("replica", q as i64)),
                                );
                                crate::log_warn!(
                                    "straggler: {peer} took {secs:.3}s this step \
                                     (fleet mean {fleet_mean:.3}s over {fleet_samples} samples)"
                                );
                            }
                            break;
                        }
                        Msg::Error { message } => {
                            return Err(StepFailure {
                                fatal: false,
                                err: anyhow::anyhow!("replica {q} failed: {message}"),
                            });
                        }
                        other => {
                            return Err(StepFailure {
                                fatal: true,
                                err: anyhow::anyhow!("{peer}: unexpected {other:?} mid-step"),
                            });
                        }
                    }
                }
                Ok(FramePoll::Pending { progressed }) => {
                    // Liveness resets on *byte* progress, not complete
                    // frames, so a slow large frame never reads as a
                    // hang; heartbeats cover compute-bound silence.
                    if progressed {
                        last_activity = Instant::now();
                    }
                    if let Some(grace) = dl.grace() {
                        if last_activity.elapsed() > grace {
                            crate::obs::metrics::counter_add("supervisor.heartbeat_misses", 1);
                            crate::obs::span::instant("supervisor.heartbeat_miss", None);
                            return Err(StepFailure {
                                fatal: true,
                                err: anyhow::anyhow!(
                                    "{peer} presumed hung: no heartbeat or data for {}ms \
                                     (grace {}ms at --heartbeat-ms {})",
                                    last_activity.elapsed().as_millis(),
                                    grace.as_millis(),
                                    dl.heartbeat_ms
                                ),
                            });
                        }
                    }
                    if let Some(limit) = dl.step {
                        if started.elapsed() > limit {
                            return Err(StepFailure {
                                fatal: true,
                                err: anyhow::anyhow!(
                                    "{peer} exceeded the step deadline ({:.1}s; \
                                     --step-timeout / MOONWALK_STEP_TIMEOUT)",
                                    limit.as_secs_f64()
                                ),
                            });
                        }
                    }
                }
                Err(e) => {
                    let what = if e.kind() == io::ErrorKind::UnexpectedEof {
                        "worker died mid-step (connection closed)".to_string()
                    } else {
                        format!("transport error mid-step: {e}")
                    };
                    return Err(StepFailure {
                        fatal: true,
                        err: anyhow::anyhow!("replica {q} ({}) {what}", family.as_str()),
                    });
                }
            }
        }
    }
    Ok(())
}

impl Drop for SocketCoordinator {
    fn drop(&mut self) {
        // Ask every live worker to exit, give them a moment, then make
        // sure no spawned process outlives the coordinator.
        for conn in self.conns.iter_mut().flatten() {
            let _ = wire::write_shutdown(&mut conn.writer);
            let _ = conn.writer.flush();
        }
        let deadline = Instant::now() + Duration::from_millis(500);
        for conn in self.conns.iter_mut().flatten() {
            let Some(child) = conn.child.as_mut() else {
                continue;
            };
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        if let Some(path) = &self.socket_path {
            let _ = std::fs::remove_file(path);
        }
        if let Some((dir, own)) = &self.socket_dir {
            if *own {
                let _ = std::fs::remove_dir_all(dir);
            }
        }
    }
}
