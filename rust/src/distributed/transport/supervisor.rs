//! The supervision layer: configurable connection deadlines, heartbeat
//! policy, retry backoff, and the scriptable [`FaultPlan`] chaos
//! harness.
//!
//! PR 4's transport had three hard-coded time constants (30 s accept,
//! 10 s hello, **no** step deadline — a hung worker blocked the
//! coordinator forever) and two ad-hoc fault hooks (`kill_worker`,
//! `simulate_worker_crash`). This module generalizes both:
//!
//! * [`Deadlines`] resolves every timing knob through the usual
//!   precedence (CLI flag > `MOONWALK_*` env var > default) and rides
//!   along every coordinator connection. The **step deadline** is the
//!   hung-worker fix: readers poll on a short timeout and abandon a
//!   connection that exceeds it. The **heartbeat** interval drives both
//!   sides — workers tick while computing; the coordinator treats
//!   `grace()` of byte-silence as a dead peer long before the step
//!   deadline fires.
//! * [`Backoff`] is the doubling retry delay used by step retry and
//!   worker connect loops.
//! * [`StragglerTracker`] keeps streaming mean/variance (Welford) of
//!   per-replica step time and flags a replica whose sample sits beyond
//!   a configurable z-score of the fleet distribution — the live
//!   telemetry plane surfaces flags as `supervisor.stragglers` counters
//!   (total + `replica`-labeled), `supervisor.straggler` trace
//!   instants, and a per-row JSONL field.
//! * [`FaultPlan`] is a deterministic, scriptable schedule of injected
//!   failures (`kill:1@3,hang:0@5,drop:1@2,delay250:0@1,corrupt:1@4`),
//!   wired through `--fault` / `MOONWALK_FAULT` and the bench harness.
//!   Worker-side events (kill, hang) ship to the worker in its init
//!   blob; coordinator-side events (drop/delay/corrupt a gradient
//!   frame) are applied in the reader loop. Events are **one-shot**:
//!   arming removes them, so a respawned worker comes back clean. The
//!   wildcard step `@*` re-arms on every spawn — that is how the
//!   failover tests model a host that never comes back.
//!
//! Determinism note: fault *injection* is deterministic (keyed on
//! `(replica, global step)`), and recovery is provably exact — the
//! retry path replays the identical batch against unchanged parameters,
//! so a post-recovery loss curve is bit-identical to a no-fault run
//! (`tests/fault_tolerance.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Default per-step compute deadline (seconds). Generous: it is the
/// backstop for a worker that hangs with heartbeats disabled; with
/// heartbeats on, `grace()` detects the hang much sooner.
pub const DEFAULT_STEP_TIMEOUT_S: u64 = 120;
/// Default worker accept/connect deadline (seconds) — PR 4's 30 s, now
/// configurable.
pub const DEFAULT_ACCEPT_TIMEOUT_S: u64 = 30;
/// Default handshake read deadline (seconds) — PR 4's 10 s, now
/// configurable.
pub const DEFAULT_HELLO_TIMEOUT_S: u64 = 10;
/// Default worker heartbeat interval (milliseconds). 0 disables
/// heartbeats (liveness then rests on the step deadline alone).
pub const DEFAULT_HEARTBEAT_MS: u64 = 500;

// Global knob state, resolved lazily like every other runtime knob
// (pool threads, replicas, transport kind): explicit setter (CLI) >
// env var > default. Values are stored in milliseconds; 0 = unresolved,
// u64::MAX = explicitly disabled.
static STEP_MS: AtomicU64 = AtomicU64::new(0);
static ACCEPT_MS: AtomicU64 = AtomicU64::new(0);
static HELLO_MS: AtomicU64 = AtomicU64::new(0);
static HEARTBEAT_MS: AtomicU64 = AtomicU64::new(0);

const DISABLED: u64 = u64::MAX;

fn resolve_ms(slot: &AtomicU64, env: &str, default_ms: u64, zero_disables: bool) -> u64 {
    match slot.load(Ordering::Relaxed) {
        0 => {}
        v => return v,
    }
    let v = match std::env::var(env) {
        Ok(s) => match s.trim().parse::<f64>() {
            Ok(secs) if secs == 0.0 && zero_disables => DISABLED,
            Ok(secs) if secs > 0.0 => (secs * 1000.0) as u64,
            _ => {
                crate::log_warn!("{env}=`{s}` is not a valid duration; using the default");
                default_ms
            }
        },
        Err(_) => default_ms,
    };
    slot.store(v.max(1), Ordering::Relaxed);
    v.max(1)
}

fn store_ms(slot: &AtomicU64, ms: u64, zero_disables: bool) {
    slot.store(
        if ms == 0 {
            if zero_disables {
                DISABLED
            } else {
                1
            }
        } else {
            ms
        },
        Ordering::Relaxed,
    );
}

/// Set the per-step compute deadline (CLI `--step-timeout`, seconds;
/// `0` disables — the PR 4 behavior of waiting forever).
pub fn set_step_timeout_secs(secs: f64) {
    store_ms(&STEP_MS, (secs * 1000.0) as u64, true);
}

/// Set the worker accept/connect deadline (CLI `--accept-timeout`,
/// seconds).
pub fn set_accept_timeout_secs(secs: f64) {
    store_ms(&ACCEPT_MS, (secs * 1000.0) as u64, false);
}

/// Set the handshake read deadline (CLI `--hello-timeout`, seconds).
pub fn set_hello_timeout_secs(secs: f64) {
    store_ms(&HELLO_MS, (secs * 1000.0) as u64, false);
}

/// Set the worker heartbeat interval (CLI `--heartbeat-ms`; `0`
/// disables heartbeats).
pub fn set_heartbeat_ms(ms: u64) {
    store_ms(&HEARTBEAT_MS, ms, true);
}

/// Every timing knob a supervised connection needs, in one copyable
/// bundle. [`Deadlines::resolve`] reads the global knobs; tests and
/// benches construct explicit values to keep fault detection fast.
#[derive(Clone, Copy, Debug)]
pub struct Deadlines {
    /// Worker accept/connect deadline.
    pub accept: Duration,
    /// Handshake (hello) read deadline.
    pub hello: Duration,
    /// Per-step compute deadline; `None` = wait forever.
    pub step: Option<Duration>,
    /// Worker heartbeat interval in milliseconds; 0 disables.
    pub heartbeat_ms: u64,
}

impl Default for Deadlines {
    fn default() -> Deadlines {
        Deadlines {
            accept: Duration::from_secs(DEFAULT_ACCEPT_TIMEOUT_S),
            hello: Duration::from_secs(DEFAULT_HELLO_TIMEOUT_S),
            step: Some(Duration::from_secs(DEFAULT_STEP_TIMEOUT_S)),
            heartbeat_ms: DEFAULT_HEARTBEAT_MS,
        }
    }
}

impl Deadlines {
    /// Resolve from the global knobs: explicit setters (the CLI flags)
    /// > `MOONWALK_STEP_TIMEOUT` / `MOONWALK_ACCEPT_TIMEOUT` /
    /// `MOONWALK_HELLO_TIMEOUT` (seconds) and `MOONWALK_HEARTBEAT_MS`
    /// (milliseconds) > the defaults.
    pub fn resolve() -> Deadlines {
        let step = resolve_ms(
            &STEP_MS,
            "MOONWALK_STEP_TIMEOUT",
            DEFAULT_STEP_TIMEOUT_S * 1000,
            true,
        );
        let accept = resolve_ms(
            &ACCEPT_MS,
            "MOONWALK_ACCEPT_TIMEOUT",
            DEFAULT_ACCEPT_TIMEOUT_S * 1000,
            false,
        );
        let hello = resolve_ms(
            &HELLO_MS,
            "MOONWALK_HELLO_TIMEOUT",
            DEFAULT_HELLO_TIMEOUT_S * 1000,
            false,
        );
        let hb = {
            match HEARTBEAT_MS.load(Ordering::Relaxed) {
                0 => {
                    let v = match std::env::var("MOONWALK_HEARTBEAT_MS") {
                        Ok(s) => match s.trim().parse::<u64>() {
                            Ok(0) => DISABLED,
                            Ok(ms) => ms,
                            Err(_) => DEFAULT_HEARTBEAT_MS,
                        },
                        Err(_) => DEFAULT_HEARTBEAT_MS,
                    };
                    HEARTBEAT_MS.store(v, Ordering::Relaxed);
                    v
                }
                v => v,
            }
        };
        Deadlines {
            accept: Duration::from_millis(accept),
            hello: Duration::from_millis(hello),
            step: if step == DISABLED {
                None
            } else {
                Some(Duration::from_millis(step))
            },
            heartbeat_ms: if hb == DISABLED { 0 } else { hb },
        }
    }

    /// How long a connection may stay byte-silent mid-step before the
    /// supervisor declares its worker dead: several missed heartbeats,
    /// floored so scheduler jitter cannot produce false positives.
    /// `None` when heartbeats are disabled (the step deadline is then
    /// the only liveness check).
    pub fn grace(&self) -> Option<Duration> {
        if self.heartbeat_ms == 0 {
            None
        } else {
            Some(Duration::from_millis((self.heartbeat_ms * 8).max(500)))
        }
    }

    /// The reader poll interval: short enough to notice deadline/grace
    /// expiry promptly, long enough not to spin.
    pub fn poll(&self) -> Duration {
        let ms = if self.heartbeat_ms > 0 {
            self.heartbeat_ms.clamp(5, 200)
        } else {
            200
        };
        Duration::from_millis(ms)
    }
}

/// The absolute ceiling any [`Backoff`] delay can reach, in
/// milliseconds (60 s). A large `--step-retries` budget doubles the
/// delay every attempt; without an absolute cap a caller-supplied
/// `max_ms` derived from an unchecked multiply could overflow the ms
/// counter or sleep absurdly long between replays. Every constructor
/// clamps to this, so a retry chain of any length is monotone,
/// bounded, and panic-free.
pub const MAX_BACKOFF_MS: u64 = 60_000;

/// Exponential retry backoff: `base, 2·base, 4·base, …` capped at
/// `max` (itself clamped to [`MAX_BACKOFF_MS`]). Deterministic (no
/// jitter) so retried runs stay reproducible.
#[derive(Clone, Debug)]
pub struct Backoff {
    next_ms: u64,
    max_ms: u64,
}

impl Backoff {
    /// A backoff starting at `base_ms`, doubling up to `max_ms`. Both
    /// arguments are clamped into `[1, MAX_BACKOFF_MS]`, so even a
    /// pathological caller value (e.g. an overflowed multiply) yields
    /// a bounded schedule.
    pub fn new(base_ms: u64, max_ms: u64) -> Backoff {
        Backoff {
            next_ms: base_ms.clamp(1, MAX_BACKOFF_MS),
            max_ms: max_ms.clamp(1, MAX_BACKOFF_MS),
        }
    }

    /// The next delay (advancing the schedule). Saturating: the
    /// doubling never wraps, and the returned delay never exceeds
    /// `max_ms` (≤ [`MAX_BACKOFF_MS`]).
    pub fn delay(&mut self) -> Duration {
        let d = self.next_ms.min(self.max_ms);
        self.next_ms = self.next_ms.saturating_mul(2).min(self.max_ms);
        Duration::from_millis(d)
    }
}

// ----- straggler detection ---------------------------------------------------

/// Default straggler z-score threshold: a replica's step time must sit
/// more than this many standard deviations above the fleet mean to be
/// flagged. 0 disables detection.
pub const DEFAULT_STRAGGLER_Z: f64 = 3.0;

/// Samples the fleet distribution must hold before any flagging — a
/// cold cache or first-step parameter upload should not trip the
/// detector.
pub const STRAGGLER_MIN_SAMPLES: u64 = 8;

// f64 bits in an AtomicU64, same lazy precedence as the deadline knobs:
// explicit setter (CLI `--straggler-z`) > MOONWALK_STRAGGLER_Z env >
// default. `u64::MAX` marks "unresolved" (it decodes to a NaN, which no
// setter can produce via to_bits on a finite value path below).
static STRAGGLER_Z: AtomicU64 = AtomicU64::new(u64::MAX);

/// Set the straggler z-score threshold (CLI `--straggler-z`; `0`
/// disables detection). Negative or non-finite values disable too.
pub fn set_straggler_z(z: f64) {
    let v = if z.is_finite() && z > 0.0 { z } else { 0.0 };
    STRAGGLER_Z.store(v.to_bits(), Ordering::Relaxed);
}

/// Resolve the straggler z-score threshold: explicit setter >
/// `MOONWALK_STRAGGLER_Z` env var > [`DEFAULT_STRAGGLER_Z`]. Returns
/// 0.0 when detection is disabled.
pub fn straggler_z() -> f64 {
    match STRAGGLER_Z.load(Ordering::Relaxed) {
        u64::MAX => {}
        bits => return f64::from_bits(bits),
    }
    let v = match std::env::var("MOONWALK_STRAGGLER_Z") {
        Ok(s) => match s.trim().parse::<f64>() {
            Ok(z) if z.is_finite() && z >= 0.0 => z,
            _ => {
                crate::log_warn!(
                    "MOONWALK_STRAGGLER_Z=`{s}` is not a valid threshold; using the default"
                );
                DEFAULT_STRAGGLER_Z
            }
        },
        Err(_) => DEFAULT_STRAGGLER_Z,
    };
    STRAGGLER_Z.store(v.to_bits(), Ordering::Relaxed);
    v
}

/// Streaming straggler detector over per-replica step times.
///
/// One Welford accumulator tracks the **fleet** distribution (every
/// sample from every replica — the reference a straggler deviates
/// from), plus a per-replica sample count/mean for attribution. A
/// sample is flagged when the fleet holds at least
/// [`STRAGGLER_MIN_SAMPLES`] observations, the variance is non-zero,
/// and the sample's z-score exceeds the threshold. Purely
/// observational: flagging never changes scheduling, so the §2.6
/// determinism contract is untouched.
#[derive(Debug, Default)]
pub struct StragglerTracker {
    n: u64,
    mean: f64,
    m2: f64,
    /// Per-replica `(samples, mean)` for the report line.
    per_replica: Vec<(u64, f64)>,
}

impl StragglerTracker {
    /// An empty tracker (thresholds resolve per call via
    /// [`straggler_z`] unless given explicitly to [`Self::record_with`]).
    pub fn new() -> StragglerTracker {
        StragglerTracker::default()
    }

    /// Record `secs` for `replica` against the globally resolved
    /// z-score knob. Returns `true` when the sample is flagged.
    pub fn record(&mut self, replica: usize, secs: f64) -> bool {
        self.record_with(replica, secs, straggler_z())
    }

    /// Record `secs` for `replica` against an explicit threshold `z`
    /// (`0` disables). Flag semantics in the type docs.
    pub fn record_with(&mut self, replica: usize, secs: f64, z: f64) -> bool {
        // Flag against the distribution *before* folding the sample in,
        // so one extreme outlier cannot dilute its own detection.
        let flagged = z > 0.0 && self.n >= STRAGGLER_MIN_SAMPLES && {
            let var = self.m2 / (self.n - 1) as f64;
            var > 0.0 && (secs - self.mean) / var.sqrt() > z
        };
        self.n += 1;
        let d = secs - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (secs - self.mean);
        if replica >= self.per_replica.len() {
            self.per_replica.resize(replica + 1, (0, 0.0));
        }
        let (rn, rmean) = &mut self.per_replica[replica];
        *rn += 1;
        *rmean += (secs - *rmean) / *rn as f64;
        flagged
    }

    /// Fleet sample count.
    pub fn samples(&self) -> u64 {
        self.n
    }

    /// Fleet mean step time in seconds (0 before any sample).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Fleet step-time standard deviation in seconds (0 below two
    /// samples).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Per-replica `(samples, mean seconds)`, indexed by replica.
    pub fn replica_means(&self) -> &[(u64, f64)] {
        &self.per_replica
    }
}

// ----- fault injection -------------------------------------------------------

/// What an injected fault does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Worker-side: abort the process right after streaming its first
    /// gradient frame of the step — a kill -9 mid-step that leaves the
    /// coordinator holding a partial delivery.
    Kill,
    /// Worker-side: stop heartbeating and sleep forever mid-step — the
    /// failure mode PR 4 could not detect.
    Hang,
    /// Coordinator-side: discard the worker's first gradient frame of
    /// the step (exercises the partial-delivery guard).
    DropFrame,
    /// Coordinator-side: delay processing the first gradient frame by
    /// this many milliseconds (a transient slow link; the step must
    /// still succeed bit-identically).
    DelayFrame(u64),
    /// Coordinator-side: corrupt the first gradient frame's tag byte,
    /// forcing the labeled decode-error path.
    CorruptFrame,
}

impl FaultKind {
    /// Whether the event executes inside the worker process (shipped in
    /// the init blob) rather than in the coordinator's reader.
    pub fn worker_side(&self) -> bool {
        matches!(self, FaultKind::Kill | FaultKind::Hang)
    }

    /// The spec spelling of this kind.
    pub fn label(&self) -> String {
        match self {
            FaultKind::Kill => "kill".into(),
            FaultKind::Hang => "hang".into(),
            FaultKind::DropFrame => "drop".into(),
            FaultKind::DelayFrame(ms) => format!("delay{ms}"),
            FaultKind::CorruptFrame => "corrupt".into(),
        }
    }
}

/// One scheduled fault: `kind` strikes `replica` at global step `step`
/// (`None` = every step — the `@*` wildcard, which re-arms after every
/// respawn and models a permanently failing host).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// The replica slot the fault targets.
    pub replica: usize,
    /// 0-based global step index; `None` fires every step.
    pub step: Option<usize>,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic schedule of injected faults (see module docs).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// The scheduled events, in spec order.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a comma-separated spec: each entry is
    /// `kind:replica@step`, kind ∈ `kill | hang | drop | corrupt |
    /// delay<ms>`, step a 0-based integer or `*` (every step).
    /// Example: `kill:1@3,hang:0@5,delay250:0@1`.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut events = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind_s, rest) = entry
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("fault `{entry}`: expected kind:replica@step"))?;
            let (replica_s, step_s) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault `{entry}`: expected kind:replica@step"))?;
            let kind = match kind_s.trim() {
                "kill" => FaultKind::Kill,
                "hang" => FaultKind::Hang,
                "drop" => FaultKind::DropFrame,
                "corrupt" => FaultKind::CorruptFrame,
                k if k.starts_with("delay") => {
                    let ms: u64 = k["delay".len()..].parse().map_err(|_| {
                        anyhow::anyhow!("fault `{entry}`: delay needs milliseconds (delay250)")
                    })?;
                    FaultKind::DelayFrame(ms)
                }
                other => anyhow::bail!(
                    "fault `{entry}`: unknown kind `{other}` (kill|hang|drop|corrupt|delay<ms>)"
                ),
            };
            let replica: usize = replica_s
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault `{entry}`: bad replica index"))?;
            let step = match step_s.trim() {
                "*" => None,
                s => Some(s.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("fault `{entry}`: bad step (integer or `*`)")
                })?),
            };
            events.push(FaultEvent {
                replica,
                step,
                kind,
            });
        }
        Ok(FaultPlan { events })
    }

    /// Resolve the active plan: explicit `spec` (the CLI `--fault`) >
    /// `MOONWALK_FAULT` env var > empty.
    pub fn resolve(spec: Option<&str>) -> anyhow::Result<FaultPlan> {
        if let Some(s) = spec {
            return FaultPlan::parse(s);
        }
        if let Ok(s) = std::env::var("MOONWALK_FAULT") {
            if !s.trim().is_empty() {
                return FaultPlan::parse(&s);
            }
        }
        Ok(FaultPlan::default())
    }

    /// Whether no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The spec spelling of this plan (round-trips through [`parse`]).
    ///
    /// [`parse`]: Self::parse
    pub fn to_spec(&self) -> String {
        self.events
            .iter()
            .map(|e| {
                format!(
                    "{}:{}@{}",
                    e.kind.label(),
                    e.replica,
                    match e.step {
                        Some(s) => s.to_string(),
                        None => "*".into(),
                    }
                )
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Take the worker-side events for `replica`, to ship in its init
    /// blob. One-shot events are consumed (a respawned worker comes
    /// back clean); wildcard (`@*`) events are copied and retained.
    pub fn arm_worker(&mut self, replica: usize) -> Vec<FaultEvent> {
        let mut armed = Vec::new();
        self.events.retain(|e| {
            if e.replica == replica && e.kind.worker_side() {
                armed.push(e.clone());
                e.step.is_none() // retain only wildcards
            } else {
                true
            }
        });
        armed
    }

    /// Take the coordinator-side fault for `(replica, step)` if one is
    /// scheduled. One-shot events are consumed; wildcards retained.
    pub fn take_coord(&mut self, replica: usize, step: usize) -> Option<FaultKind> {
        let idx = self.events.iter().position(|e| {
            e.replica == replica
                && !e.kind.worker_side()
                && e.step.map(|s| s == step).unwrap_or(true)
        })?;
        let e = self.events[idx].clone();
        if e.step.is_some() {
            self.events.remove(idx);
        }
        Some(e.kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_parses_and_round_trips() {
        let plan = FaultPlan::parse("kill:1@3, hang:0@5,drop:1@2,delay250:0@1,corrupt:1@*")
            .unwrap();
        assert_eq!(plan.events.len(), 5);
        assert_eq!(
            plan.events[0],
            FaultEvent {
                replica: 1,
                step: Some(3),
                kind: FaultKind::Kill
            }
        );
        assert_eq!(plan.events[3].kind, FaultKind::DelayFrame(250));
        assert_eq!(plan.events[4].step, None);
        let respelled = FaultPlan::parse(&plan.to_spec()).unwrap();
        assert_eq!(respelled.events, plan.events);
        assert!(FaultPlan::parse("explode:0@1").is_err());
        assert!(FaultPlan::parse("kill:0").is_err());
        assert!(FaultPlan::parse("kill:x@1").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn arming_is_one_shot_except_wildcards() {
        let mut plan = FaultPlan::parse("kill:0@2,hang:1@*,drop:0@1").unwrap();
        let armed = plan.arm_worker(0);
        assert_eq!(armed.len(), 1);
        assert_eq!(armed[0].kind, FaultKind::Kill);
        // Re-arming replica 0 finds nothing: the one-shot was consumed.
        assert!(plan.arm_worker(0).is_empty());
        // The wildcard hang re-arms every time.
        assert_eq!(plan.arm_worker(1).len(), 1);
        assert_eq!(plan.arm_worker(1).len(), 1);
        // Coordinator-side events are untouched by worker arming.
        assert_eq!(plan.take_coord(0, 1), Some(FaultKind::DropFrame));
        assert_eq!(plan.take_coord(0, 1), None, "one-shot consumed");
    }

    #[test]
    fn coord_faults_match_step_or_wildcard() {
        let mut plan = FaultPlan::parse("delay10:1@*").unwrap();
        assert_eq!(plan.take_coord(1, 0), Some(FaultKind::DelayFrame(10)));
        assert_eq!(plan.take_coord(1, 7), Some(FaultKind::DelayFrame(10)));
        assert_eq!(plan.take_coord(0, 0), None, "wrong replica");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let mut b = Backoff::new(10, 50);
        assert_eq!(b.delay().as_millis(), 10);
        assert_eq!(b.delay().as_millis(), 20);
        assert_eq!(b.delay().as_millis(), 40);
        assert_eq!(b.delay().as_millis(), 50);
        assert_eq!(b.delay().as_millis(), 50);
    }

    #[test]
    fn backoff_long_chain_monotone_capped_panic_free() {
        // Regression (ISSUE 7): a large --step-retries budget walks the
        // doubling schedule far past where u64 would wrap; pathological
        // constructor arguments (e.g. an overflowed base·8) used to
        // escape any absolute cap. The chain must stay monotone
        // nondecreasing, bounded by MAX_BACKOFF_MS, and panic-free —
        // for both an ordinary base and u64::MAX inputs.
        for (base, max) in [(1u64, u64::MAX), (50, 50 * 8), (u64::MAX, u64::MAX)] {
            let mut b = Backoff::new(base, max);
            let mut prev = 0u128;
            for step in 0..200 {
                let d = b.delay().as_millis();
                assert!(
                    d >= prev,
                    "delay regressed at step {step}: {d} < {prev} (base {base})"
                );
                assert!(
                    d <= u128::from(MAX_BACKOFF_MS),
                    "delay {d} exceeds MAX_BACKOFF_MS at step {step} (base {base})"
                );
                prev = d;
            }
            assert_eq!(
                b.delay().as_millis(),
                u128::from(MAX_BACKOFF_MS.min(max.clamp(1, MAX_BACKOFF_MS))),
                "a long chain must end pinned at the cap"
            );
        }
    }

    #[test]
    fn straggler_tracker_welford_matches_two_pass_moments() {
        let mut t = StragglerTracker::new();
        let samples = [0.010, 0.012, 0.011, 0.013, 0.009, 0.010, 0.012, 0.011];
        for (i, &s) in samples.iter().enumerate() {
            t.record_with(i % 2, s, 3.0);
        }
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        let var: f64 = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / (samples.len() - 1) as f64;
        assert!((t.mean() - mean).abs() < 1e-12);
        assert!((t.stddev() - var.sqrt()).abs() < 1e-12);
        assert_eq!(t.samples(), samples.len() as u64);
        let per = t.replica_means();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].0 + per[1].0, samples.len() as u64);
    }

    #[test]
    fn straggler_flags_only_past_min_samples_and_threshold() {
        let mut t = StragglerTracker::new();
        // A huge outlier inside the warm-up window must NOT flag.
        assert!(!t.record_with(0, 10.0, 3.0), "no flag before min samples");
        let mut t = StragglerTracker::new();
        for i in 0..STRAGGLER_MIN_SAMPLES {
            // Tight cluster with a little genuine variance.
            let jitter = (i % 3) as f64 * 1e-4;
            assert!(!t.record_with((i % 2) as usize, 0.010 + jitter, 3.0));
        }
        // 100 ms against a ~10 ms fleet: far beyond 3 sigma.
        assert!(t.record_with(1, 0.100, 3.0), "outlier must flag");
        // The same sample with detection disabled (z = 0) must not.
        let mut t2 = StragglerTracker::new();
        for i in 0..STRAGGLER_MIN_SAMPLES {
            let jitter = (i % 3) as f64 * 1e-4;
            t2.record_with((i % 2) as usize, 0.010 + jitter, 0.0);
        }
        assert!(!t2.record_with(1, 0.100, 0.0), "z=0 disables detection");
        // Zero variance (all samples identical) never flags — the
        // z-score is undefined there, not infinite.
        let mut t3 = StragglerTracker::new();
        for _ in 0..20 {
            t3.record_with(0, 0.010, 3.0);
        }
        assert!(!t3.record_with(0, 0.010, 3.0));
    }

    #[test]
    fn straggler_z_setter_clamps_invalid_to_disabled() {
        // Do not touch the global resolution order of other tests more
        // than necessary: set, check, restore to the default.
        set_straggler_z(-1.0);
        assert_eq!(straggler_z(), 0.0, "negative disables");
        set_straggler_z(f64::NAN);
        assert_eq!(straggler_z(), 0.0, "NaN disables");
        set_straggler_z(2.5);
        assert_eq!(straggler_z(), 2.5);
        set_straggler_z(DEFAULT_STRAGGLER_Z);
    }

    #[test]
    fn deadline_grace_and_poll_track_heartbeat() {
        let d = Deadlines {
            heartbeat_ms: 50,
            ..Default::default()
        };
        assert_eq!(d.grace().unwrap().as_millis(), 500, "floored at 500ms");
        let d = Deadlines {
            heartbeat_ms: 1000,
            ..Default::default()
        };
        assert_eq!(d.grace().unwrap().as_millis(), 8000);
        assert_eq!(d.poll().as_millis(), 200, "poll capped at 200ms");
        let d = Deadlines {
            heartbeat_ms: 0,
            ..Default::default()
        };
        assert!(d.grace().is_none(), "no heartbeat, no grace check");
    }
}
