//! Async double-buffered data pipeline with deterministic sharding.
//!
//! A [`BatchPlan`] is the single source of truth for what every training
//! step consumes: it draws each epoch's batch order from a **splittable
//! per-epoch RNG stream** (`stream_seed(seed, epoch)` — never the
//! caller's live RNG), so the global sample sequence is a pure function
//! of `(dataset, batch, seed)`. Each global batch is then split into
//! `replicas` disjoint, contiguous sub-batches. Because the global
//! sequence never depends on the replica count, `replicas = 1` and
//! `replicas = N` provably draw identical global batches — the
//! precondition for the gradient-equivalence contract in
//! `tests/distributed.rs`.
//!
//! [`Prefetcher`] runs the same plan on a scoped producer thread behind a
//! capacity-1 rendezvous channel: while step `t` computes, the producer
//! materializes and shards batch `t + 1` (the classic double buffer).
//! Determinism is unaffected — the prefetched stream is the plan's
//! stream, byte for byte; only the wall-clock overlap changes. The
//! trainer logs the time it spent blocked on the channel as
//! `prefetch_wait_s` (≈ 0 when the pipeline hides data latency).

use std::sync::mpsc::{sync_channel, Receiver};

use crate::coordinator::data::TextureDataset;
use crate::tensor::Tensor;
use crate::util::rng::stream_seed;
use crate::util::{Rng, Timer};

/// Everything one training step consumes, fully materialized.
///
/// Shard payloads are **raw, tracker-invisible** vectors: the producer
/// thread gathers pixels/labels but never constructs a tracked `Tensor`,
/// so a `tracker::measure` window open on the training thread sees no
/// concurrent allocations from the pipeline — per-step `peak_mem_bytes`
/// / `allocs` stay deterministic. Convert on the consuming thread with
/// [`Self::into_shards`] (zero-copy; the tracker registration happens
/// there, at a fixed point outside the measurement window).
pub struct StepBatch {
    /// 0-based global step index.
    pub step: usize,
    /// Epoch this batch was drawn from.
    pub epoch: usize,
    /// The global batch's sample indices, in draw order.
    pub global_indices: Vec<usize>,
    /// Per-replica raw `(pixels, labels)` shards: contiguous equal
    /// splits of the global batch, in replica order.
    pub raw_shards: Vec<(Vec<f32>, Vec<usize>)>,
    /// Tensor shape of one shard's input, `[shard_batch, hw, hw, cin]`.
    pub shard_shape: Vec<usize>,
}

impl StepBatch {
    /// Materialize the per-replica `(input, labels)` tensors (zero-copy
    /// move of the raw payloads; this is where the allocation tracker
    /// first sees the batch).
    pub fn into_shards(self) -> Vec<(Tensor, Vec<usize>)> {
        let shape = self.shard_shape;
        self.raw_shards
            .into_iter()
            .map(|(data, labels)| (Tensor::from_vec(data, &shape), labels))
            .collect()
    }
}

/// Deterministic batch/shard schedule over a dataset (see module docs).
pub struct BatchPlan<'a> {
    data: &'a TextureDataset,
    batch: usize,
    replicas: usize,
    seed: u64,
    next_epoch: usize,
    queue_epoch: usize,
    step: usize,
    /// Remaining batches of the current epoch, reversed so `pop()` yields
    /// them in draw order.
    queue: Vec<Vec<usize>>,
}

impl<'a> BatchPlan<'a> {
    /// A plan drawing global batches of `batch` samples from `data`,
    /// split into `replicas` shards, with the whole sample sequence a
    /// pure function of `seed`.
    pub fn new(
        data: &'a TextureDataset,
        batch: usize,
        replicas: usize,
        seed: u64,
    ) -> anyhow::Result<BatchPlan<'a>> {
        anyhow::ensure!(batch >= 1, "batch must be >= 1");
        anyhow::ensure!(replicas >= 1, "replicas must be >= 1");
        anyhow::ensure!(
            batch % replicas == 0,
            "global batch {batch} is not divisible by {replicas} replicas"
        );
        anyhow::ensure!(
            data.len() >= batch,
            "dataset has {} samples but the global batch is {batch}",
            data.len()
        );
        Ok(BatchPlan {
            data,
            batch,
            replicas,
            seed,
            next_epoch: 0,
            queue_epoch: 0,
            step: 0,
            queue: Vec::new(),
        })
    }

    /// The replica count every global batch is split across.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Per-replica sub-batch size.
    pub fn shard_batch(&self) -> usize {
        self.batch / self.replicas
    }

    /// Materialize the next step's global batch and its replica shards.
    pub fn next_step(&mut self) -> StepBatch {
        if self.queue.is_empty() {
            let mut batches =
                self.data
                    .epoch_batches_seeded(self.batch, self.seed, self.next_epoch as u64);
            batches.reverse(); // pop() takes them in epoch order
            self.queue = batches;
            self.queue_epoch = self.next_epoch;
            self.next_epoch += 1;
        }
        let global_indices = self.queue.pop().expect("dataset holds >= one batch");
        let per = self.shard_batch();
        let raw_shards = global_indices
            .chunks(per)
            .map(|c| self.data.batch_raw(c))
            .collect();
        let step = self.step;
        self.step += 1;
        StepBatch {
            step,
            epoch: self.queue_epoch,
            global_indices,
            raw_shards,
            shard_shape: self.data.batch_shape(per),
        }
    }
}

/// Per-replica augmentation/noise stream for a given epoch — the
/// `seed ⊕ epoch ⊕ shard` splittable stream of the sharded pipeline.
/// Replica-local randomness drawn from here is reproducible regardless
/// of replica→thread scheduling or how much randomness other replicas
/// consumed.
pub fn shard_rng(seed: u64, epoch: u64, shard: u64) -> Rng {
    Rng::new(stream_seed(seed, &[epoch, shard]))
}

/// Double-buffered producer over a [`BatchPlan`]: a scoped thread runs
/// the plan and hands batches through a capacity-1 channel.
pub struct Prefetcher {
    rx: Receiver<StepBatch>,
}

impl Prefetcher {
    /// Spawn the producer inside `scope`, generating exactly `steps`
    /// batches (then exiting). Dropping the `Prefetcher` early unblocks a
    /// producer stuck on a full channel (its send fails), so the scope
    /// always joins.
    pub fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        mut plan: BatchPlan<'env>,
        steps: usize,
    ) -> Prefetcher {
        let (tx, rx) = sync_channel::<StepBatch>(1);
        scope.spawn(move || {
            for _ in 0..steps {
                if tx.send(plan.next_step()).is_err() {
                    break; // consumer gone — stop producing
                }
            }
        });
        Prefetcher { rx }
    }

    /// Take the next prefetched batch, reporting the seconds this call
    /// spent blocked on the producer (the pipeline-stall metric).
    pub fn next(&self) -> anyhow::Result<(StepBatch, f64)> {
        let t = Timer::start();
        let batch = self
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("prefetch producer exited early"))?;
        Ok((batch, t.elapsed_s()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::data::SyntheticSpec;

    fn dataset(n: usize) -> TextureDataset {
        TextureDataset::generate(
            SyntheticSpec {
                hw: 8,
                cin: 1,
                classes: 3,
                noise: 0.1,
                seed: 7,
            },
            n,
        )
    }

    #[test]
    fn plan_is_deterministic_and_covers_epochs() {
        let ds = dataset(12);
        let run = || {
            let mut plan = BatchPlan::new(&ds, 4, 2, 99).unwrap();
            (0..7).map(|_| plan.next_step()).collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.global_indices, y.global_indices);
            assert_eq!(x.epoch, y.epoch);
        }
        // 12 samples / batch 4 = 3 steps per epoch; step 6 is epoch 2.
        assert_eq!(a[2].epoch, 0);
        assert_eq!(a[3].epoch, 1);
        assert_eq!(a[6].epoch, 2);
        // One epoch's batches partition the dataset.
        let mut first_epoch: Vec<usize> = a[..3]
            .iter()
            .flat_map(|s| s.global_indices.clone())
            .collect();
        first_epoch.sort();
        assert_eq!(first_epoch, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn shards_partition_the_global_batch() {
        let ds = dataset(16);
        let mut plan = BatchPlan::new(&ds, 8, 4, 5).unwrap();
        let sb = plan.next_step();
        assert_eq!(sb.raw_shards.len(), 4);
        assert_eq!(sb.shard_shape, vec![2, 8, 8, 1]);
        let global_indices = sb.global_indices.clone();
        let shards = sb.into_shards();
        let mut rebuilt: Vec<usize> = Vec::new();
        for (r, (x, labels)) in shards.iter().enumerate() {
            assert_eq!(x.shape()[0], 2, "shard {r} batch");
            assert_eq!(labels.len(), 2);
            let idx = &global_indices[r * 2..(r + 1) * 2];
            let (xr, lr) = ds.batch(idx);
            assert_eq!(x.data(), xr.data(), "shard {r} pixels");
            assert_eq!(labels, &lr, "shard {r} labels");
            rebuilt.extend_from_slice(idx);
        }
        assert_eq!(rebuilt, global_indices);
    }

    #[test]
    fn global_sequence_is_replica_count_invariant() {
        let ds = dataset(12);
        let seq = |replicas: usize| {
            let mut plan = BatchPlan::new(&ds, 4, replicas, 321).unwrap();
            (0..6).map(|_| plan.next_step().global_indices).collect::<Vec<_>>()
        };
        let one = seq(1);
        assert_eq!(one, seq(2));
        assert_eq!(one, seq(4));
    }

    #[test]
    fn invalid_plans_rejected() {
        let ds = dataset(6);
        assert!(BatchPlan::new(&ds, 4, 3, 0).is_err(), "indivisible");
        assert!(BatchPlan::new(&ds, 8, 1, 0).is_err(), "batch > dataset");
        assert!(BatchPlan::new(&ds, 0, 1, 0).is_err(), "zero batch");
    }

    #[test]
    fn prefetcher_streams_the_plan_unchanged() {
        let ds = dataset(12);
        let direct = {
            let mut plan = BatchPlan::new(&ds, 4, 2, 11).unwrap();
            (0..5).map(|_| plan.next_step()).collect::<Vec<_>>()
        };
        let prefetched: Vec<StepBatch> = std::thread::scope(|scope| {
            let plan = BatchPlan::new(&ds, 4, 2, 11).unwrap();
            let pf = Prefetcher::spawn(scope, plan, 5);
            (0..5).map(|_| pf.next().unwrap().0).collect()
        });
        for (d, p) in direct.iter().zip(&prefetched) {
            assert_eq!(d.step, p.step);
            assert_eq!(d.global_indices, p.global_indices);
            assert_eq!(d.shard_shape, p.shard_shape);
            for ((dx, dl), (px, pl)) in d.raw_shards.iter().zip(&p.raw_shards) {
                assert_eq!(dx, px);
                assert_eq!(dl, pl);
            }
        }
    }

    #[test]
    fn early_drop_does_not_wedge_the_scope() {
        let ds = dataset(12);
        std::thread::scope(|scope| {
            let plan = BatchPlan::new(&ds, 4, 1, 3).unwrap();
            let pf = Prefetcher::spawn(scope, plan, 1000);
            let _ = pf.next().unwrap();
            // pf drops here with the producer mid-stream; scope must join.
        });
    }

    #[test]
    fn shard_rng_streams_are_stable_and_distinct() {
        let a1 = shard_rng(1, 2, 3).next_u64();
        let a2 = shard_rng(1, 2, 3).next_u64();
        assert_eq!(a1, a2);
        assert_ne!(a1, shard_rng(1, 2, 4).next_u64());
        assert_ne!(a1, shard_rng(1, 3, 3).next_u64());
    }
}
