//! Streaming deterministic all-reduce over per-layer gradient sets.
//!
//! The paper's mixed-mode sweep makes a layer's parameter gradient
//! available the moment its Phase-III step finishes (§4.3: gradients
//! "need not be stored simultaneously"). [`StreamingAllReduce`] exploits
//! exactly that property for data parallelism: every replica submits each
//! layer's gradient as its engine streams it, and the reduction for a
//! layer fires **on the thread that delivers the last contribution** —
//! overlapped with the other replicas' still-running vijp sweeps instead
//! of waiting for full gradient buffers. Peak footprint is bounded by the
//! in-flight layers' per-replica parts: with replicas running in
//! lockstep (replicas ≤ pool workers, the intended configuration) that
//! is about one layer-gradient per replica. When replicas oversubscribe
//! the pool they serialize per worker and an early replica's whole
//! gradient set parks here until the stragglers deliver — still correct
//! and deterministic, but the memory bound degrades (`ReplicaGroup`
//! warns once in that configuration).
//!
//! Determinism contract (mirrors `runtime::pool`'s): the fold is
//! **replica-ordered**, never arrival-ordered — partials are parked in a
//! per-replica slot and summed `0, 1, …, R−1` once all `R` arrived, so a
//! fixed replica count gives bit-identical results run-to-run regardless
//! of thread scheduling. [`ReduceOp::Mean`] divides by the replica count
//! after the ordered sum; for power-of-two counts the division is exact,
//! so exactly-associative payloads (small integers) reduce bit-equal
//! across replica counts too (`tests/distributed.rs`).
//!
//! **Gradient-bucket fusion.** A deep stack of layers with few
//! parameters each (biases, small convs) pays one reducer round trip —
//! lock, park, count — per layer per replica.
//! [`StreamingAllReduce::with_buckets`]
//! coalesces consecutive small-parameter layers into one bucket: a
//! bucket completes when *every member layer* has arrived from *every
//! replica*, then folds its members layer-by-layer (the identical
//! replica-ordered arithmetic — bucketing changes delivery batching,
//! never values, so bucketed results are **bit-identical** to
//! unbucketed ones; `tests/distributed.rs` proves it). Parameter-free
//! layers are never submitted by any engine, so they always form
//! never-completing singleton buckets; layers at or above the bucket
//! threshold stay singletons too, preserving the streamed
//! fire-on-last-contribution latency where it matters. The parked-bytes
//! bound grows by at most one bucket's parameter payload per replica.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::tensor::Tensor;
use crate::util::{lock_ignore_poison as lock, Timer};

/// Default byte threshold for [`StreamingAllReduce::with_buckets`]:
/// consecutive layers whose parameter payloads are each below this are
/// coalesced until the bucket reaches it. 16 KiB ≈ a 4k-parameter layer
/// — far below any conv tap tensor, so real conv/dense layers stay
/// singleton-streamed.
pub const DEFAULT_BUCKET_BYTES: usize = 16 * 1024;

/// How per-replica gradients combine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// Plain replica-ordered sum.
    Sum,
    /// Replica-ordered sum scaled by `1/replicas` — the data-parallel
    /// average that makes N equal shards equivalent to the single-replica
    /// full-batch gradient under a per-shard mean loss.
    Mean,
}

/// One bucket's partial gradients, parked until every member layer has
/// reported from every replica. `parts[member][replica]` holds one
/// layer's per-replica payload.
struct BucketSlot {
    parts: Vec<Vec<Option<Vec<Tensor>>>>,
    got: usize,
}

/// The share-ordered streaming reducer for one gradient step. Cheap to
/// construct (one `Option` per bucket); build a fresh one per step.
pub struct StreamingAllReduce {
    replicas: usize,
    op: ReduceOp,
    /// Bucket member lists (layer indices) and the inverse maps.
    members: Vec<Vec<usize>>,
    /// `bucket_of[layer]` — the bucket a layer belongs to.
    bucket_of: Vec<usize>,
    /// `member_pos[layer]` — the layer's index inside its bucket.
    member_pos: Vec<usize>,
    slots: Mutex<Vec<Option<BucketSlot>>>,
    /// Nanoseconds spent inside gradient folds (the overlap metric the
    /// trainer logs as `reduce_s`).
    reduce_ns: AtomicU64,
    /// Layers fully reduced so far.
    reduced: AtomicUsize,
}

/// Greedy coalescing of consecutive small-parameter layers (see module
/// docs): parameter-free layers and layers at/above `min_bucket_bytes`
/// stay singletons; the rest accumulate until a bucket reaches the
/// threshold.
fn bucket_groups(layer_bytes: &[usize], min_bucket_bytes: usize) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut open: Vec<usize> = Vec::new();
    let mut open_bytes = 0usize;
    for (i, &bytes) in layer_bytes.iter().enumerate() {
        if bytes == 0 {
            // Never submitted by any engine — must not gate a bucket.
            groups.push(vec![i]);
        } else if bytes >= min_bucket_bytes {
            if !open.is_empty() {
                groups.push(std::mem::take(&mut open));
                open_bytes = 0;
            }
            groups.push(vec![i]);
        } else {
            open.push(i);
            open_bytes += bytes;
            if open_bytes >= min_bucket_bytes {
                groups.push(std::mem::take(&mut open));
                open_bytes = 0;
            }
        }
    }
    if !open.is_empty() {
        groups.push(open);
    }
    groups
}

impl StreamingAllReduce {
    /// A reducer for `depth` layers across `replicas` participants, one
    /// singleton bucket per layer (every layer fires the moment its last
    /// replica contribution arrives).
    pub fn new(depth: usize, replicas: usize, op: ReduceOp) -> StreamingAllReduce {
        StreamingAllReduce::from_groups((0..depth).map(|i| vec![i]).collect(), replicas, op)
    }

    /// A reducer with gradient-bucket fusion: `layer_bytes[i]` is layer
    /// `i`'s parameter-gradient payload in bytes, and consecutive layers
    /// below `min_bucket_bytes` coalesce into shared buckets (see module
    /// docs). Bucketing is a delivery-batching optimization only — the
    /// per-layer fold arithmetic is unchanged, so reduced values are
    /// bit-identical to an unbucketed reducer's.
    pub fn with_buckets(
        layer_bytes: &[usize],
        replicas: usize,
        op: ReduceOp,
        min_bucket_bytes: usize,
    ) -> StreamingAllReduce {
        StreamingAllReduce::from_groups(
            bucket_groups(layer_bytes, min_bucket_bytes),
            replicas,
            op,
        )
    }

    fn from_groups(
        members: Vec<Vec<usize>>,
        replicas: usize,
        op: ReduceOp,
    ) -> StreamingAllReduce {
        assert!(replicas >= 1, "need at least one replica");
        let depth: usize = members.iter().map(|m| m.len()).sum();
        let mut bucket_of = vec![usize::MAX; depth];
        let mut member_pos = vec![usize::MAX; depth];
        for (b, group) in members.iter().enumerate() {
            for (pos, &layer) in group.iter().enumerate() {
                assert!(layer < depth && bucket_of[layer] == usize::MAX);
                bucket_of[layer] = b;
                member_pos[layer] = pos;
            }
        }
        let buckets = members.len();
        StreamingAllReduce {
            replicas,
            op,
            members,
            bucket_of,
            member_pos,
            slots: Mutex::new((0..buckets).map(|_| None).collect()),
            reduce_ns: AtomicU64::new(0),
            reduced: AtomicUsize::new(0),
        }
    }

    /// The participant count this reducer waits for per layer.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Number of reduce buckets (== depth for an unbucketed reducer).
    pub fn bucket_count(&self) -> usize {
        self.members.len()
    }

    /// Submit one replica's gradients for one layer. Returns the reduced
    /// layers this submission completed — empty while the layer's bucket
    /// still waits on other contributions, the bucket's full member list
    /// (ascending layer order, reduced payloads) once this was the last
    /// one; the fold runs on *this* submitter's thread. Each
    /// (layer, replica) pair may be submitted exactly once; payload
    /// arity/shape must agree across replicas (asserted at fold time).
    pub fn submit_bucketed(
        &self,
        layer: usize,
        replica: usize,
        grads: Vec<Tensor>,
    ) -> Vec<(usize, Vec<Tensor>)> {
        assert!(replica < self.replicas, "replica {replica} out of range");
        assert!(
            layer < self.bucket_of.len(),
            "layer {layer} out of range"
        );
        let bucket = self.bucket_of[layer];
        let pos = self.member_pos[layer];
        let n_members = self.members[bucket].len();
        let slot_parts = {
            let mut slots = lock(&self.slots);
            let slot = slots[bucket].get_or_insert_with(|| BucketSlot {
                parts: (0..n_members)
                    .map(|_| (0..self.replicas).map(|_| None).collect())
                    .collect(),
                got: 0,
            });
            assert!(
                slot.parts[pos][replica].is_none(),
                "duplicate submission for layer {layer} from replica {replica}"
            );
            slot.parts[pos][replica] = Some(grads);
            slot.got += 1;
            if slot.got < n_members * self.replicas {
                return Vec::new();
            }
            // Complete: take the slot out so its memory is released the
            // moment the fold finishes, and fold *outside* the lock so
            // other buckets keep streaming through meanwhile.
            slots[bucket].take().expect("slot just filled").parts
        };
        let t = Timer::start();
        let _sp = crate::span!("reduce.bucket", bucket = bucket);
        let mut out = Vec::with_capacity(n_members);
        for (pos, layer_parts) in slot_parts.into_iter().enumerate() {
            let member_layer = self.members[bucket][pos];
            let _sl = crate::span!("reduce.layer", layer = member_layer);
            let mut parts = layer_parts.into_iter().map(|p| p.expect("counted part"));
            let mut acc = parts.next().expect("replicas >= 1");
            for part in parts {
                assert_eq!(
                    acc.len(),
                    part.len(),
                    "layer {member_layer}: gradient arity differs across replicas"
                );
                for (a, b) in acc.iter_mut().zip(&part) {
                    assert_eq!(
                        a.shape(),
                        b.shape(),
                        "layer {member_layer}: gradient shape differs across replicas"
                    );
                    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
                        *x += y;
                    }
                }
            }
            if self.op == ReduceOp::Mean && self.replicas > 1 {
                let inv = 1.0 / self.replicas as f32;
                for a in acc.iter_mut() {
                    for x in a.data_mut() {
                        *x *= inv;
                    }
                }
            }
            out.push((member_layer, acc));
        }
        out.sort_by_key(|(layer, _)| *layer);
        self.reduce_ns
            .fetch_add((t.elapsed_s() * 1e9) as u64, Ordering::Relaxed);
        self.reduced.fetch_add(out.len(), Ordering::Relaxed);
        out
    }

    /// Singleton-bucket convenience form of [`Self::submit_bucketed`]:
    /// `Some(reduced)` when this submission completed the layer, `None`
    /// before. Panics on reducers built with multi-layer buckets — those
    /// deliver several layers per completion, so callers must use
    /// [`Self::submit_bucketed`].
    pub fn submit(
        &self,
        layer: usize,
        replica: usize,
        grads: Vec<Tensor>,
    ) -> Option<Vec<Tensor>> {
        assert!(
            layer < self.bucket_of.len() && self.members[self.bucket_of[layer]].len() == 1,
            "submit() requires a singleton bucket for layer {layer}; \
             use submit_bucketed() on fused reducers"
        );
        let mut out = self.submit_bucketed(layer, replica, grads);
        debug_assert!(out.len() <= 1);
        out.pop().map(|(_, g)| g)
    }

    /// Wall-clock spent folding, summed over all completed layers.
    pub fn reduce_seconds(&self) -> f64 {
        self.reduce_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Layers fully reduced so far.
    pub fn reduced_layers(&self) -> usize {
        self.reduced.load(Ordering::Relaxed)
    }

    /// Layers with at least one pending (un-reduced) contribution — zero
    /// after a healthy step; non-zero means a replica died mid-stream.
    pub fn pending_layers(&self) -> usize {
        lock(&self.slots)
            .iter()
            .filter_map(|s| s.as_ref())
            .map(|slot| {
                slot.parts
                    .iter()
                    .filter(|m| m.iter().any(|p| p.is_some()))
                    .count()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(vals: &[f32]) -> Vec<Tensor> {
        vec![Tensor::from_vec(vals.to_vec(), &[vals.len()])]
    }

    #[test]
    fn reduces_in_replica_order_when_complete() {
        let r = StreamingAllReduce::new(2, 3, ReduceOp::Sum);
        assert!(r.submit(1, 2, grad(&[1.0, 2.0])).is_none());
        assert!(r.submit(1, 0, grad(&[10.0, 20.0])).is_none());
        assert_eq!(r.pending_layers(), 1);
        let out = r.submit(1, 1, grad(&[100.0, 200.0])).expect("complete");
        assert_eq!(out[0].data(), &[111.0, 222.0]);
        assert_eq!(r.pending_layers(), 0);
        assert_eq!(r.reduced_layers(), 1);
    }

    #[test]
    fn mean_divides_by_replicas() {
        let r = StreamingAllReduce::new(1, 4, ReduceOp::Mean);
        for rep in 0..3 {
            assert!(r.submit(0, rep, grad(&[8.0])).is_none());
        }
        let out = r.submit(0, 3, grad(&[8.0])).unwrap();
        assert_eq!(out[0].data(), &[8.0], "mean of equal parts is exact");
    }

    #[test]
    fn single_replica_is_identity() {
        let r = StreamingAllReduce::new(1, 1, ReduceOp::Mean);
        let out = r.submit(0, 0, grad(&[3.5, -1.25])).unwrap();
        assert_eq!(out[0].data(), &[3.5, -1.25]);
    }

    #[test]
    fn arrival_order_does_not_change_bits() {
        let fold = |order: &[usize]| {
            let r = StreamingAllReduce::new(1, 3, ReduceOp::Sum);
            let mut out = None;
            for &rep in order {
                // Distinct, order-sensitive-if-misfolded payloads.
                let v = [(rep as f32 + 1.0) * 0.1, (rep as f32 + 1.0) * 100.0];
                if let Some(g) = r.submit(0, rep, grad(&v)) {
                    out = Some(g);
                }
            }
            out.expect("all replicas submitted")
        };
        let a = fold(&[0, 1, 2]);
        let b = fold(&[2, 0, 1]);
        assert_eq!(a[0].data(), b[0].data(), "fold must be replica-ordered");
    }

    #[test]
    #[should_panic(expected = "duplicate submission")]
    fn duplicate_submission_panics() {
        let r = StreamingAllReduce::new(1, 2, ReduceOp::Sum);
        let _ = r.submit(0, 0, grad(&[1.0]));
        let _ = r.submit(0, 0, grad(&[1.0]));
    }

    #[test]
    fn empty_gradsets_reduce_to_empty() {
        // Parameter-free layers stream empty sets uniformly.
        let r = StreamingAllReduce::new(1, 2, ReduceOp::Mean);
        assert!(r.submit(0, 1, Vec::new()).is_none());
        let out = r.submit(0, 0, Vec::new()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn bucket_groups_coalesce_small_layers_only() {
        // bytes: zero-param layers singleton, big layers singleton,
        // consecutive small layers fused until the threshold.
        let groups = bucket_groups(&[0, 100, 100, 4096, 0, 100, 100], 256);
        assert_eq!(
            groups,
            vec![
                vec![0],
                vec![1, 2], // closed by the big layer 3
                vec![3],
                vec![4],
                vec![5, 6], // tail flush
            ]
        );
        // Threshold closes a bucket as soon as it is reached.
        let groups = bucket_groups(&[100, 200, 100, 100], 256);
        assert_eq!(groups, vec![vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn bucketed_fold_bit_identical_to_unbucketed() {
        // Same submissions through a fused reducer and a singleton one:
        // the delivered payloads must be bit-identical per layer, only
        // the delivery batching differs.
        let bytes = [8usize, 8, 8]; // all below the threshold -> one bucket
        let payload = |layer: usize, rep: usize| {
            grad(&[
                0.1 * (layer as f32 + 1.0) + rep as f32,
                100.0 / (layer as f32 + 3.0) - rep as f32,
            ])
        };
        let plain = StreamingAllReduce::new(3, 2, ReduceOp::Mean);
        let mut expect: Vec<Option<Vec<Tensor>>> = vec![None, None, None];
        for layer in 0..3 {
            for rep in 0..2 {
                if let Some(g) = plain.submit(layer, rep, payload(layer, rep)) {
                    expect[layer] = Some(g);
                }
            }
        }
        let fused = StreamingAllReduce::with_buckets(&bytes, 2, ReduceOp::Mean, 64);
        assert_eq!(fused.bucket_count(), 1);
        let mut delivered = 0usize;
        for layer in 0..3 {
            for rep in 0..2 {
                for (li, g) in fused.submit_bucketed(layer, rep, payload(layer, rep)) {
                    let e = expect[li].as_ref().unwrap();
                    assert_eq!(g.len(), e.len());
                    for (a, b) in g.iter().zip(e) {
                        assert_eq!(a.data(), b.data(), "layer {li}: fused fold diverged");
                    }
                    delivered += 1;
                }
            }
        }
        assert_eq!(delivered, 3, "whole bucket delivered on the last submission");
        assert_eq!(fused.reduced_layers(), 3);
        assert_eq!(fused.pending_layers(), 0);
    }

    #[test]
    fn bucket_waits_for_every_member_and_replica() {
        let fused = StreamingAllReduce::with_buckets(&[8, 8], 2, ReduceOp::Sum, 64);
        assert!(fused.submit_bucketed(0, 0, grad(&[1.0])).is_empty());
        assert!(fused.submit_bucketed(1, 0, grad(&[2.0])).is_empty());
        assert!(fused.submit_bucketed(0, 1, grad(&[3.0])).is_empty());
        assert_eq!(fused.pending_layers(), 2);
        let out = fused.submit_bucketed(1, 1, grad(&[4.0]));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, 0);
        assert_eq!(out[0].1[0].data(), &[4.0]);
        assert_eq!(out[1].0, 1);
        assert_eq!(out[1].1[0].data(), &[6.0]);
    }

    #[test]
    #[should_panic(expected = "singleton bucket")]
    fn submit_rejected_on_fused_reducers() {
        let fused = StreamingAllReduce::with_buckets(&[8, 8], 1, ReduceOp::Sum, 64);
        let _ = fused.submit(0, 0, grad(&[1.0]));
    }
}
