//! Streaming deterministic all-reduce over per-layer gradient sets.
//!
//! The paper's mixed-mode sweep makes a layer's parameter gradient
//! available the moment its Phase-III step finishes (§4.3: gradients
//! "need not be stored simultaneously"). [`StreamingAllReduce`] exploits
//! exactly that property for data parallelism: every replica submits each
//! layer's gradient as its engine streams it, and the reduction for a
//! layer fires **on the thread that delivers the last contribution** —
//! overlapped with the other replicas' still-running vijp sweeps instead
//! of waiting for full gradient buffers. Peak footprint is bounded by the
//! in-flight layers' per-replica parts: with replicas running in
//! lockstep (replicas ≤ pool workers, the intended configuration) that
//! is about one layer-gradient per replica. When replicas oversubscribe
//! the pool they serialize per worker and an early replica's whole
//! gradient set parks here until the stragglers deliver — still correct
//! and deterministic, but the memory bound degrades (`ReplicaGroup`
//! warns once in that configuration).
//!
//! Determinism contract (mirrors `runtime::pool`'s): the fold is
//! **replica-ordered**, never arrival-ordered — partials are parked in a
//! per-replica slot and summed `0, 1, …, R−1` once all `R` arrived, so a
//! fixed replica count gives bit-identical results run-to-run regardless
//! of thread scheduling. [`ReduceOp::Mean`] divides by the replica count
//! after the ordered sum; for power-of-two counts the division is exact,
//! so exactly-associative payloads (small integers) reduce bit-equal
//! across replica counts too (`tests/distributed.rs`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::tensor::Tensor;
use crate::util::{lock_ignore_poison as lock, Timer};

/// How per-replica gradients combine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// Plain replica-ordered sum.
    Sum,
    /// Replica-ordered sum scaled by `1/replicas` — the data-parallel
    /// average that makes N equal shards equivalent to the single-replica
    /// full-batch gradient under a per-shard mean loss.
    Mean,
}

/// One layer's partial gradients, parked until every replica reported.
struct LayerSlot {
    parts: Vec<Option<Vec<Tensor>>>,
    got: usize,
}

/// The share-ordered streaming reducer for one gradient step. Cheap to
/// construct (one `Option` per layer); build a fresh one per step.
pub struct StreamingAllReduce {
    replicas: usize,
    op: ReduceOp,
    slots: Mutex<Vec<Option<LayerSlot>>>,
    /// Nanoseconds spent inside gradient folds (the overlap metric the
    /// trainer logs as `reduce_s`).
    reduce_ns: AtomicU64,
    /// Layers fully reduced so far.
    reduced: AtomicUsize,
}

impl StreamingAllReduce {
    /// A reducer for `depth` layers across `replicas` participants.
    pub fn new(depth: usize, replicas: usize, op: ReduceOp) -> StreamingAllReduce {
        assert!(replicas >= 1, "need at least one replica");
        StreamingAllReduce {
            replicas,
            op,
            slots: Mutex::new((0..depth).map(|_| None).collect()),
            reduce_ns: AtomicU64::new(0),
            reduced: AtomicUsize::new(0),
        }
    }

    /// The participant count this reducer waits for per layer.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Submit one replica's gradients for one layer. Returns the reduced
    /// gradients once the final replica's contribution for that layer
    /// arrives (on *that* submitter's thread), `None` before. Each
    /// (layer, replica) pair may be submitted exactly once; payload
    /// arity/shape must agree across replicas (asserted at fold time).
    pub fn submit(
        &self,
        layer: usize,
        replica: usize,
        grads: Vec<Tensor>,
    ) -> Option<Vec<Tensor>> {
        assert!(replica < self.replicas, "replica {replica} out of range");
        let slot_parts = {
            let mut slots = lock(&self.slots);
            assert!(layer < slots.len(), "layer {layer} out of range");
            let slot = slots[layer].get_or_insert_with(|| LayerSlot {
                parts: (0..self.replicas).map(|_| None).collect(),
                got: 0,
            });
            assert!(
                slot.parts[replica].is_none(),
                "duplicate submission for layer {layer} from replica {replica}"
            );
            slot.parts[replica] = Some(grads);
            slot.got += 1;
            if slot.got < self.replicas {
                return None;
            }
            // Complete: take the slot out so its memory is released the
            // moment the fold finishes, and fold *outside* the lock so
            // other layers keep streaming through meanwhile.
            slots[layer].take().expect("slot just filled").parts
        };
        let t = Timer::start();
        let mut parts = slot_parts.into_iter().map(|p| p.expect("counted part"));
        let mut acc = parts.next().expect("replicas >= 1");
        for part in parts {
            assert_eq!(
                acc.len(),
                part.len(),
                "layer {layer}: gradient arity differs across replicas"
            );
            for (a, b) in acc.iter_mut().zip(&part) {
                assert_eq!(
                    a.shape(),
                    b.shape(),
                    "layer {layer}: gradient shape differs across replicas"
                );
                for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
                    *x += y;
                }
            }
        }
        if self.op == ReduceOp::Mean && self.replicas > 1 {
            let inv = 1.0 / self.replicas as f32;
            for a in acc.iter_mut() {
                for x in a.data_mut() {
                    *x *= inv;
                }
            }
        }
        self.reduce_ns
            .fetch_add((t.elapsed_s() * 1e9) as u64, Ordering::Relaxed);
        self.reduced.fetch_add(1, Ordering::Relaxed);
        Some(acc)
    }

    /// Wall-clock spent folding, summed over all completed layers.
    pub fn reduce_seconds(&self) -> f64 {
        self.reduce_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Layers fully reduced so far.
    pub fn reduced_layers(&self) -> usize {
        self.reduced.load(Ordering::Relaxed)
    }

    /// Layers with at least one pending (un-reduced) contribution — zero
    /// after a healthy step; non-zero means a replica died mid-stream.
    pub fn pending_layers(&self) -> usize {
        lock(&self.slots).iter().filter(|s| s.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(vals: &[f32]) -> Vec<Tensor> {
        vec![Tensor::from_vec(vals.to_vec(), &[vals.len()])]
    }

    #[test]
    fn reduces_in_replica_order_when_complete() {
        let r = StreamingAllReduce::new(2, 3, ReduceOp::Sum);
        assert!(r.submit(1, 2, grad(&[1.0, 2.0])).is_none());
        assert!(r.submit(1, 0, grad(&[10.0, 20.0])).is_none());
        assert_eq!(r.pending_layers(), 1);
        let out = r.submit(1, 1, grad(&[100.0, 200.0])).expect("complete");
        assert_eq!(out[0].data(), &[111.0, 222.0]);
        assert_eq!(r.pending_layers(), 0);
        assert_eq!(r.reduced_layers(), 1);
    }

    #[test]
    fn mean_divides_by_replicas() {
        let r = StreamingAllReduce::new(1, 4, ReduceOp::Mean);
        for rep in 0..3 {
            assert!(r.submit(0, rep, grad(&[8.0])).is_none());
        }
        let out = r.submit(0, 3, grad(&[8.0])).unwrap();
        assert_eq!(out[0].data(), &[8.0], "mean of equal parts is exact");
    }

    #[test]
    fn single_replica_is_identity() {
        let r = StreamingAllReduce::new(1, 1, ReduceOp::Mean);
        let out = r.submit(0, 0, grad(&[3.5, -1.25])).unwrap();
        assert_eq!(out[0].data(), &[3.5, -1.25]);
    }

    #[test]
    fn arrival_order_does_not_change_bits() {
        let fold = |order: &[usize]| {
            let r = StreamingAllReduce::new(1, 3, ReduceOp::Sum);
            let mut out = None;
            for &rep in order {
                // Distinct, order-sensitive-if-misfolded payloads.
                let v = [(rep as f32 + 1.0) * 0.1, (rep as f32 + 1.0) * 100.0];
                if let Some(g) = r.submit(0, rep, grad(&v)) {
                    out = Some(g);
                }
            }
            out.expect("all replicas submitted")
        };
        let a = fold(&[0, 1, 2]);
        let b = fold(&[2, 0, 1]);
        assert_eq!(a[0].data(), b[0].data(), "fold must be replica-ordered");
    }

    #[test]
    #[should_panic(expected = "duplicate submission")]
    fn duplicate_submission_panics() {
        let r = StreamingAllReduce::new(1, 2, ReduceOp::Sum);
        let _ = r.submit(0, 0, grad(&[1.0]));
        let _ = r.submit(0, 0, grad(&[1.0]));
    }

    #[test]
    fn empty_gradsets_reduce_to_empty() {
        // Parameter-free layers stream empty sets uniformly.
        let r = StreamingAllReduce::new(1, 2, ReduceOp::Mean);
        assert!(r.submit(0, 1, Vec::new()).is_none());
        let out = r.submit(0, 0, Vec::new()).unwrap();
        assert!(out.is_empty());
    }
}
