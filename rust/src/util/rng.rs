//! Deterministic PRNG: PCG64 (XSL-RR) seeded via SplitMix64, with uniform,
//! normal (Box–Muller) and categorical sampling. Substrate for the missing
//! `rand` crate; deterministic across platforms so tests and experiments
//! are reproducible.

/// A PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Derive a splittable stream seed from a base seed and a list of stream
/// labels — the distributed pipeline's "seed ⊕ epoch ⊕ shard" streams.
/// Each label is golden-ratio-spread and diffused through SplitMix64, so
/// neighbouring `(epoch, shard)` pairs yield decorrelated streams while
/// the result stays a pure function of `(seed, labels)` — independent of
/// how much randomness any live generator has consumed.
pub fn stream_seed(seed: u64, labels: &[u64]) -> u64 {
    let mut acc = seed;
    for &label in labels {
        let mut s = acc ^ label.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        acc = splitmix64(&mut s);
    }
    acc
}

impl Rng {
    /// Create a generator from a 64-bit seed (stream id fixed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let i0 = splitmix64(&mut sm) as u128;
        let i1 = splitmix64(&mut sm) as u128;
        let mut rng = Rng {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
            gauss_spare: None,
        };
        // Warm up.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Vector of standard normals as f32.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }

    /// Vector of uniform f32 in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.uniform_range(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn stream_seed_is_pure_and_label_sensitive() {
        assert_eq!(stream_seed(5, &[1, 2]), stream_seed(5, &[1, 2]));
        assert_ne!(stream_seed(5, &[1, 2]), stream_seed(5, &[2, 1]));
        assert_ne!(stream_seed(5, &[1, 2]), stream_seed(6, &[1, 2]));
        assert_ne!(stream_seed(5, &[0]), stream_seed(5, &[1]));
        assert_eq!(stream_seed(7, &[]), 7, "no labels = base seed");
    }

    #[test]
    fn fork_independent() {
        let mut a = Rng::new(5);
        let mut c = a.fork();
        let x = a.next_u64();
        let y = c.next_u64();
        assert_ne!(x, y);
    }
}
