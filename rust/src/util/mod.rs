//! In-tree substrates: PRNG, JSON codec, timing, logging.
//!
//! The offline build image vendors only `xla`/`anyhow`/`thiserror`, so the
//! usual ecosystem crates (rand, serde/serde_json, criterion) are rebuilt
//! here at the size this project needs.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;

/// Poison-tolerant mutex lock, shared by every process-global structure
/// (worker pool, streaming reducer, gradient collectors): a panicking
/// holder — e.g. an injected test panic on a pool worker — must not
/// brick later users of the lock.
pub fn lock_ignore_poison<T>(
    m: &std::sync::Mutex<T>,
) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}
