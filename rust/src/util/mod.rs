//! In-tree substrates: PRNG, JSON codec, timing, logging.
//!
//! The offline build image vendors only `xla`/`anyhow`/`thiserror`, so the
//! usual ecosystem crates (rand, serde/serde_json, criterion) are rebuilt
//! here at the size this project needs.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;
