//! Wall-clock timing helpers and a tiny benchmark runner (substrate for the
//! missing criterion crate): warm-up iterations followed by timed runs,
//! reporting median / p10 / p90.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Result of a [`bench`] run, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub mean: f64,
    pub iters: usize,
}

impl BenchStats {
    pub fn median_ms(&self) -> f64 {
        self.median * 1e3
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| times[((times.len() - 1) as f64 * p).round() as usize];
    BenchStats {
        median: pct(0.5),
        p10: pct(0.1),
        p90: pct(0.9),
        mean: times.iter().sum::<f64>() / times.len() as f64,
        iters,
    }
}

/// Adaptive bench: choose an iteration count so total time ≈ `budget_s`,
/// with at least `min_iters` iterations.
pub fn bench_auto<F: FnMut()>(budget_s: f64, min_iters: usize, mut f: F) -> BenchStats {
    let t = Timer::start();
    f(); // first call (also warms caches / lazy init)
    let once = t.elapsed_s().max(1e-9);
    let iters = ((budget_s / once).floor() as usize).clamp(min_iters, 1000);
    bench(1.min(iters), iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let stats = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.p10 <= stats.median && stats.median <= stats.p90);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
    }
}
