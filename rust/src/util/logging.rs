//! Leveled stderr logging + JSONL metric sinks. Substrate for the missing
//! tracing/log crates; intentionally tiny.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::json::Json;

/// Log levels, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

/// Set the global minimum level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Log a message to stderr if the level is enabled.
pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}

/// Append-only JSONL metrics writer (one JSON object per line).
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    pub fn create(path: &Path) -> anyhow::Result<JsonlWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(JsonlWriter {
            out: BufWriter::new(file),
        })
    }

    pub fn write(&mut self, record: &Json) -> anyhow::Result<()> {
        writeln!(self.out, "{}", record.to_string())?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

impl Drop for JsonlWriter {
    /// Best-effort flush so a crash-killed or early-returning owner
    /// doesn't lose the buffered tail of the metrics stream. (BufWriter
    /// also flushes on drop, but silently and only through its own
    /// buffer — this keeps the behavior explicit and panic-safe.)
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("moonwalk_log_test");
        let path = dir.join("m.jsonl");
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            w.write(&Json::from_pairs(vec![("step", 1usize.into())]))
                .unwrap();
            w.write(&Json::from_pairs(vec![("step", 2usize.into())]))
                .unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            Json::parse(lines[1]).unwrap().req_usize("step").unwrap(),
            2
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
