//! Minimal JSON codec (parser + writer), substrate for the missing
//! serde/serde_json crates. Supports the full JSON data model; numbers are
//! stored as f64 (adequate for configs, manifests and metric logs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    // ----- accessors ----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Insert into an object (panics if not an object — programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Required typed accessors used by config loading.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).as_usize().unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).as_f64().unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).as_str().unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).as_bool().unwrap_or(default)
    }

    // ----- parsing ------------------------------------------------------
    pub fn parse(src: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are unlikely in our configs;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: find char boundary.
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::from_pairs(vec![
            ("name", "conv_fwd".into()),
            ("shape", vec![4usize, 32, 32, 3].into()),
            ("lr", 0.001.into()),
            ("nested", Json::from_pairs(vec![("k", 3usize.into())])),
        ]);
        let v2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_usize("f").is_err());
        assert!(v.req_str("missing").is_err());
        assert_eq!(v.opt_usize("missing", 7), 7);
    }
}
