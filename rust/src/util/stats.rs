//! Small statistics helpers used by the bench harness and Table-1 scaling
//! analysis (log–log slope fits).

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Ordinary least squares fit `y = a + b*x`; returns `(a, b)`.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = num / den;
    (my - b * mx, b)
}

/// Log–log slope: the empirical scaling exponent of `y(x) ~ x^e`.
/// Used to verify Table 1's asymptotics from measured sweeps.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.max(1e-300).ln()).collect();
    linfit(&lx, &ly).1
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linfit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_recovers_exponent() {
        let xs: Vec<f64> = (1..=8).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powi(2)).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
    }
}
