//! **Pure-forward Moonwalk** (paper §4.4): obtain the seed cotangent
//! entirely in forward mode — one jvp pass per input dimension, each
//! propagating a basis tangent `e_j` to the loss — then run the same
//! Phase III as mixed-mode Moonwalk (vijp + vjp_params). No reverse
//! sweep anywhere; memory `O(Mx + Mθ)`, time `O(n³L + ndL)` (Table 1):
//! "most suitable when the input dimension is small or when memory
//! constraints dominate compute considerations".
//!
//! Networks may start with a parameter-free non-submersive prefix (the
//! channel-expanding Upsample); the seed cotangent is then computed at
//! the prefix boundary instead of the raw input, so Phase III can cross
//! every parameterized layer with vijp alone.

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::{Loss, ResidualKind, Submersivity};
use crate::tensor::Tensor;

/// Pure-forward Moonwalk.
#[derive(Default)]
pub struct PureMoonwalk;

impl PureMoonwalk {
    /// First layer index from which the rest of the network is
    /// submersive; layers before it must be parameter-free (they are
    /// skipped by seeding past them).
    fn seed_index(&self, net: &Network) -> anyhow::Result<usize> {
        let audit = net.audit();
        let seed = audit
            .iter()
            .rposition(|s| !s.is_submersive())
            .map(|i| i + 1)
            .unwrap_or(0);
        for (i, sub) in audit.iter().enumerate().take(seed) {
            if net.layers[i].n_params() > 0 {
                let reason = match sub {
                    Submersivity::NonSubmersive { reason, .. } => reason.clone(),
                    _ => "earlier non-submersive layer blocks Phase III".into(),
                };
                anyhow::bail!(
                    "pure-forward Moonwalk requires a submersive suffix covering all \
                     parameterized layers; layer {i} (`{}`) violates it: {reason}",
                    net.layers[i].name()
                );
            }
        }
        Ok(seed)
    }
}

impl GradEngine for PureMoonwalk {
    fn name(&self) -> String {
        "pure_moonwalk".into()
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        let seed = self.seed_index(net)?;

        // Forward to the seed boundary (kept: one activation).
        let mut x_seed = x0.clone();
        for layer in &net.layers[..seed] {
            x_seed = layer.forward(&x_seed);
        }

        // Loss value via a plain forward continuation.
        let mut y = x_seed.clone();
        for layer in &net.layers[seed..] {
            y = layer.forward(&y);
        }
        let loss_val = loss.value(&y);
        drop(y);

        // Phase I/II (forward-mode): h_seed[j] = ∂J/∂x_seed[j], one jvp
        // pass per element of the seed activation.
        let n = x_seed.len();
        let mut h_seed = Tensor::zeros(x_seed.shape());
        for j in 0..n {
            let mut u = Tensor::zeros(x_seed.shape());
            u.data_mut()[j] = 1.0;
            let mut x = x_seed.clone();
            for layer in &net.layers[seed..] {
                let u_next = layer.jvp_input(&x, &u);
                x = layer.forward(&x);
                u = u_next;
            }
            h_seed.data_mut()[j] = loss.jvp(&x, &u);
        }

        // Phase III: identical to mixed-mode Moonwalk from the seed.
        let mut x = x_seed;
        let mut h = h_seed;
        for (off, layer) in net.layers[seed..].iter().enumerate() {
            let i = seed + off;
            let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
            let h_out = layer
                .vijp(&res, &h)
                .map_err(|e| anyhow::anyhow!("Phase III vijp failed at layer {i}: {e}"))?;
            if layer.n_params() > 0 {
                sink(i, layer.vjp_params(&x, &h_out));
            }
            x = y;
            h = h_out;
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_cnn2d, build_mlp, SubmersiveCnn2dSpec};
    use crate::nn::MeanLoss;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn matches_backprop_on_micro_mlp() {
        let mut rng = Rng::new(0);
        let net = build_mlp(&[6, 4, 3], 0.1, &mut rng);
        let x = Tensor::randn(&[2, 6], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let pm = PureMoonwalk.compute(&net, &x, &MeanLoss).unwrap();
        assert!((bp.loss - pm.loss).abs() < 1e-6);
        for (a, b) in bp.grads.iter().flatten().zip(pm.grads.iter().flatten()) {
            assert_close(b, a, 1e-2, "pure moonwalk grads");
        }
    }

    #[test]
    fn seeds_past_upsample_prefix() {
        let mut rng = Rng::new(1);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 8,
            depth: 1,
            channels: 3,
            cin: 2,
            classes: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 8, 8, 2], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let pm = PureMoonwalk.compute(&net, &x, &MeanLoss).unwrap();
        for (a, b) in bp.grads.iter().flatten().zip(pm.grads.iter().flatten()) {
            assert_close(b, a, 1e-2, "seeded pure moonwalk");
        }
    }

    #[test]
    fn rejects_parameterized_non_submersive_prefix() {
        // Unconstrained convolutions are non-submersive AND parameterized:
        // the pure-forward variant has no backward pass to checkpoint
        // cotangents, so it must refuse.
        let mut rng = Rng::new(2);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 8,
            depth: 1,
            channels: 3,
            cin: 2,
            constrained: false,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 8, 8, 2], 1.0, &mut rng);
        assert!(PureMoonwalk.compute(&net, &x, &MeanLoss).is_err());
    }
}
