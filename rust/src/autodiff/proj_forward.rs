//! Projected forward gradients (Baydin et al. 2022, "Gradients without
//! Backpropagation"; paper §11 "ProjForward"): sample a random parameter
//! tangent `u`, push it through the network in a single jvp pass
//! concurrently with the forward evaluation, and estimate
//! `∇θJ ≈ (∇θJ·u) u`. Unbiased but **high variance** — the ✓ in
//! Table 1's High-variance column, and the reason the paper's exact
//! Moonwalk is preferable when applicable.
//!
//! Time matches Backprop asymptotically (`O(n²L + ndL)`), memory is
//! `O(Mx + Mθ)` plus the tangent set (same size as the parameters).

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::Loss;
use crate::tensor::{ops, Tensor};
use crate::util::Rng;
use std::sync::Mutex;

/// Forward-gradient estimator with `samples` averaged probes.
pub struct ProjForward {
    pub samples: usize,
    seed: u64,
    /// Per-call counter so repeated calls use fresh tangents.
    calls: Mutex<u64>,
}

impl ProjForward {
    pub fn new(samples: usize, seed: u64) -> ProjForward {
        assert!(samples > 0);
        ProjForward {
            samples,
            seed,
            calls: Mutex::new(0),
        }
    }
}

impl GradEngine for ProjForward {
    fn name(&self) -> String {
        format!("projforward(s={})", self.samples)
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        let call_id = {
            let mut c = self.calls.lock().unwrap();
            *c += 1;
            *c
        };
        let mut rng = Rng::new(self.seed ^ (call_id.wrapping_mul(0x9e3779b97f4a7c15)));

        // Accumulated estimates per layer/param.
        let mut acc: Vec<Vec<Tensor>> = net
            .layers
            .iter()
            .map(|l| l.params().iter().map(|p| Tensor::zeros(p.shape())).collect())
            .collect();
        let mut loss_val = 0.0;

        for _ in 0..self.samples {
            // Sample a fresh tangent for every parameter.
            let tangents: Vec<Vec<Tensor>> = net
                .layers
                .iter()
                .map(|l| {
                    l.params()
                        .iter()
                        .map(|p| Tensor::randn(p.shape(), 1.0, &mut rng))
                        .collect()
                })
                .collect();

            // Single concurrent forward + jvp pass.
            let mut x = x0.clone();
            let mut u = Tensor::zeros(x0.shape());
            for (li, layer) in net.layers.iter().enumerate() {
                let mut u_next = layer.jvp_input(&x, &u);
                if layer.n_params() > 0 {
                    let up = layer.jvp_params(&x, &tangents[li]);
                    u_next = ops::add(&u_next, &up);
                }
                x = layer.forward(&x);
                u = u_next;
            }
            loss_val = loss.value(&x);
            let s = loss.jvp(&x, &u); // directional derivative ∇J·u

            for (li, t) in tangents.iter().enumerate() {
                for (pi, tp) in t.iter().enumerate() {
                    ops::axpy_inplace(&mut acc[li][pi], s / self.samples as f32, tp);
                }
            }
        }

        for (li, grads) in acc.into_iter().enumerate() {
            if !grads.is_empty() {
                sink(li, grads);
            }
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::build_mlp;
    use crate::nn::MeanLoss;
    use crate::util::Rng as URng;

    /// The estimator is unbiased: averaging many single-sample estimates
    /// must converge toward the true gradient direction (cosine > 0.5 on
    /// a small problem with enough samples).
    #[test]
    fn unbiased_direction() {
        let mut rng = URng::new(0);
        let net = build_mlp(&[6, 5, 3], 0.1, &mut rng);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let pf = ProjForward::new(400, 7).compute(&net, &x, &MeanLoss).unwrap();

        // Flatten and compare directions.
        let flat = |g: &Vec<Vec<Tensor>>| -> Vec<f32> {
            g.iter()
                .flatten()
                .flat_map(|t| t.data().iter().copied())
                .collect()
        };
        let a = flat(&bp.grads);
        let b = flat(&pf.grads);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
        let cos = dot / (na * nb + 1e-12);
        assert!(cos > 0.5, "cosine similarity too low: {cos}");
    }

    /// Single-sample estimates are high-variance (Table 1): the spread of
    /// repeated estimates of one coordinate must be large relative to the
    /// coordinate's value.
    #[test]
    fn high_variance_single_sample() {
        let mut rng = URng::new(1);
        let net = build_mlp(&[6, 5, 3], 0.1, &mut rng);
        let x = Tensor::randn(&[2, 6], 1.0, &mut rng);
        let engine = ProjForward::new(1, 3);
        let mut estimates = Vec::new();
        for _ in 0..20 {
            let r = engine.compute(&net, &x, &MeanLoss).unwrap();
            estimates.push(r.grads[0][0].data()[0]);
        }
        let mean: f32 = estimates.iter().sum::<f32>() / estimates.len() as f32;
        let var: f32 = estimates.iter().map(|e| (e - mean) * (e - mean)).sum::<f32>()
            / estimates.len() as f32;
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let truth = bp.grads[0][0].data()[0];
        assert!(
            var.sqrt() > truth.abs(),
            "expected high variance: std {} vs |g| {}",
            var.sqrt(),
            truth.abs()
        );
    }

    #[test]
    fn fresh_tangents_each_call() {
        let mut rng = URng::new(2);
        let net = build_mlp(&[4, 3], 0.1, &mut rng);
        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
        let engine = ProjForward::new(1, 9);
        let a = engine.compute(&net, &x, &MeanLoss).unwrap();
        let b = engine.compute(&net, &x, &MeanLoss).unwrap();
        assert_ne!(
            a.grads[0][0].data(),
            b.grads[0][0].data(),
            "successive calls must not reuse tangents"
        );
    }
}
