//! Reversible backpropagation (Gomez et al. 2017; paper §11
//! "RevBackprop"): no residuals are stored during the forward pass; in
//! the reverse sweep each layer's *input* is reconstructed from its
//! output via the exact inverse `f⁻¹`, after which vjp proceeds as usual.
//! Memory `O(Mx + Mθ)`, but applicable **only to invertible networks** —
//! the ✗ in Table 1's Submersive column, and the restriction Moonwalk
//! lifts (invertible ⊊ submersive, §1).

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::{Loss, ResidualKind};
use crate::tensor::Tensor;

/// Reversible backprop (invertible architectures only).
pub struct RevBackprop;

impl GradEngine for RevBackprop {
    fn name(&self) -> String {
        "revbackprop".into()
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        // Forward with no storage at all.
        let mut x = x0.clone();
        for layer in &net.layers {
            x = layer.forward(&x);
        }
        let loss_val = loss.value(&x);
        let mut g = loss.grad(&x);

        // Reverse: invert activations layer by layer.
        let mut x_out = x;
        for (i, layer) in net.layers.iter().enumerate().rev() {
            let x_in = layer.inverse(&x_out).map_err(|e| {
                anyhow::anyhow!("RevBackprop inverse failed at layer {i}: {e}")
            })?;
            if layer.n_params() > 0 {
                sink(i, layer.vjp_params(&x_in, &g));
            }
            // Rebuild the (cheap) residual from the reconstructed input.
            let (_, res) = layer.forward_res(&x_in, ResidualKind::Minimal);
            g = layer.vjp_input(&res, &g);
            x_out = x_in;
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_cnn2d, build_invertible_cnn2d, SubmersiveCnn2dSpec};
    use crate::nn::MeanLoss;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn matches_backprop_on_invertible_net() {
        let mut rng = Rng::new(0);
        let net = build_invertible_cnn2d(4, 3, 0.2, &mut rng);
        let x = Tensor::randn(&[2, 6, 6, 4], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let rb = RevBackprop.compute(&net, &x, &MeanLoss).unwrap();
        assert!((bp.loss - rb.loss).abs() < 1e-6);
        for (a, b) in bp.grads.iter().flatten().zip(rb.grads.iter().flatten()) {
            assert_close(b, a, 1e-3, "revbackprop grads");
        }
    }

    #[test]
    fn rejects_non_invertible_net() {
        // The paper's point: strided CNNs are submersive but NOT
        // invertible — RevBackprop cannot handle them, Moonwalk can.
        let mut rng = Rng::new(1);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 4,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 16, 16, 2], 1.0, &mut rng);
        assert!(RevBackprop.compute(&net, &x, &MeanLoss).is_err());
    }

    #[test]
    fn constant_memory_in_depth() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[2, 8, 8, 4], 1.0, &mut rng);
        let mut peaks = Vec::new();
        for depth in [2usize, 6] {
            let net = build_invertible_cnn2d(4, depth, 0.2, &mut rng);
            let (_, mem) = crate::tensor::tracker::measure(|| {
                RevBackprop
                    .compute_streaming(&net, &x, &MeanLoss, &mut |_, _| {})
                    .unwrap()
            });
            peaks.push(mem.peak_extra_bytes as f64);
        }
        // Depth tripled; peak should grow far less than linearly.
        assert!(
            peaks[1] < peaks[0] * 1.5,
            "revbackprop peak should be ~constant in depth: {peaks:?}"
        );
    }
}
