//! **PlannedEngine** — Moonwalk's Phase I–III structure executing a
//! *compiled per-layer plan* (`crate::plan`) instead of the
//! network-global [`super::MoonwalkOpts`] decisions.
//!
//! Where [`super::Moonwalk`] derives one rule for the whole chain
//! (fragment everything fragmental at one block size, checkpoint every
//! break), this engine executes whatever mixed strategy the budgeted
//! planner chose per layer:
//!
//! * `Vijp` — Phase III recovers the output cotangent with vijp
//!   (Eq. 9); nothing stored.
//! * `Fragment { block }` — Phase II captures §5.1 slices at the
//!   layer's own block size; Phase III reconstructs (Alg. 3).
//! * `Residual(Full)` — Phase II checkpoints the full output cotangent
//!   (§4.1); Phase III skips the vijp sweep for this layer entirely.
//! * `Residual(Minimal)` — nothing kept, the cotangent chain breaks
//!   (parameter-free layers only); the next `Residual(Full)` re-anchors
//!   it — the paper's h₁-seed placement falls out of the planner.
//!
//! The plan is compiled lazily from a calibration probe of the concrete
//! input shape on first use and cached per shape (recompiled when the
//! shape changes). Probing never touches global tracker state, so lazy
//! compilation is safe inside an open `tracker::measure` window and
//! deterministic across runs and replicas — the same (network, shape,
//! budget) always executes the same plan. Like every engine, gradients
//! stream layer-by-layer in Phase-III (forward) order, so the engine
//! drops into `ReplicaGroup`/`Transport` unchanged.
//!
//! With an **unbounded** budget the planner checkpoints every cotangent,
//! which makes this engine's gradients *bit-identical* to Backprop's:
//! Phase II walks the identical `vjp_input` chain, the checkpoints are
//! the identical per-layer output cotangents, and Phase III's
//! recomputed activations are bit-equal to the tape Backprop stored
//! (`tests/planner.rs` proves the bit-equality).

use std::sync::Mutex;

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::{Fragment, Loss, ResidualKind};
use crate::plan::{self, CompiledPlan, ResidualTier, Strategy};
use crate::tensor::Tensor;
use crate::util::lock_ignore_poison as lock;

/// Construction options for [`PlannedEngine`].
#[derive(Clone, Debug)]
pub struct PlanOpts {
    /// Peak-bytes budget the plan must respect (`None` = unbounded,
    /// which compiles the fastest — all-checkpoint — plan).
    pub budget: Option<usize>,
    /// Fragmental block-size candidates the calibration probe measures
    /// per layer (the planner searches among them).
    pub frag_blocks: Vec<usize>,
}

impl Default for PlanOpts {
    fn default() -> PlanOpts {
        PlanOpts {
            budget: None,
            frag_blocks: plan::DEFAULT_FRAG_BLOCKS.to_vec(),
        }
    }
}

impl PlanOpts {
    /// Resolve options from the environment: `MOONWALK_BUDGET` (bytes)
    /// sets the budget when parseable (the env spelling of the CLI's
    /// `--budget`). This is how `engine_by_name("planned")` — and the
    /// replica worker subprocesses it spawns — pick up the budget
    /// without a dedicated constructor argument.
    pub fn from_env() -> PlanOpts {
        let mut opts = PlanOpts::default();
        if let Ok(v) = std::env::var("MOONWALK_BUDGET") {
            match v.trim().parse::<usize>() {
                Ok(b) if b > 0 => opts.budget = Some(b),
                _ => {
                    crate::log_warn!(
                        "MOONWALK_BUDGET=`{v}` is not a positive byte count; ignoring"
                    );
                }
            }
        }
        opts
    }
}

/// A compiled plan cached for one concrete (network, input shape) pair.
/// The network is identified by a per-layer fingerprint — the cache must
/// not serve a plan compiled for a *different* architecture that happens
/// to share the input shape and depth.
struct CachedPlan {
    in_shape: Vec<usize>,
    fingerprint: Vec<(String, usize)>,
    plan: CompiledPlan,
    probes: Vec<plan::LayerProbe>,
}

/// Per-layer identity the plan cache is keyed on: layer labels carry the
/// full geometry (kernel/stride/pad/channels), parameter counts catch
/// the rest.
fn net_fingerprint(net: &Network) -> Vec<(String, usize)> {
    net.layers
        .iter()
        .map(|l| (l.name(), l.n_params()))
        .collect()
}

/// The budgeted mixed-strategy gradient engine (see module docs).
pub struct PlannedEngine {
    /// Budget and probe options the plans are compiled under.
    pub opts: PlanOpts,
    cache: Mutex<Option<CachedPlan>>,
}

/// What Phase II parked for Phase III under the compiled plan.
enum Aid {
    None,
    Fragment(Fragment),
    Checkpoint(Tensor),
}

impl PlannedEngine {
    /// An engine compiling plans under `opts`.
    pub fn new(opts: PlanOpts) -> PlannedEngine {
        PlannedEngine {
            opts,
            cache: Mutex::new(None),
        }
    }

    /// Convenience constructor: default probe candidates, explicit
    /// budget (`None` = unbounded).
    pub fn with_budget(budget: Option<usize>) -> PlannedEngine {
        PlannedEngine::new(PlanOpts {
            budget,
            ..Default::default()
        })
    }

    /// Compile (or fetch the cached) plan for `net` on `in_shape` and
    /// return a copy — the eager entry the CLI and tests use to print
    /// the plan table and warm the cache *outside* any measurement
    /// window.
    pub fn prepare(&self, net: &Network, in_shape: &[usize]) -> anyhow::Result<CompiledPlan> {
        let mut cache = lock(&self.cache);
        let fingerprint = net_fingerprint(net);
        if let Some(c) = cache.as_ref() {
            if c.in_shape == in_shape && c.fingerprint == fingerprint {
                return Ok(c.plan.clone());
            }
        }
        let probes = plan::probe_network(net, in_shape, &self.opts.frag_blocks)?;
        let compiled = plan::compile(&probes, self.opts.budget)?;
        let out = compiled.clone();
        *cache = Some(CachedPlan {
            in_shape: in_shape.to_vec(),
            fingerprint,
            plan: compiled,
            probes,
        });
        Ok(out)
    }

    /// The probe's summary table for the cached/compiled plan (compiles
    /// if needed) — what `moonwalk train --engine planned` prints. Uses
    /// the probes cached beside the plan; no re-probing.
    pub fn plan_table(&self, net: &Network, in_shape: &[usize]) -> anyhow::Result<String> {
        let compiled = self.prepare(net, in_shape)?;
        let cache = lock(&self.cache);
        let cached = cache.as_ref().expect("prepare just populated the cache");
        Ok(plan::summary_table(&compiled, &cached.probes))
    }
}

impl GradEngine for PlannedEngine {
    fn name(&self) -> String {
        match self.opts.budget {
            Some(b) => format!("planned(budget={b})"),
            None => "planned".into(),
        }
    }

    fn planned_peak_bytes(&self) -> Option<usize> {
        lock(&self.cache).as_ref().map(|c| c.plan.planned_peak)
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        let compiled = self.prepare(net, x0.shape())?;
        anyhow::ensure!(
            compiled.decisions.len() == net.depth(),
            "plan depth {} does not match network depth {}",
            compiled.decisions.len(),
            net.depth()
        );

        // Phase I: forward with minimal residuals only (identical to
        // Moonwalk — the plan only changes what Phase II preserves).
        let mut residuals = Vec::with_capacity(net.depth());
        let mut x = x0.clone();
        {
            let _sp = crate::span!("planned.phase1");
            for (i, layer) in net.layers.iter().enumerate() {
                let _sl = crate::span!("phase1.forward", layer = i);
                let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
                residuals.push(Some(res));
                x = y;
            }
        }
        let loss_val = loss.value(&x);

        // Phase II: reverse cotangent sweep, parking per-layer aids as
        // the plan dictates. The next cotangent is computed *before* a
        // checkpoint parks `h`, so the checkpoint is a move, not a clone
        // — bit-identical, one fewer live activation per checkpointed
        // layer, and no copy (this is the all-layers case at an
        // unbounded budget).
        let mut aids: Vec<Aid> = (0..net.depth()).map(|_| Aid::None).collect();
        let mut h = loss.grad(&x);
        drop(x);
        {
            let _sp = crate::span!("planned.phase2");
            for (i, layer) in net.layers.iter().enumerate().rev() {
                let _sl = crate::span!("phase2.cotangent", layer = i);
                let res = residuals[i].take().expect("consumed once");
                let h_next = layer.vjp_input(&res, &h);
                aids[i] = match compiled.decisions[i].strategy {
                    Strategy::Vijp | Strategy::Residual(ResidualTier::Minimal) => Aid::None,
                    Strategy::Fragment { block } => {
                        Aid::Fragment(layer.fragment_capture(&h, block).map_err(|e| {
                            anyhow::anyhow!("planned fragment capture failed at layer {i}: {e}")
                        })?)
                    }
                    Strategy::Residual(ResidualTier::Full) => Aid::Checkpoint(h),
                };
                h = h_next;
            }
        }

        // Phase III: forward sweep — recompute activations, obtain each
        // layer's output cotangent per its strategy, emit parameter
        // gradients, drop everything before moving on.
        let mut x = x0.clone();
        let mut h = Some(h);
        let _sp = crate::span!("planned.phase3");
        for (i, layer) in net.layers.iter().enumerate() {
            let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
            let strategy = compiled.decisions[i].strategy;
            // Take the input cotangent out of the chain slot so it drops
            // the moment the output cotangent exists — `vjp_params`'s
            // scratch leases must not stack on top of a cotangent the
            // layer no longer needs (the planner's conservative transient
            // bound counts on this).
            let h_in = h.take();
            let h_out = match (std::mem::replace(&mut aids[i], Aid::None), strategy) {
                (Aid::Checkpoint(ck), _) => {
                    crate::obs::span::instant("phase3.checkpoint", Some(("layer", i as i64)));
                    Some(ck)
                }
                (Aid::Fragment(frag), _) => {
                    let _sf = crate::span!("phase3.fragment", layer = i);
                    let h_in = h_in.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("planned fragment at layer {i} needs an intact chain")
                    })?;
                    Some(layer.fragment_reconstruct(&frag, h_in).map_err(|e| {
                        anyhow::anyhow!("planned reconstruction failed at layer {i}: {e}")
                    })?)
                }
                (Aid::None, Strategy::Residual(ResidualTier::Minimal)) => None,
                (Aid::None, _) => {
                    let _sv = crate::span!("phase3.vijp", layer = i);
                    let h_in = h_in.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("planned vijp at layer {i} needs an intact chain")
                    })?;
                    Some(layer.vijp(&res, h_in).map_err(|e| {
                        anyhow::anyhow!("planned Phase III vijp failed at layer {i}: {e}")
                    })?)
                }
            };
            drop(h_in);
            if layer.n_params() > 0 {
                let _sg = crate::span!("phase3.vjp_params", layer = i);
                let h_out = h_out
                    .as_ref()
                    .expect("validated plans anchor parameterized layers");
                sink(i, layer.vjp_params(&x, h_out));
            }
            x = y;
            h = h_out;
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use crate::nn::MeanLoss;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    fn small_net(seed: u64, depth: usize) -> (Network, Tensor) {
        let mut rng = Rng::new(seed);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth,
            channels: 4,
            cin: 2,
            classes: 3,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
        (net, x)
    }

    #[test]
    fn unbounded_plan_is_bit_identical_to_backprop() {
        let (net, x) = small_net(0, 3);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let engine = PlannedEngine::with_budget(None);
        let got = engine.compute(&net, &x, &MeanLoss).unwrap();
        assert_eq!(bp.loss.to_bits(), got.loss.to_bits());
        for (a, b) in bp.grads.iter().flatten().zip(got.grads.iter().flatten()) {
            assert_eq!(a.data(), b.data(), "all-checkpoint plan must equal backprop");
        }
    }

    #[test]
    fn tight_budget_matches_backprop_to_tolerance() {
        let (net, x) = small_net(1, 3);
        let probes = plan::probe_network(&net, x.shape(), plan::DEFAULT_FRAG_BLOCKS).unwrap();
        let frontier = plan::build_frontier(&probes);
        let engine = PlannedEngine::with_budget(Some(frontier.min_peak()));
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let got = engine.compute(&net, &x, &MeanLoss).unwrap();
        assert!((bp.loss - got.loss).abs() < 1e-6);
        for (li, (a, b)) in bp.grads.iter().zip(&got.grads).enumerate() {
            for (ga, gb) in a.iter().zip(b) {
                assert_close(gb, ga, 5e-3, &format!("layer {li}"));
            }
        }
    }

    #[test]
    fn streams_in_forward_order_and_reports_peak() {
        let (net, x) = small_net(2, 2);
        let engine = PlannedEngine::with_budget(None);
        assert!(engine.planned_peak_bytes().is_none(), "no plan before first use");
        let mut order = Vec::new();
        engine
            .compute_streaming(&net, &x, &MeanLoss, &mut |i, _| order.push(i))
            .unwrap();
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(order, sorted, "planned engine streams forward");
        assert!(engine.planned_peak_bytes().unwrap() > 0);
    }

    #[test]
    fn plan_recompiles_on_network_change() {
        let (net_a, x) = small_net(5, 2);
        let engine = PlannedEngine::with_budget(None);
        engine.prepare(&net_a, x.shape()).unwrap();
        let peak_a = engine.planned_peak_bytes().unwrap();
        // Same depth and input shape, different channel width — the
        // fingerprint must keep the cache from serving net_a's plan.
        let mut rng = Rng::new(6);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 6,
            cin: 2,
            classes: 3,
            ..Default::default()
        };
        let net_b = crate::model::build_cnn2d(&spec, &mut rng);
        assert_eq!(net_a.depth(), net_b.depth());
        engine.prepare(&net_b, x.shape()).unwrap();
        let peak_b = engine.planned_peak_bytes().unwrap();
        assert_ne!(peak_a, peak_b, "different architecture must re-plan");
    }

    #[test]
    fn plan_recompiles_on_shape_change() {
        let (net, x) = small_net(3, 2);
        let engine = PlannedEngine::with_budget(None);
        engine.prepare(&net, x.shape()).unwrap();
        let peak_a = engine.planned_peak_bytes().unwrap();
        let mut rng = Rng::new(9);
        let x2 = Tensor::randn(&[4, 16, 16, 2], 1.0, &mut rng);
        engine.compute(&net, &x2, &MeanLoss).unwrap();
        let peak_b = engine.planned_peak_bytes().unwrap();
        assert!(peak_b > peak_a, "doubled batch must re-plan with larger peaks");
    }
}
