//! Gradient engines — the paper's contribution and every baseline it
//! compares against (Table 1):
//!
//! | engine            | module           | paper section |
//! |-------------------|------------------|---------------|
//! | Backprop          | [`backprop`]     | §3.2          |
//! | Backprop+ckpt     | [`checkpointed`] | §11           |
//! | Forward-mode      | [`forward_mode`] | §3.2 / §11    |
//! | ProjForward       | [`proj_forward`] | §11 (Baydin et al.) |
//! | RevBackprop       | [`rev_backprop`] | §11 (Gomez et al.)  |
//! | Moonwalk (mixed)  | [`moonwalk`]     | §4.3, Alg. 1  |
//! | Pure-forward      | [`pure_moonwalk`]| §4.4          |
//! | Moonwalk+ckpt     | [`moonwalk`] (segments opt) | §11 |
//! | Moonwalk+fragmental | [`moonwalk`] (block opt)  | §5.1 |
//! | Planned (budgeted per-layer mix) | [`planned`] | §4–5 + §11 |
//!
//! All engines produce **exact** gradients (bitwise-comparable to Backprop
//! up to fp reassociation) except ProjForward, which is an unbiased but
//! high-variance estimator — exactly the paper's Table-1
//! "High-variance" column.

pub mod backprop;
pub mod checkpointed;
pub mod forward_mode;
pub mod moonwalk;
pub mod planned;
pub mod proj_forward;
pub mod pure_moonwalk;
pub mod rev_backprop;

pub use backprop::Backprop;
pub use checkpointed::CheckpointedBackprop;
pub use forward_mode::ForwardMode;
pub use moonwalk::{Moonwalk, MoonwalkOpts};
pub use planned::{PlanOpts, PlannedEngine};
pub use proj_forward::ProjForward;
pub use pure_moonwalk::PureMoonwalk;
pub use rev_backprop::RevBackprop;

use crate::model::Network;
use crate::nn::Loss;
use crate::tensor::Tensor;

/// Full gradient set for one loss evaluation.
pub struct GradResult {
    pub loss: f32,
    /// Per-layer, per-parameter gradients (empty vec for parameter-free
    /// layers), aligned with `net.layers[i].params()`.
    pub grads: Vec<Vec<Tensor>>,
}

/// A gradient computation strategy.
pub trait GradEngine: Send + Sync {
    fn name(&self) -> String;

    /// Compute the loss and stream each layer's parameter gradients to
    /// `sink(layer_index, grads)` as soon as they are available, so they
    /// can be applied and dropped immediately (the paper's §4.3
    /// observation that Moonwalk "need not store [gradients]
    /// simultaneously"). Order of sink calls is engine-specific.
    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32>;

    /// Predicted peak extra bytes of this engine's compiled execution
    /// plan, when it has one (the budgeted [`PlannedEngine`] after its
    /// first plan compiles; `None` for every fixed-strategy engine).
    /// The trainer logs it beside the measured per-step peak.
    fn planned_peak_bytes(&self) -> Option<usize> {
        None
    }

    /// Convenience wrapper collecting all gradients (used by equivalence
    /// tests and simple training loops).
    fn compute(&self, net: &Network, x0: &Tensor, loss: &dyn Loss) -> anyhow::Result<GradResult> {
        let mut grads: Vec<Vec<Tensor>> = (0..net.depth()).map(|_| Vec::new()).collect();
        let loss_val = self.compute_streaming(net, x0, loss, &mut |i, g| {
            grads[i] = g;
        })?;
        Ok(GradResult {
            loss: loss_val,
            grads,
        })
    }
}

/// Instantiate an engine by its config name. Recognized names:
/// `backprop`, `backprop_ckpt`, `forward`, `projforward`, `revbackprop`,
/// `moonwalk`, `pure_moonwalk`, `moonwalk_ckpt`, `moonwalk_frag`,
/// `planned` (budgeted per-layer mix; budget from `MOONWALK_BUDGET` —
/// the CLI's `--budget` constructs it with an explicit budget instead).
pub fn engine_by_name(
    name: &str,
    block: usize,
    checkpoint_segments: usize,
    seed: u64,
) -> anyhow::Result<Box<dyn GradEngine>> {
    Ok(match name {
        "backprop" => Box::new(Backprop),
        "backprop_ckpt" => Box::new(CheckpointedBackprop::new(checkpoint_segments)),
        "forward" => Box::new(ForwardMode),
        "projforward" => Box::new(ProjForward::new(1, seed)),
        "revbackprop" => Box::new(RevBackprop),
        "moonwalk" => Box::new(Moonwalk::new(MoonwalkOpts::default())),
        "pure_moonwalk" => Box::new(PureMoonwalk::default()),
        "moonwalk_ckpt" => Box::new(Moonwalk::new(MoonwalkOpts {
            checkpoint_segments: Some(checkpoint_segments),
            ..Default::default()
        })),
        "moonwalk_frag" => Box::new(Moonwalk::new(MoonwalkOpts {
            fragment_block: Some(block),
            ..Default::default()
        })),
        "planned" => Box::new(PlannedEngine::new(PlanOpts::from_env())),
        other => anyhow::bail!("unknown gradient engine `{other}`"),
    })
}

/// All exact-engine names (gradient-equivalence test set).
pub const EXACT_ENGINES: &[&str] = &[
    "backprop",
    "backprop_ckpt",
    "moonwalk",
    "moonwalk_ckpt",
    "moonwalk_frag",
    "planned",
];
