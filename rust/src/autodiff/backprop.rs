//! Standard reverse-mode backpropagation (paper §3.2, Fig. 1 right
//! column): a forward pass caching the full activation chain (the tape)
//! plus cheap structural residuals, then a reverse sweep computing
//! parameter gradients with `vjp` — time `O(n²L + ndL)`, memory
//! `O(MxL + MθL)` (Table 1).
//!
//! Tape entries are dropped as soon as the reverse sweep consumes them,
//! so the measured peak is the end-of-forward tape — the same accounting
//! a deep-learning framework's allocator would show.

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::{Loss, Residual, ResidualKind};
use crate::tensor::Tensor;

/// Plain Backprop.
pub struct Backprop;

impl GradEngine for Backprop {
    fn name(&self) -> String {
        "backprop".into()
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        // Phase I: forward, caching the full activation chain (each
        // activation stored exactly once, as a framework's tape would)
        // plus the cheap per-layer minimal residuals (signs/argmaxes).
        let mut residuals: Vec<Option<Residual>> = Vec::with_capacity(net.depth());
        let mut xs: Vec<Tensor> = Vec::with_capacity(net.depth() + 1);
        xs.push(x0.clone());
        {
            let _sp = crate::span!("backprop.phase1");
            for (i, layer) in net.layers.iter().enumerate() {
                let _sl = crate::span!("phase1.forward", layer = i);
                let (y, res) = layer.forward_res(xs.last().unwrap(), ResidualKind::Minimal);
                residuals.push(Some(res));
                xs.push(y);
            }
        }
        let loss_val = loss.value(xs.last().unwrap());

        // Phase II: reverse sweep with vjp; the tape shrinks as it is
        // consumed (frameworks release residuals the same way).
        let mut g = loss.grad(xs.last().unwrap());
        {
            let _sp = crate::span!("backprop.reverse");
            for (i, layer) in net.layers.iter().enumerate().rev() {
                let _sl = crate::span!("phase2.vjp", layer = i);
                xs.truncate(i + 1); // drop activation x_{i+1}
                let res = residuals[i].take().expect("residual consumed once");
                if layer.n_params() > 0 {
                    sink(i, layer.vjp_params(&xs[i], &g));
                }
                g = layer.vjp_input(&res, &g);
            }
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use crate::nn::MeanLoss;
    use crate::tensor::ops;
    use crate::util::Rng;

    /// Backprop gradients must match central finite differences on a
    /// small network — the root oracle every other engine is compared to.
    #[test]
    fn matches_finite_differences() {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 8,
            depth: 1,
            channels: 3,
            cin: 2,
            classes: 2,
            ..Default::default()
        };
        let mut net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 8, 8, 2], 1.0, &mut rng);
        let loss = MeanLoss;
        let result = Backprop.compute(&net, &x, &loss).unwrap();

        // Probe a few parameter coordinates of each parameterized layer.
        let eps = 1e-2f32;
        for li in 0..net.depth() {
            if net.layers[li].n_params() == 0 {
                continue;
            }
            for pi in 0..net.layers[li].params().len() {
                let len = net.layers[li].params()[pi].len();
                for &e in &[0usize, len / 2, len - 1] {
                    let orig = net.layers[li].params()[pi].data()[e];
                    net.layers[li].params_mut()[pi].data_mut()[e] = orig + eps;
                    let fp = loss.value(&net.forward(&x));
                    net.layers[li].params_mut()[pi].data_mut()[e] = orig - eps;
                    let fm = loss.value(&net.forward(&x));
                    net.layers[li].params_mut()[pi].data_mut()[e] = orig;
                    let fd = (fp - fm) / (2.0 * eps);
                    let an = result.grads[li][pi].data()[e];
                    assert!(
                        (fd - an).abs() < 2e-3 * fd.abs().max(1.0),
                        "layer {li} param {pi} elem {e}: fd {fd} vs analytic {an}"
                    );
                }
            }
        }
    }

    #[test]
    fn streaming_order_is_reverse() {
        let mut rng = Rng::new(1);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 8,
            depth: 2,
            channels: 3,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 8, 8, 2], 1.0, &mut rng);
        let mut order = Vec::new();
        Backprop
            .compute_streaming(&net, &x, &MeanLoss, &mut |i, _| order.push(i))
            .unwrap();
        let mut sorted = order.clone();
        sorted.sort_by(|a, b| b.cmp(a));
        assert_eq!(order, sorted, "backprop delivers grads in reverse order");
    }

    #[test]
    fn loss_value_matches_plain_forward() {
        let mut rng = Rng::new(2);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 8,
            depth: 1,
            channels: 2,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[2, 8, 8, 2], 1.0, &mut rng);
        let r = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let direct = MeanLoss.value(&net.forward(&x));
        assert!((r.loss - direct).abs() < 1e-6);
        // Gradient should be non-trivial.
        let gnorm: f32 = r.grads.iter().flatten().map(ops::norm).sum();
        assert!(gnorm > 0.0);
    }
}
