//! True forward-mode differentiation (paper §3.2 / §11 "Forward"): one
//! jvp pass per scalar parameter, each pass pushing a tangent from the
//! parameter's layer to the loss. Exact but `O(n²dL²)` time — the paper's
//! point of comparison for why naive forward-mode is impractical; usable
//! here only on micro-networks (Table-1 scaling bench).
//!
//! Memory is `O(Mx + Mθ)`: each pass keeps one activation and one tangent.

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::Loss;
use crate::tensor::Tensor;

/// Naive exact forward-mode differentiation.
pub struct ForwardMode;

impl GradEngine for ForwardMode {
    fn name(&self) -> String {
        "forward".into()
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        let loss_val = loss.value(&net.forward(x0));

        for (li, layer) in net.layers.iter().enumerate() {
            let params = layer.params();
            if params.is_empty() {
                continue;
            }
            let shapes: Vec<Vec<usize>> = params.iter().map(|p| p.shape().to_vec()).collect();
            drop(params);
            let mut grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
            for (pi, shape) in shapes.iter().enumerate() {
                let len: usize = shape.iter().product();
                for e in 0..len {
                    // One full forward pass per parameter element: propagate
                    // x normally and the tangent u from layer li onward.
                    let mut x = x0.clone();
                    let mut u: Option<Tensor> = None;
                    for (lj, l) in net.layers.iter().enumerate() {
                        let u_next = match (&u, lj == li) {
                            (None, false) => None,
                            (None, true) => {
                                // Inject the basis tangent dθ = e_(pi,e).
                                let dparams: Vec<Tensor> = shapes
                                    .iter()
                                    .enumerate()
                                    .map(|(qi, s)| {
                                        let mut t = Tensor::zeros(s);
                                        if qi == pi {
                                            t.data_mut()[e] = 1.0;
                                        }
                                        t
                                    })
                                    .collect();
                                Some(l.jvp_params(&x, &dparams))
                            }
                            (Some(uv), false) => Some(l.jvp_input(&x, uv)),
                            (Some(uv), true) => unreachable!(
                                "tangent exists before its own layer: {uv:?} at {lj}"
                            ),
                        };
                        x = l.forward(&x);
                        u = u_next;
                    }
                    let tangent = u.expect("tangent must exist after injection layer");
                    grads[pi].data_mut()[e] = loss.jvp(&x, &tangent);
                }
            }
            sink(li, grads);
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::build_mlp;
    use crate::nn::MeanLoss;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn matches_backprop_on_micro_mlp() {
        let mut rng = Rng::new(0);
        let net = build_mlp(&[5, 4, 3], 0.1, &mut rng);
        let x = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let fw = ForwardMode.compute(&net, &x, &MeanLoss).unwrap();
        assert!((bp.loss - fw.loss).abs() < 1e-6);
        for (a, b) in bp.grads.iter().flatten().zip(fw.grads.iter().flatten()) {
            assert_close(b, a, 1e-3, "forward-mode grads");
        }
    }

    #[test]
    fn matches_backprop_on_micro_cnn() {
        use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
        let mut rng = Rng::new(1);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 6,
            depth: 1,
            channels: 2,
            cin: 1,
            classes: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 6, 6, 1], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let fw = ForwardMode.compute(&net, &x, &MeanLoss).unwrap();
        for (a, b) in bp.grads.iter().flatten().zip(fw.grads.iter().flatten()) {
            assert_close(b, a, 1e-3, "forward-mode cnn grads");
        }
    }
}
