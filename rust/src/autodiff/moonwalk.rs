//! **Moonwalk** — mixed-mode inverse-forward differentiation
//! (paper §4, Algorithm 1, Fig. 1 left column), with the two optional
//! refinements of §5.1 / §11:
//!
//! * **Phase I** — forward pass storing only *Minimal* residuals (sign
//!   bits, argmax indices; nothing for convolutions/dense).
//! * **Phase II** — reverse sweep computing only the input cotangent
//!   `h0 = ∂J/∂x0` via `vjp_input` (no parameter grads). Non-submersive
//!   layers get their *output* cotangent preserved here: fragmentally
//!   (first `k−1` slices per block, Alg. 3) when the layer supports it
//!   and `fragment_block` is set, otherwise as a full cotangent
//!   checkpoint (§4.1's fallback). With `checkpoint_segments`, Phase
//!   I+II instead run segment-wise (activation checkpointing), storing
//!   only segment-boundary activations and rematerializing minimal
//!   residuals per segment — memory `O(√(n·Mx·L) + Mθ)` (Table 1).
//! * **Phase III** — forward sweep: recompute activations, push the
//!   cotangent *forward* with **vijp** (Eq. 9), emit each layer's
//!   parameter gradient with `vjp_params` (Eq. 10), and drop everything
//!   before moving on — memory constant in depth.
//!
//! Parallelism: every layer operator invoked by the three phases
//! (`forward_res`, `vjp_input`, `vijp`, `vjp_params`) is batch-parallel
//! internally — images fan out across the scoped worker pool
//! (`runtime::pool`, `--threads`) with per-worker scratch leased from the
//! buffer arena, so the Phase I/II/III loops run multicore and, in steady
//! state, allocation-free apart from the per-layer activation/cotangent
//! tensors themselves. Partitioning is deterministic: a fixed thread
//! count reproduces gradients bit-for-bit.

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::{Fragment, Loss, Residual, ResidualKind, Submersivity};
use crate::tensor::Tensor;

/// Options selecting the Moonwalk variant.
#[derive(Clone, Debug, Default)]
pub struct MoonwalkOpts {
    /// Fragmental-checkpointing block size `B` for non-submersive layers
    /// that support it (§5.1). `None` ⇒ full cotangent checkpoints.
    pub fragment_block: Option<usize>,
    /// Activation-checkpointing segment count for Phase I+II (§11,
    /// "Moonwalk + checkpoint"). `Some(0)` ⇒ auto `√L`.
    pub checkpoint_segments: Option<usize>,
    /// Ablation switch: checkpoint the cotangent at the *breaking*
    /// layer's output instead of the paper's h₁-seed placement at the
    /// next parameterized layer (§4.3). Costs s² more checkpoint bytes
    /// after a strided entry conv; kept for the ablation bench.
    pub naive_anchor: bool,
}

/// What Phase II preserved for a layer whose output cotangent cannot be
/// recovered by vijp alone.
enum CotangentAid {
    /// Submersive layer — Phase III uses vijp, nothing stored.
    None,
    /// Fragmental slices (Alg. 3).
    Fragment(Fragment),
    /// Full output-cotangent checkpoint (§4.1 fallback; also how the
    /// leading channel-expanding Upsample is handled).
    Checkpoint(Tensor),
}

/// The mixed-mode Moonwalk engine.
pub struct Moonwalk {
    pub opts: MoonwalkOpts,
}

impl Moonwalk {
    pub fn new(opts: MoonwalkOpts) -> Moonwalk {
        Moonwalk { opts }
    }

    /// Decide how Phase II/III must treat each layer.
    ///
    /// The cotangent chain runs forward through vijp. A non-submersive
    /// layer *breaks* the chain; it is re-anchored at the first
    /// subsequent layer that needs a cotangent (one with parameters) by
    /// checkpointing that layer's **output** cotangent during Phase II —
    /// the paper's "alternative reconstruction seed (h₁)" trick (§4.3),
    /// which places the checkpoint *after* the anchor layer where the
    /// activation is smallest (e.g. past a stride-2 convolution).
    /// Parameter-free layers inside a broken stretch need nothing.
    /// Fragmental capture (Alg. 3) substitutes for a full checkpoint when
    /// the breaking layer supports it AND the chain is intact at its
    /// input (reconstruction consumes the input cotangent).
    fn plan(&self, net: &Network) -> Vec<LayerPlan> {
        let mut plans = Vec::with_capacity(net.depth());
        let mut chain_ok = true; // do we know the cotangent entering layer i?
        for layer in &net.layers {
            let sub = layer.submersivity();
            let plan = match sub {
                Submersivity::Submersive { .. } if chain_ok => {
                    if layer.n_params() > 0 {
                        LayerPlan::Vijp
                    } else {
                        // vijp is still the cheapest way to continue the
                        // chain (sign/argmax gathers).
                        LayerPlan::Vijp
                    }
                }
                Submersivity::NonSubmersive { fragmental_ok, .. }
                    if chain_ok && fragmental_ok && self.opts.fragment_block.is_some() =>
                {
                    LayerPlan::Fragment(self.opts.fragment_block.unwrap())
                }
                // Chain broken here (or already broken): anchor at the
                // first layer that has parameters (h₁ seed) — or, under
                // the naive-anchor ablation, immediately.
                _ => {
                    if layer.n_params() > 0 || self.opts.naive_anchor {
                        LayerPlan::Checkpoint
                    } else {
                        LayerPlan::SkipBroken
                    }
                }
            };
            chain_ok = !matches!(plan, LayerPlan::SkipBroken);
            plans.push(plan);
        }
        plans
    }

    /// Phases I+II without activation checkpointing: returns
    /// `(loss, h0, aids)`.
    fn input_cotangent_plain(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        plan: &[LayerPlan],
    ) -> anyhow::Result<(f32, Tensor, Vec<CotangentAid>)> {
        // Phase I: minimal residuals only.
        let mut residuals: Vec<Option<Residual>> = Vec::with_capacity(net.depth());
        let mut x = x0.clone();
        {
            let _sp = crate::span!("moonwalk.phase1");
            for (i, layer) in net.layers.iter().enumerate() {
                let _sl = crate::span!("phase1.forward", layer = i);
                let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
                residuals.push(Some(res));
                x = y;
            }
        }
        let loss_val = loss.value(&x);

        // Phase II: input cotangent only; capture aids on the way.
        let mut aids: Vec<CotangentAid> = (0..net.depth()).map(|_| CotangentAid::None).collect();
        let mut h = loss.grad(&x);
        drop(x);
        {
            let _sp = crate::span!("moonwalk.phase2");
            for (i, layer) in net.layers.iter().enumerate().rev() {
                let _sl = crate::span!("phase2.cotangent", layer = i);
                let res = residuals[i].take().expect("consumed once");
                aids[i] = capture_aid(layer.as_ref(), i, &plan[i], &h)?;
                h = layer.vjp_input(&res, &h);
            }
        }
        Ok((loss_val, h, aids))
    }

    /// Phases I+II with activation checkpointing (§11): store only
    /// segment-boundary activations forward, then per segment (reverse)
    /// rematerialize minimal residuals and sweep the cotangent back.
    fn input_cotangent_checkpointed(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        plan: &[LayerPlan],
        segments: usize,
    ) -> anyhow::Result<(f32, Tensor, Vec<CotangentAid>)> {
        let depth = net.depth();
        let segments = if segments == 0 {
            (depth as f64).sqrt().round().max(1.0) as usize
        } else {
            segments.clamp(1, depth)
        };
        let seg_len = (depth + segments - 1) / segments;
        // Segment boundaries: 0, seg_len, 2*seg_len, ...
        let starts: Vec<usize> = (0..segments).map(|s| s * seg_len).collect();

        // Phase I: forward storing only boundary activations.
        let mut boundary: Vec<Option<Tensor>> = vec![None; segments];
        let mut x = x0.clone();
        {
            let _sp = crate::span!("moonwalk.phase1");
            for (i, layer) in net.layers.iter().enumerate() {
                let _sl = crate::span!("phase1.forward", layer = i);
                if let Some(seg) = starts.iter().position(|&s| s == i) {
                    boundary[seg] = Some(x.clone());
                }
                x = layer.forward(&x);
            }
        }
        let loss_val = loss.value(&x);
        let mut h = loss.grad(&x);
        drop(x);

        // Phase II: reverse, one segment at a time.
        let mut aids: Vec<CotangentAid> = (0..depth).map(|_| CotangentAid::None).collect();
        {
            let _sp = crate::span!("moonwalk.phase2");
            for seg in (0..segments).rev() {
                let _ss = crate::span!("phase2.segment", segment = seg);
                let lo = starts[seg];
                let hi = ((seg + 1) * seg_len).min(depth);
                let x_seg = boundary[seg].take().expect("boundary stored");
                // Rematerialize minimal residuals inside the segment.
                let mut residuals: Vec<Option<Residual>> = Vec::with_capacity(hi - lo);
                let mut xs = x_seg;
                for layer in &net.layers[lo..hi] {
                    let (y, res) = layer.forward_res(&xs, ResidualKind::Minimal);
                    residuals.push(Some(res));
                    xs = y;
                }
                drop(xs);
                for i in (lo..hi).rev() {
                    let _sl = crate::span!("phase2.cotangent", layer = i);
                    let res = residuals[i - lo].take().expect("consumed once");
                    aids[i] = capture_aid(net.layers[i].as_ref(), i, &plan[i], &h)?;
                    h = net.layers[i].vjp_input(&res, &h);
                }
            }
        }
        Ok((loss_val, h, aids))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LayerPlan {
    /// Chain intact: recover the output cotangent with vijp.
    Vijp,
    /// Chain intact but layer non-submersive: fragmental capture (Alg. 3).
    Fragment(usize),
    /// Chain broken upstream (or broken here): anchor by checkpointing
    /// this layer's output cotangent in Phase II.
    Checkpoint,
    /// Chain broken and the layer has no parameters: nothing needed.
    SkipBroken,
}

fn capture_aid(
    layer: &dyn crate::nn::Layer,
    index: usize,
    plan: &LayerPlan,
    h_out: &Tensor,
) -> anyhow::Result<CotangentAid> {
    Ok(match plan {
        LayerPlan::Vijp | LayerPlan::SkipBroken => CotangentAid::None,
        LayerPlan::Fragment(block) => {
            CotangentAid::Fragment(layer.fragment_capture(h_out, *block).map_err(|e| {
                anyhow::anyhow!("Phase II fragment capture failed at layer {index}: {e}")
            })?)
        }
        LayerPlan::Checkpoint => CotangentAid::Checkpoint(h_out.clone()),
    })
}

impl GradEngine for Moonwalk {
    fn name(&self) -> String {
        match (&self.opts.fragment_block, &self.opts.checkpoint_segments) {
            (Some(b), _) => format!("moonwalk_frag(B={b})"),
            (_, Some(c)) => format!("moonwalk_ckpt(c={c})"),
            _ => "moonwalk".into(),
        }
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        let plan = self.plan(net);

        // Phases I+II: the input cotangent h0 (Alg. 1 line 2).
        let (loss_val, h0, mut aids) = match self.opts.checkpoint_segments {
            Some(segs) => {
                self.input_cotangent_checkpointed(net, x0, loss, &plan, segs)?
            }
            None => self.input_cotangent_plain(net, x0, loss, &plan)?,
        };

        // Phase III (Alg. 1 loop): forward sweep with vijp + vjp_params.
        // Nothing outlives one iteration except (x, h).
        let mut x = x0.clone();
        let mut h = Some(h0);
        let _sp = crate::span!("moonwalk.phase3");
        for (i, layer) in net.layers.iter().enumerate() {
            let (y, res) = layer.forward_res(&x, ResidualKind::Minimal);
            let h_out = match (std::mem::replace(&mut aids[i], CotangentAid::None), &plan[i]) {
                (CotangentAid::Checkpoint(ck), _) => {
                    crate::obs::span::instant("phase3.checkpoint", Some(("layer", i as i64)));
                    Some(ck)
                }
                (CotangentAid::Fragment(frag), _) => {
                    let _sf = crate::span!("phase3.fragment", layer = i);
                    let h_in = h.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("fragment at layer {i} needs an intact chain")
                    })?;
                    Some(layer.fragment_reconstruct(&frag, h_in).map_err(|e| {
                        anyhow::anyhow!("Phase III reconstruction failed at layer {i}: {e}")
                    })?)
                }
                (CotangentAid::None, LayerPlan::SkipBroken) => None,
                (CotangentAid::None, _) => {
                    let _sv = crate::span!("phase3.vijp", layer = i);
                    let h_in = h.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("vijp at layer {i} needs an intact chain")
                    })?;
                    Some(layer.vijp(&res, h_in).map_err(|e| {
                        anyhow::anyhow!("Phase III vijp failed at layer {i}: {e}")
                    })?)
                }
            };
            if layer.n_params() > 0 {
                let _sg = crate::span!("phase3.vjp_params", layer = i);
                let h_out = h_out.as_ref().expect("plan anchors parameterized layers");
                sink(i, layer.vjp_params(&x, h_out)); // Eq. 10
            }
            x = y;
            h = h_out;
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use crate::nn::{MeanLoss, SoftmaxCrossEntropy};
    use crate::tensor::assert_close;
    use crate::util::Rng;

    fn small_net(seed: u64, depth: usize) -> (crate::model::Network, Tensor) {
        let mut rng = Rng::new(seed);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth,
            channels: 4,
            cin: 2,
            classes: 3,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
        (net, x)
    }

    #[test]
    fn matches_backprop_mean_loss() {
        let (net, x) = small_net(0, 2);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let mw = Moonwalk::new(MoonwalkOpts::default())
            .compute(&net, &x, &MeanLoss)
            .unwrap();
        assert!((bp.loss - mw.loss).abs() < 1e-6);
        for (li, (a, b)) in bp.grads.iter().zip(&mw.grads).enumerate() {
            for (pi, (ga, gb)) in a.iter().zip(b).enumerate() {
                assert_close(gb, ga, 5e-3, &format!("layer {li} param {pi}"));
            }
        }
    }

    #[test]
    fn matches_backprop_xent_loss() {
        let (net, x) = small_net(1, 3);
        let loss = SoftmaxCrossEntropy::new(vec![0, 2]);
        let bp = Backprop.compute(&net, &x, &loss).unwrap();
        let mw = Moonwalk::new(MoonwalkOpts::default())
            .compute(&net, &x, &loss)
            .unwrap();
        for (a, b) in bp.grads.iter().flatten().zip(mw.grads.iter().flatten()) {
            assert_close(b, a, 5e-3, "xent grads");
        }
    }

    #[test]
    fn checkpointed_variant_matches() {
        let (net, x) = small_net(2, 4);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        for segs in [0usize, 2, 3] {
            let mw = Moonwalk::new(MoonwalkOpts {
                checkpoint_segments: Some(segs),
                ..Default::default()
            })
            .compute(&net, &x, &MeanLoss)
            .unwrap();
            for (a, b) in bp.grads.iter().flatten().zip(mw.grads.iter().flatten()) {
                assert_close(b, a, 5e-3, &format!("ckpt segs={segs}"));
            }
        }
    }

    #[test]
    fn unconstrained_conv_falls_back_to_checkpoint() {
        // Unconstrained convs are non-submersive ⇒ Moonwalk must still be
        // exact via full cotangent checkpoints (§4.1 fallback).
        let mut rng = Rng::new(3);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 4,
            cin: 2,
            constrained: false,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[1, 16, 16, 2], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        let mw = Moonwalk::new(MoonwalkOpts::default())
            .compute(&net, &x, &MeanLoss)
            .unwrap();
        for (a, b) in bp.grads.iter().flatten().zip(mw.grads.iter().flatten()) {
            assert_close(b, a, 5e-3, "fallback grads");
        }
    }

    #[test]
    fn phase3_streams_in_forward_order() {
        let (net, x) = small_net(4, 3);
        let mut order = Vec::new();
        Moonwalk::new(MoonwalkOpts::default())
            .compute_streaming(&net, &x, &MeanLoss, &mut |i, _| order.push(i))
            .unwrap();
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(order, sorted, "moonwalk delivers grads forward");
    }
}
