//! Backprop with activation checkpointing / rematerialization
//! (Martens & Sutskever 2012; Chen et al. 2016; paper §11): store only
//! `c` segment-boundary activations during the forward pass, then during
//! the reverse sweep recompute each segment's Full residuals before
//! backpropagating through it. Memory `O(√(n(Mx+Mθ)L))` at the optimal
//! `c`, same asymptotic time as Backprop (one extra forward).

use crate::autodiff::GradEngine;
use crate::model::Network;
use crate::nn::{Loss, Residual, ResidualKind};
use crate::tensor::Tensor;

/// Checkpointed Backprop with `segments` segments (0 = auto `√L`).
pub struct CheckpointedBackprop {
    pub segments: usize,
}

impl CheckpointedBackprop {
    pub fn new(segments: usize) -> CheckpointedBackprop {
        CheckpointedBackprop { segments }
    }
}

impl GradEngine for CheckpointedBackprop {
    fn name(&self) -> String {
        format!("backprop_ckpt(c={})", self.segments)
    }

    fn compute_streaming(
        &self,
        net: &Network,
        x0: &Tensor,
        loss: &dyn Loss,
        sink: &mut dyn FnMut(usize, Vec<Tensor>),
    ) -> anyhow::Result<f32> {
        let depth = net.depth();
        let segments = if self.segments == 0 {
            (depth as f64).sqrt().round().max(1.0) as usize
        } else {
            self.segments.clamp(1, depth)
        };
        let seg_len = (depth + segments - 1) / segments;
        let starts: Vec<usize> = (0..segments).map(|s| s * seg_len).collect();

        // Forward: store only segment-boundary activations.
        let mut boundary: Vec<Option<Tensor>> = vec![None; segments];
        let mut x = x0.clone();
        for (i, layer) in net.layers.iter().enumerate() {
            if let Some(seg) = starts.iter().position(|&s| s == i) {
                boundary[seg] = Some(x.clone());
            }
            x = layer.forward(&x);
        }
        let loss_val = loss.value(&x);
        let mut g = loss.grad(&x);
        drop(x);

        // Reverse: rematerialize one segment's activation chain at a time.
        for seg in (0..segments).rev() {
            let lo = starts[seg];
            let hi = ((seg + 1) * seg_len).min(depth);
            let mut residuals: Vec<Option<Residual>> = Vec::with_capacity(hi - lo);
            let mut xs: Vec<Tensor> = Vec::with_capacity(hi - lo + 1);
            xs.push(boundary[seg].take().expect("boundary stored"));
            for layer in &net.layers[lo..hi] {
                let (y, res) = layer.forward_res(xs.last().unwrap(), ResidualKind::Minimal);
                residuals.push(Some(res));
                xs.push(y);
            }
            for i in (lo..hi).rev() {
                let layer = &net.layers[i];
                xs.truncate(i - lo + 1);
                let res = residuals[i - lo].take().expect("consumed once");
                if layer.n_params() > 0 {
                    sink(i, layer.vjp_params(&xs[i - lo], &g));
                }
                g = layer.vjp_input(&res, &g);
            }
        }
        Ok(loss_val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Backprop;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};
    use crate::nn::MeanLoss;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn matches_backprop_all_segment_counts() {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 4,
            channels: 4,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let x = Tensor::randn(&[2, 16, 16, 2], 1.0, &mut rng);
        let bp = Backprop.compute(&net, &x, &MeanLoss).unwrap();
        for segs in [0usize, 1, 2, 3, 5, 100] {
            let ck = CheckpointedBackprop::new(segs)
                .compute(&net, &x, &MeanLoss)
                .unwrap();
            assert!((bp.loss - ck.loss).abs() < 1e-6);
            for (a, b) in bp.grads.iter().flatten().zip(ck.grads.iter().flatten()) {
                assert_close(b, a, 1e-4, &format!("segments={segs}"));
            }
        }
    }

    #[test]
    fn uses_less_memory_than_backprop_on_deep_net() {
        // Resolution-preserving stack: every layer's residual is the same
        // size, so the O(√L) saving is visible (a stride-2 pyramid is
        // dominated by its first layer and barely benefits — that effect
        // is part of what Fig. 2a shows).
        let mut rng = Rng::new(1);
        let net = crate::model::build_invertible_cnn2d(8, 12, 0.1, &mut rng);
        let x = Tensor::randn(&[2, 16, 16, 8], 1.0, &mut rng);
        let (_, bp_mem) = crate::tensor::tracker::measure(|| {
            Backprop
                .compute_streaming(&net, &x, &MeanLoss, &mut |_, _| {})
                .unwrap()
        });
        let (_, ck_mem) = crate::tensor::tracker::measure(|| {
            CheckpointedBackprop::new(0)
                .compute_streaming(&net, &x, &MeanLoss, &mut |_, _| {})
                .unwrap()
        });
        assert!(
            ck_mem.peak_extra_bytes < bp_mem.peak_extra_bytes,
            "checkpointing should reduce peak: ckpt {} vs bp {}",
            ck_mem.peak_extra_bytes,
            bp_mem.peak_extra_bytes
        );
    }
}
