//! `moonwalk` — CLI launcher for the Moonwalk reproduction framework.
//!
//! Subcommands:
//! * `train     --config cfg.json [--metrics out.jsonl]` — train a
//!   classifier with the configured gradient engine (Fig. 4 setup).
//! * `gradcheck --config cfg.json` — verify every applicable engine
//!   produces Backprop's gradients on the configured network.
//! * `audit     --config cfg.json` — per-layer submersivity report.
//! * `plan      --config cfg.json --budget-mb N [--budget BYTES]
//!   [--autotune]` — Table-1 model + planner: predicted memory/time per
//!   method, chosen whole-network engine, and the **per-layer
//!   mixed-strategy plan** (`plan::compile`) for the same budget.
//!   `--autotune` calibrates conv algorithm choices first (timed once,
//!   cached; persisted via `--conv-cache`), and the plan table's
//!   `timed_ms` column shows the cached calibration per conv layer.
//! * `sweep     --config cfg.json --depths 1,2,..` — memory/time sweep
//!   (the Fig. 2 / Fig. 3 measurement, printable without cargo bench).
//! * `report    <run.trace.json> [--json out.json] [--folded out.folded]`
//!   — aggregate a `--trace` capture into a per-layer × per-phase
//!   time/bytes attribution table (stdout; `--json` for the
//!   machine-readable twin) and an inferno/flamegraph.pl-compatible
//!   folded-stack file (`--folded`). The trace path is positional so it
//!   never collides with the global `--trace` capture flag.
//!
//! Global flags (every subcommand):
//! * `--threads N` — worker-pool size for the parallel tensor runtime
//!   (default: `MOONWALK_THREADS` env var, else available parallelism).
//! * `--gemm auto|scalar|blocked|parallel` — force a GEMM algorithm
//!   (default auto; `MOONWALK_GEMM` is the env spelling).
//! * `--conv-algo auto|direct|im2col|winograd` — force a convolution
//!   lowering for conv1d/conv2d forward and weight-gradient ops
//!   (default auto; `MOONWALK_CONV` is the env spelling). `auto`
//!   resolves override → autotune-cache hit → direct, and never times
//!   anything on its own: calibration only happens through explicit
//!   entry points (`plan --autotune`, the `conv_rows` bench family).
//! * `--conv-cache PATH` — persist/load the conv autotune cache at
//!   PATH (`MOONWALK_CONV_CACHE` is the env spelling). `train` exports
//!   both conv settings to spawned replica workers so every process
//!   resolves identical algorithms and compiles identical plans.
//! * `--replicas N` — data-parallel replica count for `train`: the
//!   global batch is sharded N ways, one gradient engine runs per
//!   replica, and per-layer gradients are all-reduced streamed
//!   (default: `MOONWALK_REPLICAS` env var, else 1). The batch size
//!   must be divisible by N.
//! * `--transport local|unix|tcp` — where `train`'s replicas execute:
//!   in-process on the worker pool (default), one worker **subprocess**
//!   per replica over unix-domain sockets, or worker processes over TCP
//!   (`MOONWALK_TRANSPORT` is the env spelling). The socket transports
//!   give each replica its own process memory budget; gradients are
//!   bit-identical to the in-process transport at the same replica
//!   count. TCP extras: `--listen HOST:PORT` (default `127.0.0.1:0`)
//!   binds the coordinator, and `--remote-workers K` leaves the last K
//!   replica slots for standalone workers dialing in from other hosts.
//! * Supervision (socket transports): `--step-timeout S` (per-step
//!   compute deadline, `0` = wait forever), `--accept-timeout S`,
//!   `--hello-timeout S`, `--heartbeat-ms MS` (worker liveness ticks;
//!   `0` disables). Env spellings: `MOONWALK_STEP_TIMEOUT`,
//!   `MOONWALK_ACCEPT_TIMEOUT`, `MOONWALK_HELLO_TIMEOUT` (seconds),
//!   `MOONWALK_HEARTBEAT_MS`.
//! * `--trace PATH` — record a span trace of the run and write it as
//!   Chrome trace-event JSON at PATH (load at <https://ui.perfetto.dev>;
//!   `MOONWALK_TRACE` is the env spelling). Covers every subcommand;
//!   with a socket transport the worker subprocesses' spans are merged
//!   into the same file. See `docs/OBSERVABILITY.md`.
//! * `--metrics-listen HOST:PORT` — serve live telemetry over HTTP
//!   while the run is in flight (`MOONWALK_METRICS_LISTEN` is the env
//!   spelling; port 0 binds an ephemeral port, printed at startup):
//!   `/metrics` (Prometheus text exposition, fleet series labeled
//!   `replica="…"` under a socket transport), `/snapshot` (the metrics
//!   registry as JSON) and `/healthz` (last-step age vs the step
//!   deadline). Scraping never perturbs computed values.
//! * `--straggler-z Z` — flag a replica whose step wall time exceeds
//!   the fleet's streaming mean by more than `Z` standard deviations
//!   (`supervisor.stragglers` metric, trace instants and the trainer's
//!   JSONL `stragglers` field; `MOONWALK_STRAGGLER_Z` is the env
//!   spelling, default 3, `0` disables).
//! * Fault tolerance: `--step-retries N` (replay a failed step N times
//!   per membership level, default 2), `--failover` (after the retry
//!   budget, shrink onto surviving workers instead of aborting),
//!   `--grad-accum K` (accumulate K micro-batches per optimizer step),
//!   and `--fault kind:replica@step,...` (scripted fault injection —
//!   `kill|hang|drop|corrupt|delay<ms>`, step `*` = every step;
//!   `MOONWALK_FAULT` is the env spelling) for testing recovery.
//! * `--engine NAME` — override the config's gradient engine for
//!   `train` (any `autodiff::engine_by_name` name, plus `planned`).
//! * `--budget BYTES` — peak-memory budget for the `planned` engine
//!   (`kb`/`mb`/`gb` suffixes accepted; `MOONWALK_BUDGET` is the env
//!   spelling, plain bytes): a calibration probe measures the per-layer
//!   residual tiers on the configured shape and the planner compiles a
//!   per-layer strategy mix whose predicted peak respects the budget.
//!   `train --engine planned` prints the plan table before training.
//!
//! Hidden mode: `--replica-worker --connect <socket> --replica <r>`
//! (unix) or `--replica-worker --connect-tcp <host:port> --replica <r>`
//! (tcp — also the standalone multi-host worker launch) is the
//! subprocess entry the socket transports spawn; it is not part of the
//! user-facing CLI surface.

use moonwalk::autodiff::{engine_by_name, Backprop, GradEngine, EXACT_ENGINES};
use moonwalk::cli::Args;
use moonwalk::distributed::transport::{
    EngineSpec, FaultPlan, TcpTransport, TcpTransportOpts, TransportKind, UnixTransport,
    UnixTransportOpts,
};
use moonwalk::distributed::RetryPolicy;
use moonwalk::coordinator::{Optimizer, OptimizerKind, SyntheticSpec, TextureDataset, Trainer};
use moonwalk::model::config::{ArchKind, Config};
use moonwalk::memsim;
use moonwalk::nn::MeanLoss;
use moonwalk::tensor::{rel_err, tracker, Tensor};
use moonwalk::util::Rng;

fn load_config(args: &Args) -> anyhow::Result<Config> {
    match args.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path)),
        None => Ok(Config::default()),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    if cfg.arch != ArchKind::Cnn2d {
        anyhow::bail!("train currently supports the cnn2d classifier configs");
    }
    // `--engine` overrides the config (the `--budget` knob pairs with
    // `--engine planned`, so the config file need not change per run).
    if let Some(name) = args.get("engine") {
        cfg.engine = name.to_string();
    }
    let mut rng = Rng::new(cfg.seed);
    let mut net = cfg.build_network(&mut rng);
    let replicas_for_shape = moonwalk::distributed::replicas().max(1);
    let engine: Box<dyn GradEngine> = if cfg.engine == "planned" {
        let budget = moonwalk::cli::budget_bytes(args)?;
        let planned = moonwalk::autodiff::PlannedEngine::new(moonwalk::autodiff::PlanOpts {
            budget,
            ..Default::default()
        });
        // Plans are per concrete input shape: each replica differentiates
        // a shard of the global batch, so compile for the shard shape and
        // print the table before training (this also warms the plan cache
        // outside the trainer's per-step measurement window).
        anyhow::ensure!(
            cfg.batch % replicas_for_shape == 0,
            "batch {} is not divisible into {replicas_for_shape} replicas",
            cfg.batch
        );
        let mut shard_shape = cfg.input_shape();
        shard_shape[0] = cfg.batch / replicas_for_shape;
        // Replica worker subprocesses rebuild the engine from its name
        // via `engine_by_name("planned")`, which reads MOONWALK_BUDGET —
        // export the flag's value so `--transport unix` workers compile
        // the identical plan.
        if let Some(b) = budget {
            std::env::set_var("MOONWALK_BUDGET", b.to_string());
        }
        let compiled = planned.prepare(&net, &shard_shape)?;
        println!("execution plan (shard shape {shard_shape:?}):");
        print!("{}", planned.plan_table(&net, &shard_shape)?);
        println!(
            "budget={} planned_peak={} conservative_peak={}",
            match budget {
                Some(b) => tracker::fmt_bytes(b),
                None => "unbounded".into(),
            },
            tracker::fmt_bytes(compiled.planned_peak),
            tracker::fmt_bytes(compiled.conservative_peak)
        );
        Box::new(planned)
    } else {
        engine_by_name(&cfg.engine, cfg.block, cfg.checkpoint_every, cfg.seed)?
    };
    let data = TextureDataset::generate(
        SyntheticSpec {
            classes: cfg.classes,
            hw: cfg.input_hw,
            cin: cfg.cin,
            noise: 0.3,
            seed: cfg.seed,
        },
        cfg.dataset_size,
    );
    let (train, test) = data.split(0.2);
    let opt = Optimizer::new(
        OptimizerKind::parse(&cfg.optimizer)?,
        cfg.lr as f32,
        &net,
        cfg.constrained,
    );
    let mut trainer = Trainer::new(&mut net, engine.as_ref(), opt);
    trainer.replicas = moonwalk::distributed::replicas();
    // Route replicas through worker subprocesses when asked: the workers
    // rebuild this config's architecture, receive a parameter broadcast
    // each step, and stream per-layer gradients back over the socket.
    // Honored at any replica count — even one subprocess buys a separate
    // process memory budget.
    let kind = moonwalk::distributed::transport::kind();
    // Export the conv dispatch state before any worker subprocess is
    // spawned (all engines, both socket transports): workers resolve
    // convolution algorithms from MOONWALK_CONV / MOONWALK_CONV_CACHE,
    // so exporting here guarantees every process picks identical
    // lowerings — and, with a shared cache file, compiles identical
    // plans — keeping gradients bit-identical across transports.
    if let Some(algo) = moonwalk::tensor::conv_algo::conv_override() {
        std::env::set_var("MOONWALK_CONV", algo.label());
    }
    if let Some(path) = moonwalk::tensor::conv_algo::cache_path() {
        std::env::set_var("MOONWALK_CONV_CACHE", &path);
    }
    let faults = FaultPlan::resolve(args.get("fault"))?;
    let engine_spec = EngineSpec {
        name: cfg.engine.clone(),
        block: cfg.block,
        checkpoint_segments: cfg.checkpoint_every,
        seed: cfg.seed,
    };
    match kind {
        TransportKind::Unix => {
            let mut opts =
                UnixTransportOpts::new(trainer.replicas, cfg.to_json().to_string(), engine_spec);
            opts.faults = faults;
            trainer.transport = Some(Box::new(UnixTransport::spawn(opts)?));
        }
        TransportKind::Tcp => {
            let mut opts =
                TcpTransportOpts::new(trainer.replicas, cfg.to_json().to_string(), engine_spec);
            opts.listen = args.get_or("listen", "127.0.0.1:0").to_string();
            opts.remote_workers = args.get_usize("remote-workers", 0)?;
            opts.faults = faults;
            let remote = opts.remote_workers;
            let transport = TcpTransport::spawn(opts)?;
            if remote > 0 {
                // By the time spawn returns the remote workers have
                // already dialed in, but print the resolved address
                // anyway — it documents what the run bound.
                println!(
                    "tcp coordinator on {} ({remote} remote worker slot(s))",
                    transport.local_addr()
                );
            }
            trainer.transport = Some(Box::new(transport));
        }
        TransportKind::Local => {
            anyhow::ensure!(
                faults.is_empty(),
                "--fault needs a socket transport (--transport unix|tcp)"
            );
        }
    }
    let mut retry = RetryPolicy::default();
    if let Some(r) = args.get_usize_opt("step-retries")? {
        retry.retries = r;
    }
    retry.failover = args.has("failover");
    trainer.retry = retry;
    let accum = args.get_usize("grad-accum", 1)?;
    anyhow::ensure!(accum >= 1, "--grad-accum must be >= 1");
    trainer.grad_accum = accum;
    let metrics = args.get("metrics").map(std::path::PathBuf::from);
    let report = trainer.train(
        &train,
        &test,
        cfg.batch,
        cfg.steps,
        &mut rng,
        metrics.as_deref(),
    )?;
    println!(
        "engine={} steps={} replicas={} transport={} final_loss={:.4} train_acc={:.3} \
         test_acc={:.3} peak_mem={} time={:.1}s reduce={:.2}s prefetch_wait={:.2}s \
         retries={} failovers={}{}",
        engine.name(),
        report.steps,
        report.replicas,
        report.transport,
        report.final_loss,
        report.train_accuracy,
        report.test_accuracy,
        tracker::fmt_bytes(report.peak_mem_bytes),
        report.total_time_s,
        report.reduce_time_s,
        report.prefetch_wait_s,
        report.retries,
        report.failovers,
        match report.planned_peak_bytes {
            Some(p) => format!(" planned_peak={}", tracker::fmt_bytes(p)),
            None => String::new(),
        }
    );
    if report.heartbeat_misses + report.respawns + report.stragglers > 0
        || report.backoff_wait_ms > 0
    {
        println!(
            "supervisor: heartbeat_misses={} respawns={} backoff_wait_ms={} stragglers={}",
            report.heartbeat_misses, report.respawns, report.backoff_wait_ms, report.stragglers
        );
    }
    Ok(())
}

fn cmd_gradcheck(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let mut rng = Rng::new(cfg.seed);
    let net = cfg.build_network(&mut rng);
    let x = Tensor::randn(&cfg.input_shape(), 1.0, &mut rng);
    let reference = Backprop.compute(&net, &x, &MeanLoss)?;
    println!("reference: backprop loss={:.6}", reference.loss);
    let mut failures = 0;
    for name in EXACT_ENGINES {
        if *name == "backprop" {
            continue;
        }
        let engine = engine_by_name(name, cfg.block, cfg.checkpoint_every, cfg.seed)?;
        match engine.compute(&net, &x, &MeanLoss) {
            Err(e) => {
                println!("  {name:<16} SKIP ({e})");
            }
            Ok(result) => {
                let mut worst = 0f32;
                for (a, b) in reference
                    .grads
                    .iter()
                    .flatten()
                    .zip(result.grads.iter().flatten())
                {
                    worst = worst.max(rel_err(b, a));
                }
                let ok = worst < 5e-3;
                if !ok {
                    failures += 1;
                }
                println!(
                    "  {:<16} {} (max rel err {:.2e})",
                    engine.name(),
                    if ok { "OK  " } else { "FAIL" },
                    worst
                );
            }
        }
    }
    if failures > 0 {
        anyhow::bail!("{failures} engine(s) disagreed with backprop");
    }
    Ok(())
}

fn cmd_audit(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let mut rng = Rng::new(cfg.seed);
    let net = cfg.build_network(&mut rng);
    println!("network: {} layers, {} params", net.depth(), net.n_params());
    for (i, (layer, sub)) in net.layers.iter().zip(net.audit()).enumerate() {
        let desc = match sub {
            moonwalk::nn::Submersivity::Submersive { fast_path } => format!(
                "submersive{}",
                if fast_path { " (parallel vijp)" } else { " (wavefront vijp)" }
            ),
            moonwalk::nn::Submersivity::NonSubmersive {
                reason,
                fragmental_ok,
            } => format!(
                "NON-submersive{}: {reason}",
                if fragmental_ok { " (fragmental ok)" } else { "" }
            ),
        };
        println!("  [{i:>2}] {:<34} {desc}", layer.name());
    }
    println!(
        "network is {}",
        if net.is_submersive() {
            "fully submersive"
        } else {
            "not fully submersive"
        }
    );
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let budget_mb = args.get_f64("budget-mb", 1024.0)?;
    let budget = (budget_mb * 1024.0 * 1024.0) as usize;
    let mut rng = Rng::new(cfg.seed);
    let net = cfg.build_network(&mut rng);
    let in_shape = cfg.input_shape();
    let costs = memsim::profile(&net, &in_shape)?;
    let input_elems: usize = in_shape.iter().product();

    println!("Table-1 model for this network (extra bytes to compute gradients):");
    let methods = [
        memsim::Method::Backprop,
        memsim::Method::BackpropCkpt { segments: 0 },
        memsim::Method::Forward,
        memsim::Method::ProjForward,
        memsim::Method::RevBackprop,
        memsim::Method::PureMoonwalk,
        memsim::Method::Moonwalk,
        memsim::Method::MoonwalkCkpt { segments: 0 },
        memsim::Method::MoonwalkFrag { block: cfg.block.max(3), k: 3 },
    ];
    for m in &methods {
        let app = memsim::applicable(m, &costs);
        let mem = memsim::predict_memory(m, &costs);
        let t = memsim::predict_time_units(m, &costs, input_elems);
        println!(
            "  {:<24} mem={:<12} time={:>12.3e} fwd-flops {}",
            m.label(),
            tracker::fmt_bytes(mem),
            t,
            if app { "" } else { "(not applicable)" }
        );
    }
    match memsim::plan(&costs, budget, !args.has("allow-noisy"), input_elems) {
        Some((m, mem, t)) => println!(
            "planner: budget {} -> {} (predicted mem {}, time {:.3e})",
            tracker::fmt_bytes(budget),
            m.label(),
            tracker::fmt_bytes(mem),
            t
        ),
        None => println!(
            "planner: no method fits in {}",
            tracker::fmt_bytes(budget)
        ),
    }

    // The per-layer mixed-strategy plan for the same budget (`--budget`
    // overrides `--budget-mb` for this section when given): calibration
    // probe + Pareto DP, the `--engine planned` execution plan.
    let layer_budget = moonwalk::cli::budget_bytes(args)?.unwrap_or(budget);
    let mut probes = moonwalk::plan::probe_network(&net, &in_shape, moonwalk::plan::DEFAULT_FRAG_BLOCKS)?;
    // `--autotune` calibrates the conv algorithm choices for this
    // network (times candidates, records winners in the autotune cache;
    // persists when `--conv-cache`/MOONWALK_CONV_CACHE is set). Without
    // it nothing is timed; the timed_ms column simply reflects whatever
    // the cache already holds.
    if args.has("autotune") {
        let outcomes = moonwalk::plan::calibrate_convs(&net, &in_shape)?;
        let timed = outcomes.iter().filter(|o| !o.cached).count();
        println!(
            "\nconv autotune: {} op(s) ({} calibrated, {} already cached):",
            outcomes.len(),
            timed,
            outcomes.len() - timed
        );
        for o in &outcomes {
            println!("  {:<44} -> {:<9} {:.3} ms", o.key, o.algo.label(), o.best_ms);
        }
    }
    moonwalk::plan::attach_timed(&net, &in_shape, &mut probes);
    println!("\nper-layer execution plan (budget {}):", tracker::fmt_bytes(layer_budget));
    match moonwalk::plan::compile(&probes, Some(layer_budget)) {
        Ok(compiled) => print!("{}", moonwalk::plan::summary_table(&compiled, &probes)),
        Err(e) => println!("  no per-layer plan fits: {e}"),
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use moonwalk::coordinator::sweep::{format_table, measure_engine, SweepRow};
    let cfg = load_config(args)?;
    let depths: Vec<usize> = args
        .get_or("depths", "1,2,3,4")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("--depths: {e}"))?;
    let engines: Vec<String> = args
        .get_or("engines", "backprop,backprop_ckpt,moonwalk")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let mut rows = Vec::new();
    for &depth in &depths {
        let mut c = cfg.clone();
        c.depth = depth;
        let mut rng = Rng::new(c.seed);
        let net = c.build_network(&mut rng);
        let x = Tensor::randn(&c.input_shape(), 1.0, &mut rng);
        for name in &engines {
            let engine = engine_by_name(name, c.block, c.checkpoint_every, c.seed)?;
            let (mem, time, loss) =
                measure_engine(engine.as_ref(), &net, &x, &MeanLoss, 1, 3)?;
            rows.push(SweepRow {
                engine: engine.name(),
                depth,
                param: c.block,
                peak_mem_bytes: mem,
                median_time_s: time,
                loss,
            });
        }
    }
    print!("{}", format_table("sweep", &rows));
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    // The input trace is positional (`moonwalk report run.trace.json`):
    // the `--trace` flag is the *capture* knob and must stay usable to
    // record a trace of any subcommand, including this one.
    let input = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("input"))
        .ok_or_else(|| {
            anyhow::anyhow!("usage: moonwalk report <run.trace.json> [--json out.json] [--folded out.folded]")
        })?;
    let report = moonwalk::obs::report::from_file(std::path::Path::new(input))?;
    print!("{}", report.table());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("report JSON written to {path}");
    }
    if let Some(path) = args.get("folded") {
        std::fs::write(path, report.folded())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("folded stacks written to {path} (inferno/flamegraph.pl format)");
    }
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    // Hidden subprocess mode (spawned by the socket transports, or
    // launched standalone on another host with --connect-tcp): serve the
    // replica-worker protocol and exit. Runs before configure_runtime —
    // the worker pins its own pool size from the coordinator's init blob.
    if args.has("replica-worker") {
        if let Err(e) = moonwalk::distributed::transport::worker::run(&args) {
            eprintln!("replica worker error: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    if let Err(e) = moonwalk::cli::configure_runtime(&args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("gradcheck") => cmd_gradcheck(&args),
        Some("audit") => cmd_audit(&args),
        Some("plan") => cmd_plan(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("report") => cmd_report(&args),
        other => {
            eprintln!(
                "usage: moonwalk <train|gradcheck|audit|plan|sweep|report> [--config cfg.json] \
                 [--threads N] [--gemm auto|scalar|blocked|parallel] [--replicas N] \
                 [--transport local|unix|tcp] [--listen HOST:PORT] [--remote-workers K] \
                 [--step-timeout S] [--heartbeat-ms MS] [--step-retries N] [--failover] \
                 [--grad-accum K] [--fault SPEC] [--engine NAME] [--budget BYTES] \
                 [--trace out.trace.json] [--metrics-listen HOST:PORT] [--straggler-z Z] \
                 [--conv-algo auto|direct|im2col|winograd] [--conv-cache PATH] ...\n\
                 (got {other:?}; see README.md)"
            );
            std::process::exit(2);
        }
    };
    // Flush the span capture into the merged Chrome trace (a no-op
    // without --trace / MOONWALK_TRACE). Runs also after a failed
    // subcommand: a trace of the failing run is exactly what you want.
    match moonwalk::obs::export::finish() {
        Ok(Some(path)) => println!("trace written to {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("warning: trace export failed: {e:#}"),
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
