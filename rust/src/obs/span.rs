//! Thread-local ring-buffer span recorder — the capture half of the
//! tracing subsystem (the export half is [`crate::obs::export`]).
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost off.** Tracing defaults to disabled; the only work a
//!    disabled [`span!`](crate::span) site does is one relaxed atomic
//!    load and constructing a `SpanGuard(None)` — no clock read, no
//!    tracker sample, no buffer touch. The `trace_rows` family of
//!    `BENCH_perf_ops.json` gates this (disabled-mode overhead must stay
//!    within noise of the instrumented-but-off median).
//! 2. **Never perturb determinism.** Recording reads wall/monotonic
//!    clocks and [`tracker::current`] but writes nothing any kernel
//!    reads, takes no lock shared with compute, and — critically — its
//!    buffers are plain heap memory, **never** registered with the
//!    allocation tracker, so `tracker::measure` profiles are identical
//!    with tracing on and off. The bit-equality grid in
//!    `rust/tests/trace.rs` enforces this end to end.
//! 3. **Contention-free append.** Each thread records into its own
//!    ring buffer behind a mutex only that thread touches on the hot
//!    path (the exporter locks it once, at drain time), so appends
//!    never contend across pool workers.
//!
//! Spans are RAII guards opened by the [`span!`](crate::span) macro:
//!
//! ```
//! let _sp = moonwalk::span!("phase2.cotangent", layer = 3usize);
//! // ... timed work ...
//! // guard drop records the span
//! ```
//!
//! Every span samples [`tracker::current`] at open and close, so the
//! exported timeline doubles as a memory timeline — the paper's
//! residual-collapse claim, visible per phase per layer.
//!
//! The ring holds [`RING_CAPACITY`] events per thread; overflow
//! overwrites the oldest events and counts them in
//! [`ThreadEvents::dropped`] rather than blocking or reallocating.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::tensor::tracker;
use crate::util::lock_ignore_poison as lock;

/// Events kept per thread before the ring overwrites its oldest entry.
pub const RING_CAPACITY: usize = 65_536;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// `(monotonic anchor, unix micros at that anchor)` — timestamps are
/// `unix_base + anchor.elapsed()`, so they are monotone within a
/// process and wall-clock aligned *across* processes (the coordinator
/// and its worker subprocesses each anchor once; the merge in
/// `obs::export` then needs no clock exchange).
static EPOCH: OnceLock<(Instant, u64)> = OnceLock::new();

fn epoch() -> &'static (Instant, u64) {
    EPOCH.get_or_init(|| {
        let unix_us = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        (Instant::now(), unix_us)
    })
}

/// Microseconds since the unix epoch, monotone within the process.
pub fn now_us() -> u64 {
    let (anchor, base) = epoch();
    base + anchor.elapsed().as_micros() as u64
}

/// Globally enable or disable span recording. Open guards created
/// while enabled still record on drop after a disable — balance is
/// preserved.
pub fn set_enabled(on: bool) {
    if on {
        // Anchor the clock before the first span so timestamps never
        // pay the SystemTime call on the recording path.
        let _ = epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether span recording is currently enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// One recorded span (or instant event) as drained from a ring.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Static span name, e.g. `"phase3.vijp"` (taxonomy in
    /// `docs/OBSERVABILITY.md`).
    pub name: &'static str,
    /// Optional `(key, value)` argument, e.g. `("layer", 3)`.
    pub arg: Option<(&'static str, i64)>,
    /// Open timestamp, microseconds since the unix epoch.
    pub start_us: u64,
    /// Duration in microseconds (0 for instants).
    pub dur_us: u64,
    /// Nesting depth at open (0 = top level on its thread).
    pub depth: u32,
    /// `tracker::current()` sampled at open.
    pub mem_open: usize,
    /// `tracker::current()` sampled at close (== open for instants).
    pub mem_close: usize,
    /// True for point events recorded via [`instant`].
    pub instant: bool,
}

struct Ring {
    events: Vec<SpanEvent>,
    /// Overwrite cursor once the ring is full.
    next: usize,
    dropped: u64,
}

impl Ring {
    const fn new() -> Ring {
        Ring {
            events: Vec::new(),
            next: 0,
            dropped: 0,
        }
    }

    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < RING_CAPACITY {
            self.events.push(ev);
        } else {
            self.events[self.next] = ev;
            self.next = (self.next + 1) % RING_CAPACITY;
            self.dropped += 1;
        }
    }

    fn drain(&mut self) -> (Vec<SpanEvent>, u64) {
        // Chronological order: when wrapped, the oldest surviving event
        // sits at the overwrite cursor.
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.next..]);
        out.extend_from_slice(&self.events[..self.next]);
        self.events.clear();
        self.next = 0;
        (out, std::mem::take(&mut self.dropped))
    }
}

/// All registered rings, living as long as the process (rings of exited
/// threads stay registered so their tail events still export).
static REGISTRY: Mutex<Vec<(u64, Arc<Mutex<Ring>>)>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn register() -> (u64, Arc<Mutex<Ring>>) {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let ring = Arc::new(Mutex::new(Ring::new()));
    lock(&REGISTRY).push((tid, Arc::clone(&ring)));
    (tid, ring)
}

thread_local! {
    static LOCAL: (u64, Arc<Mutex<Ring>>) = register();
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

fn record(ev: SpanEvent) {
    // try_with: a span dropped during thread-local teardown (e.g. a
    // guard owned by a pool worker's last job) must not abort — the
    // event is silently dropped instead.
    let _ = LOCAL.try_with(|(_, ring)| lock(ring).push(ev));
}

struct ActiveSpan {
    name: &'static str,
    arg: Option<(&'static str, i64)>,
    start_us: u64,
    mem_open: usize,
    depth: u32,
}

/// RAII span handle returned by [`open`] / the [`span!`](crate::span)
/// macro; records one [`SpanEvent`] on drop. Holds `None` (and costs
/// nothing) when tracing is disabled.
pub struct SpanGuard(Option<ActiveSpan>);

/// Open a span. Prefer the [`span!`](crate::span) macro, which
/// stringifies the argument key for you. The guard must be bound
/// (`let _sp = …`) — binding to `_` drops it immediately.
pub fn open(name: &'static str, arg: Option<(&'static str, i64)>) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard(Some(ActiveSpan {
        name,
        arg,
        start_us: now_us(),
        mem_open: tracker::current(),
        depth,
    }))
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.0.take() else { return };
        let _ = DEPTH.try_with(|d| d.set(d.get().saturating_sub(1)));
        let end = now_us();
        record(SpanEvent {
            name: a.name,
            arg: a.arg,
            start_us: a.start_us,
            dur_us: end.saturating_sub(a.start_us),
            depth: a.depth,
            mem_open: a.mem_open,
            mem_close: tracker::current(),
            instant: false,
        });
    }
}

/// Record a zero-duration point event (supervisor retries, pool wakes,
/// heartbeat misses — things with a *when* but no extent).
pub fn instant(name: &'static str, arg: Option<(&'static str, i64)>) {
    if !enabled() {
        return;
    }
    let now = now_us();
    let mem = tracker::current();
    record(SpanEvent {
        name,
        arg,
        start_us: now,
        dur_us: 0,
        depth: DEPTH.with(|d| d.get()),
        mem_open: mem,
        mem_close: mem,
        instant: true,
    });
}

/// One thread's drained events.
pub struct ThreadEvents {
    /// Process-local logical thread id (stable for the thread's life;
    /// *not* the OS tid).
    pub tid: u64,
    /// Events in chronological record order.
    pub events: Vec<SpanEvent>,
    /// Events overwritten by ring overflow since the last drain.
    pub dropped: u64,
}

/// Drain every thread's ring (including rings of threads that have
/// exited). Consuming: a second drain returns only events recorded in
/// between.
pub fn drain_all() -> Vec<ThreadEvents> {
    lock(&REGISTRY)
        .iter()
        .map(|(tid, ring)| {
            let (events, dropped) = lock(ring).drain();
            ThreadEvents {
                tid: *tid,
                events,
                dropped,
            }
        })
        .collect()
}

/// Open a tracing span, recorded when the returned guard drops.
///
/// ```
/// let _sp = moonwalk::span!("train.step");
/// let _sl = moonwalk::span!("phase1.forward", layer = 2usize);
/// ```
///
/// The second arm attaches one integer argument (the key is
/// stringified); the value expression is evaluated even when tracing
/// is disabled, so keep it trivial (an index, a count).
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::span::open($name, None)
    };
    ($name:expr, $key:ident = $val:expr) => {
        $crate::obs::span::open($name, Some((stringify!($key), $val as i64)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_guard_records_nothing() {
        // Unique names so concurrent unit tests' events can't collide.
        set_enabled(false);
        {
            let _sp = crate::span!("unit.disabled_probe");
        }
        let seen: usize = drain_all()
            .iter()
            .flat_map(|t| t.events.iter())
            .filter(|e| e.name == "unit.disabled_probe")
            .count();
        assert_eq!(seen, 0);
    }

    #[test]
    fn nested_spans_balance_and_nest() {
        set_enabled(true);
        {
            let _outer = crate::span!("unit.nest_outer");
            let _inner = crate::span!("unit.nest_inner", layer = 7usize);
        }
        set_enabled(false);
        let all: Vec<SpanEvent> = drain_all()
            .into_iter()
            .flat_map(|t| t.events)
            .filter(|e| e.name.starts_with("unit.nest_"))
            .collect();
        let outer = all.iter().find(|e| e.name == "unit.nest_outer").unwrap();
        let inner = all.iter().find(|e| e.name == "unit.nest_inner").unwrap();
        assert_eq!(inner.depth, outer.depth + 1);
        assert_eq!(inner.arg, Some(("layer", 7)));
        // Containment: inner opened no earlier and closed no later.
        assert!(inner.start_us >= outer.start_us);
        assert!(inner.start_us + inner.dur_us <= outer.start_us + outer.dur_us);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut ring = Ring::new();
        let mk = |i: u64| SpanEvent {
            name: "unit.ring",
            arg: None,
            start_us: i,
            dur_us: 0,
            depth: 0,
            mem_open: 0,
            mem_close: 0,
            instant: true,
        };
        for i in 0..(RING_CAPACITY as u64 + 10) {
            ring.push(mk(i));
        }
        let (events, dropped) = ring.drain();
        assert_eq!(dropped, 10);
        assert_eq!(events.len(), RING_CAPACITY);
        // Oldest surviving event is #10; order is chronological.
        assert_eq!(events[0].start_us, 10);
        assert!(events.windows(2).all(|w| w[0].start_us < w[1].start_us));
    }
}
