//! Chrome trace-event export: merge every thread's span ring — and, in
//! multi-process runs, every worker subprocess's spool file — into one
//! Perfetto-loadable JSON timeline.
//!
//! Wiring:
//!
//! * `--trace out.trace.json` (any CLI entry point; `MOONWALK_TRACE`
//!   env equivalent) calls [`set_trace_path`], which enables span
//!   recording, creates a fresh `out.trace.json.workers/` spool
//!   directory, mints a per-run id, and exports both as
//!   `MOONWALK_TRACE_DIR` / `MOONWALK_TRACE_RUN` so worker
//!   subprocesses spawned later (unix/TCP transports respawn workers
//!   freely) inherit the setting with no wire-format change.
//! * A worker subprocess calls [`worker_init_from_env`] at entry; on
//!   exit it writes its own events to
//!   `<spool>/worker-<replica>-<pid>-<run id>.trace.json` via
//!   [`write_worker_file`] — one file per process *incarnation*, so a
//!   respawned replica never clobbers its predecessor's tail.
//! * The coordinator calls [`finish`] once at process end: it drains
//!   local rings, folds in every spool file **stamped with the current
//!   run id** (an orphaned worker from a crashed earlier run that
//!   writes late can no longer leak its events into this trace),
//!   deletes each matched file after merging it, rebases timestamps to
//!   the earliest event, removes the spool and writes the single
//!   merged `{"traceEvents": […]}` file. Ring overflow is surfaced
//!   here too: when any thread (coordinator or worker) overwrote
//!   events, `finish` warns `trace: N events dropped (ring full)` on
//!   stderr and bumps the `trace.dropped_events` metric.
//!
//! Process/thread attribution uses the OS pid and the recorder's
//! logical tid, with `process_name`/`thread_name` metadata events, so
//! Perfetto shows one lane per worker process. Span memory samples
//! additionally export as `mem.current` counter events — the timeline
//! doubles as a live-bytes plot.

use std::path::PathBuf;
use std::sync::Mutex;

use crate::obs::span;
use crate::util::json::Json;
use crate::util::lock_ignore_poison as lock;

/// Where the merged trace is written (coordinator role only).
static TRACE_PATH: Mutex<Option<PathBuf>> = Mutex::new(None);
/// Spool directory for per-process worker files (both roles: the
/// coordinator creates and later merges it; a worker only writes).
static SPOOL_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Env var carrying the spool directory to worker subprocesses.
pub const TRACE_DIR_ENV: &str = "MOONWALK_TRACE_DIR";
/// Env var carrying the per-run spool id to worker subprocesses. Spool
/// files are stamped with it so [`finish`] merges only files from the
/// run it owns.
pub const TRACE_RUN_ENV: &str = "MOONWALK_TRACE_RUN";

/// The current capture's run id (coordinator mints it; workers inherit
/// it through [`TRACE_RUN_ENV`]).
static RUN_ID: Mutex<Option<String>> = Mutex::new(None);

/// Enable tracing and arrange for [`finish`] to write the merged trace
/// to `path`. Creates a fresh `<path>.workers/` spool, mints a per-run
/// id, and exports both as [`TRACE_DIR_ENV`] / [`TRACE_RUN_ENV`] for
/// worker subprocesses.
pub fn set_trace_path(path: &str) -> anyhow::Result<()> {
    let p = PathBuf::from(path);
    if let Some(dir) = p.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let spool = PathBuf::from(format!("{path}.workers"));
    let _ = std::fs::remove_dir_all(&spool);
    std::fs::create_dir_all(&spool)?;
    // Unique per capture within and across processes: pid disambiguates
    // concurrent coordinators, the microsecond clock disambiguates
    // successive captures in one process.
    let run_id = format!("{}-{}", std::process::id(), span::now_us());
    std::env::set_var(TRACE_DIR_ENV, &spool);
    std::env::set_var(TRACE_RUN_ENV, &run_id);
    *lock(&TRACE_PATH) = Some(p);
    *lock(&SPOOL_DIR) = Some(spool);
    *lock(&RUN_ID) = Some(run_id);
    span::set_enabled(true);
    Ok(())
}

/// Worker-subprocess entry hook: if the coordinator exported
/// [`TRACE_DIR_ENV`], enable span recording and remember the spool
/// (and the run id, when stamped) so [`write_worker_file`] has
/// somewhere to write. No-op otherwise.
pub fn worker_init_from_env() {
    if let Ok(dir) = std::env::var(TRACE_DIR_ENV) {
        if !dir.is_empty() {
            *lock(&SPOOL_DIR) = Some(PathBuf::from(dir));
            if let Ok(id) = std::env::var(TRACE_RUN_ENV) {
                if !id.is_empty() {
                    *lock(&RUN_ID) = Some(id);
                }
            }
            span::set_enabled(true);
        }
    }
}

/// Whether a trace capture is in flight in this process (either role).
/// The `trace_rows` bench family checks this before toggling the
/// recorder so it never drains a user-requested capture.
pub fn trace_active() -> bool {
    lock(&TRACE_PATH).is_some() || lock(&SPOOL_DIR).is_some()
}

/// Drain the local rings into Chrome trace events, attributed to this
/// process (`label` becomes the Perfetto process name). The second
/// return is the total ring-overflow drop count across threads —
/// surfaced by [`finish`] (coordinator) or embedded in the spool file
/// (workers), never silently discarded.
fn chrome_events(label: &str) -> (Vec<Json>, u64) {
    let pid = std::process::id() as usize;
    let mut out: Vec<Json> = Vec::new();
    let mut meta = Json::obj();
    meta.set("name", "process_name".into());
    meta.set("ph", "M".into());
    meta.set("pid", pid.into());
    meta.set("tid", 0usize.into());
    let mut margs = Json::obj();
    margs.set("name", label.into());
    meta.set("args", margs);
    out.push(meta);
    let mut dropped = 0u64;
    for t in span::drain_all() {
        if t.events.is_empty() && t.dropped == 0 {
            continue;
        }
        dropped += t.dropped;
        let tid = t.tid as usize;
        let mut tmeta = Json::obj();
        tmeta.set("name", "thread_name".into());
        tmeta.set("ph", "M".into());
        tmeta.set("pid", pid.into());
        tmeta.set("tid", tid.into());
        let mut targs = Json::obj();
        targs.set("name", format!("thread-{tid}").into());
        tmeta.set("args", targs);
        out.push(tmeta);
        for e in &t.events {
            let mut args = Json::obj();
            if let Some((k, v)) = e.arg {
                args.set(k, (v as f64).into());
            }
            args.set("mem_open_bytes", e.mem_open.into());
            args.set("mem_close_bytes", e.mem_close.into());
            args.set("depth", (e.depth as usize).into());
            let mut ev = Json::obj();
            ev.set("name", e.name.into());
            ev.set("ts", (e.start_us as f64).into());
            ev.set("pid", pid.into());
            ev.set("tid", tid.into());
            if e.instant {
                ev.set("ph", "i".into());
                // Thread-scoped instant marker.
                ev.set("s", "t".into());
            } else {
                ev.set("ph", "X".into());
                ev.set("dur", (e.dur_us as f64).into());
            }
            ev.set("args", args);
            out.push(ev);
            // Memory timeline: live tracked bytes as a counter track,
            // sampled at every span boundary.
            for (ts, bytes) in [(e.start_us, e.mem_open), (e.start_us + e.dur_us, e.mem_close)] {
                let mut c = Json::obj();
                c.set("name", "mem.current".into());
                c.set("ph", "C".into());
                c.set("ts", (ts as f64).into());
                c.set("pid", pid.into());
                c.set("tid", tid.into());
                let mut cargs = Json::obj();
                cargs.set("bytes", bytes.into());
                c.set("args", cargs);
                out.push(c);
                if e.instant {
                    break; // open == close; one sample is enough
                }
            }
        }
    }
    (out, dropped)
}

/// Write this worker's drained events to its per-incarnation spool
/// file, stamped with the capture's run id so the coordinator merges
/// only its own run's files. Returns the written path, or `None` when
/// no spool is configured or the write fails (tracing is best-effort
/// on the worker side — a dying worker must still exit cleanly).
pub fn write_worker_file(replica: usize) -> Option<PathBuf> {
    let dir = lock(&SPOOL_DIR).clone()?;
    let run_id = lock(&RUN_ID).clone().unwrap_or_default();
    let (events, dropped) = chrome_events(&format!("worker-{replica}"));
    let path = dir.join(format!(
        "worker-{replica}-{}-{run_id}.trace.json",
        std::process::id()
    ));
    let obj = Json::from_pairs(vec![
        ("traceEvents", Json::Arr(events)),
        ("droppedEvents", (dropped as usize).into()),
    ]);
    match std::fs::write(&path, obj.to_string()) {
        Ok(()) => Some(path),
        Err(_) => None,
    }
}

/// Merge local rings + this run's worker spool files and write the
/// single Chrome trace JSON. Returns the written path, or `None` when
/// no `--trace` capture was requested (callers invoke this
/// unconditionally at process end). Consumes the capture: tracing is
/// disabled, each merged spool file is deleted, and the spool
/// directory removed. Spool files from *other* runs (a crashed
/// earlier incarnation, an orphaned worker writing late) are skipped
/// with a warning instead of being merged — the per-run-id stamp is
/// what tells them apart.
pub fn finish() -> anyhow::Result<Option<PathBuf>> {
    let Some(path) = lock(&TRACE_PATH).take() else {
        return Ok(None);
    };
    let spool = lock(&SPOOL_DIR).take();
    let run_id = lock(&RUN_ID).take().unwrap_or_default();
    span::set_enabled(false);
    let (mut events, mut dropped) = chrome_events("coordinator");
    if let Some(dir) = spool {
        if let Ok(entries) = std::fs::read_dir(&dir) {
            let suffix = format!("-{run_id}.trace.json");
            let mut files: Vec<PathBuf> = Vec::new();
            let mut stale = 0usize;
            for p in entries.filter_map(|e| e.ok().map(|e| e.path())) {
                let name = p
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_default();
                if name.ends_with(&suffix) {
                    files.push(p);
                } else if name.ends_with(".json") {
                    stale += 1;
                }
            }
            if stale > 0 {
                crate::log_warn!(
                    "trace spool {}: skipped {stale} file(s) from other runs",
                    dir.display()
                );
            }
            files.sort(); // deterministic merge order
            for file in files {
                let Ok(text) = std::fs::read_to_string(&file) else {
                    continue;
                };
                match Json::parse(&text) {
                    Ok(j) => {
                        if let Some(arr) = j.get("traceEvents").as_arr() {
                            events.extend(arr.iter().cloned());
                        }
                        dropped += j.get("droppedEvents").as_usize().unwrap_or(0) as u64;
                        let _ = std::fs::remove_file(&file);
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "skipping malformed worker trace {}: {e}",
                            file.display()
                        );
                    }
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        std::env::remove_var(TRACE_DIR_ENV);
        std::env::remove_var(TRACE_RUN_ENV);
    }
    if dropped > 0 {
        // Ring overflow means the trace is incomplete — say so loudly
        // (stderr + metric) instead of letting the gap pass as truth.
        eprintln!("trace: {dropped} events dropped (ring full)");
        crate::obs::metrics::counter_add("trace.dropped_events", dropped);
    }
    // Rebase timestamps to the earliest event so the trace opens at
    // t=0 instead of unix-epoch microseconds (metadata events carry no
    // `ts` and are left alone). Wall-clock anchoring across processes
    // is preserved — every process recorded unix-epoch micros.
    let min_ts = events
        .iter()
        .filter_map(|e| e.get("ts").as_f64())
        .fold(f64::INFINITY, f64::min);
    if min_ts.is_finite() {
        for e in events.iter_mut() {
            if let Some(t) = e.get("ts").as_f64() {
                e.set("ts", (t - min_ts).into());
            }
        }
    }
    let obj = Json::from_pairs(vec![("traceEvents", Json::Arr(events))]);
    std::fs::write(&path, obj.to_string())?;
    Ok(Some(path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_without_capture_is_none() {
        // TRACE_PATH is process-global; this only asserts the
        // no-capture path, which other tests never enter concurrently
        // (integration tests own the capture lifecycle in their own
        // process).
        if !trace_active() {
            assert!(finish().unwrap().is_none());
        }
    }

    #[test]
    fn chrome_events_shape() {
        span::set_enabled(true);
        {
            let _sp = crate::span!("unit.export_probe", layer = 2usize);
        }
        span::instant("unit.export_instant", None);
        span::set_enabled(false);
        let (evs, dropped) = chrome_events("unit-test");
        assert_eq!(dropped, 0, "two events cannot overflow the ring");
        // Find our X event and check the Chrome fields.
        let x = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("unit.export_probe"))
            .expect("span exported");
        assert_eq!(x.get("ph").as_str(), Some("X"));
        assert!(x.get("ts").as_f64().is_some());
        assert!(x.get("dur").as_f64().is_some());
        assert!(x.get("pid").as_usize().is_some());
        assert_eq!(x.get("args").get("layer").as_f64(), Some(2.0));
        assert!(x.get("args").get("mem_open_bytes").as_usize().is_some());
        let i = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("unit.export_instant"))
            .expect("instant exported");
        assert_eq!(i.get("ph").as_str(), Some("i"));
        // Counter samples ride along.
        assert!(evs
            .iter()
            .any(|e| e.get("name").as_str() == Some("mem.current")
                && e.get("ph").as_str() == Some("C")));
    }
}
