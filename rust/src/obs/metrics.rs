//! Typed counter/gauge/histogram registry — one place for the runtime
//! counters that PRs 1–7 scattered across modules as ad-hoc statics.
//!
//! Two kinds of source feed [`snapshot`] and the Prometheus exposition
//! ([`render_prometheus`], served by [`crate::obs::http`]):
//!
//! * **Live sources** — counters that already exist as module statics
//!   with public readers (pool lifecycle, arena recycle rate, tracker
//!   bytes/allocs/frees). The snapshot *reads* them; their owners keep
//!   the hot-path `AtomicUsize` they always had, so absorbing them here
//!   costs the kernels nothing.
//! * **Registered metrics** — named counters/gauges/histograms written
//!   through [`counter_add`] / [`gauge_set`] / [`observe`] from cold
//!   paths (supervisor retries, respawns, heartbeat misses, backoff
//!   waits). These sit behind one mutex-guarded map — fine for events
//!   that happen at most a few times per step, wrong for per-element
//!   work (use a module static and add it to the snapshot instead).
//!
//! Key naming: `subsystem.metric`, flat (no nesting), e.g.
//! `supervisor.respawns`, `step.retries`. The glossary lives in
//! `docs/OBSERVABILITY.md`. Counters are process-global and monotone;
//! consumers that need per-run numbers (the trainer's `TrainReport`)
//! record a baseline with [`counter`] and report deltas.
//!
//! **Labels.** A series may carry Prometheus-style labels — the fleet
//! aggregation path folds worker metric deltas under a
//! `replica="<logical shard>"` label so one scrape shows every replica
//! (`moonwalk_step_seconds{replica="3"}`). Labeled writes go through
//! the `*_labeled` twins, which store the series under the composite
//! key produced by [`series_key`]; the JSON [`snapshot`] keeps those
//! composite keys flat, while [`render_prometheus`] parses them back
//! into proper label sets.
//!
//! **Histogram buckets.** Every histogram shares the fixed
//! [`BUCKET_BOUNDS`] seconds ladder, recorded as cumulative counts.
//! Buckets surface only in the Prometheus exposition
//! (`_bucket{le="…"}` series); the JSON snapshot keeps its original
//! `{count, sum, min, max, mean}` shape so downstream consumers
//! (trainer JSONL, `BENCH_perf_ops.json`) are untouched.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::lock_ignore_poison as lock;

/// Shared histogram bucket upper bounds, in seconds: a step-time ladder
/// from 1 ms to 1 min. Rendered cumulatively (plus the implicit `+Inf`
/// bucket) in the Prometheus exposition.
pub const BUCKET_BOUNDS: [f64; 14] = [
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 60.0,
];

enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist {
        count: u64,
        sum: f64,
        min: f64,
        max: f64,
        /// Non-cumulative per-bucket counts aligned with
        /// [`BUCKET_BOUNDS`]; observations above the last bound land
        /// only in `count` (the `+Inf` bucket).
        buckets: [u64; BUCKET_BOUNDS.len()],
    },
}

static REGISTRY: Mutex<BTreeMap<String, Metric>> = Mutex::new(BTreeMap::new());

/// Compose the registry key for `name` with `labels` attached:
/// `name{k="v",k2="v2"}` (label values escaped per the Prometheus text
/// format). With no labels this is `name` itself. Write through the
/// `*_labeled` functions rather than calling this directly.
pub fn series_key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut s = String::with_capacity(name.len() + 16 * labels.len());
    s.push_str(name);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        push_label(&mut s, k, v);
    }
    s.push('}');
    s
}

/// Append one `k="v"` pair (value escaped per the Prometheus text
/// format) to a label body under construction.
fn push_label(s: &mut String, k: &str, v: &str) {
    s.push_str(k);
    s.push_str("=\"");
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            c => s.push(c),
        }
    }
    s.push('"');
}

/// Attach `k="v"` to a series key that may already carry labels:
/// `name` becomes `name{k="v"}`, while `name{a="b"}` becomes
/// `name{a="b",k="v"}` — the label merges into the existing block
/// instead of growing a second `{…}` that [`split_key`] (and every
/// Prometheus parser) would misread. The fleet-aggregation fold routes
/// worker-shipped keys through this, so a worker-side labeled metric
/// gains its `replica` label cleanly.
pub fn with_label(key: &str, k: &str, v: &str) -> String {
    match key.find('{') {
        Some(_) if key.ends_with('}') => {
            let mut s = String::with_capacity(key.len() + k.len() + v.len() + 8);
            s.push_str(&key[..key.len() - 1]);
            s.push(',');
            push_label(&mut s, k, v);
            s.push('}');
            s
        }
        _ => series_key(key, &[(k, v)]),
    }
}

/// Add `delta` to the named monotone counter (created at 0 on first use).
pub fn counter_add(name: &str, delta: u64) {
    let mut reg = lock(&REGISTRY);
    match reg.get_mut(name) {
        Some(Metric::Counter(v)) => *v += delta,
        _ => {
            reg.insert(name.to_string(), Metric::Counter(delta));
        }
    }
}

/// [`counter_add`] on the series of `name` labeled with `labels`.
pub fn counter_add_labeled(name: &str, labels: &[(&str, &str)], delta: u64) {
    counter_add(&series_key(name, labels), delta);
}

/// Current value of a registered counter (0 if absent). Use this to
/// snapshot a baseline before a run and report deltas after it.
pub fn counter(name: &str) -> u64 {
    match lock(&REGISTRY).get(name) {
        Some(Metric::Counter(v)) => *v,
        _ => 0,
    }
}

/// Set the named gauge to `v` (last-write-wins).
pub fn gauge_set(name: &str, v: f64) {
    lock(&REGISTRY).insert(name.to_string(), Metric::Gauge(v));
}

/// Current value of a registered gauge (`None` if absent or not a
/// gauge) — the `/healthz` endpoint reads the trainer's
/// `train.last_step_unix_us` heartbeat through this.
pub fn gauge(name: &str) -> Option<f64> {
    match lock(&REGISTRY).get(name) {
        Some(Metric::Gauge(v)) => Some(*v),
        _ => None,
    }
}

/// Record one observation into the named histogram (count/sum/min/max
/// plus the fixed [`BUCKET_BOUNDS`] bucket counts).
pub fn observe(name: &str, v: f64) {
    let mut reg = lock(&REGISTRY);
    match reg.get_mut(name) {
        Some(Metric::Hist {
            count,
            sum,
            min,
            max,
            buckets,
        }) => {
            *count += 1;
            *sum += v;
            *min = min.min(v);
            *max = max.max(v);
            if let Some(b) = BUCKET_BOUNDS.iter().position(|&ub| v <= ub) {
                buckets[b] += 1;
            }
        }
        _ => {
            let mut buckets = [0u64; BUCKET_BOUNDS.len()];
            if let Some(b) = BUCKET_BOUNDS.iter().position(|&ub| v <= ub) {
                buckets[b] += 1;
            }
            reg.insert(
                name.to_string(),
                Metric::Hist {
                    count: 1,
                    sum: v,
                    min: v,
                    max: v,
                    buckets,
                },
            );
        }
    }
}

/// [`observe`] on the series of `name` labeled with `labels`.
pub fn observe_labeled(name: &str, labels: &[(&str, &str)], v: f64) {
    observe(&series_key(name, labels), v);
}

/// Registered counters as `(series key, value)` pairs — the worker
/// side of fleet aggregation snapshots this before a step and ships
/// per-step deltas over the wire.
pub fn counters() -> Vec<(String, u64)> {
    lock(&REGISTRY)
        .iter()
        .filter_map(|(k, m)| match m {
            Metric::Counter(v) => Some((k.clone(), *v)),
            _ => None,
        })
        .collect()
}

/// Drop every registered metric (tests; live sources are unaffected).
pub fn reset() {
    lock(&REGISTRY).clear();
}

/// One flat JSON object with every live source and every registered
/// metric — the blob the trainer, `TrainReport` consumers and
/// `BENCH_perf_ops.json` share. Histograms render as
/// `{count, sum, min, max, mean}` sub-objects; everything else is a
/// number. Labeled series appear under their composite
/// `name{label="…"}` key.
pub fn snapshot() -> Json {
    let mut out = Json::obj();
    let p = crate::runtime::pool::stats();
    out.set("pool.regions", p.regions.into());
    out.set("pool.wakes", p.wakes.into());
    out.set("pool.parks", p.parks.into());
    out.set("pool.workers_spawned", p.workers_spawned.into());
    out.set("arena.hits", crate::tensor::arena::hits().into());
    out.set("arena.misses", crate::tensor::arena::misses().into());
    out.set("arena.pooled", crate::tensor::arena::pooled().into());
    out.set(
        "tracker.current_bytes",
        crate::tensor::tracker::current().into(),
    );
    out.set("tracker.peak_bytes", crate::tensor::tracker::peak().into());
    out.set(
        "tracker.total_allocs",
        crate::tensor::tracker::total_allocs().into(),
    );
    out.set(
        "tracker.total_frees",
        crate::tensor::tracker::total_frees().into(),
    );
    for (k, m) in lock(&REGISTRY).iter() {
        match m {
            Metric::Counter(v) => {
                out.set(k, (*v as usize).into());
            }
            Metric::Gauge(v) => {
                out.set(k, (*v).into());
            }
            Metric::Hist {
                count,
                sum,
                min,
                max,
                ..
            } => {
                out.set(
                    k,
                    Json::from_pairs(vec![
                        ("count", (*count as usize).into()),
                        ("sum", (*sum).into()),
                        ("min", (*min).into()),
                        ("max", (*max).into()),
                        ("mean", (*sum / (*count).max(1) as f64).into()),
                    ]),
                );
            }
        }
    }
    out
}

/// Mangle a flat `subsystem.metric` key into a valid Prometheus metric
/// name: `moonwalk_` prefix, `.` (and any other invalid character)
/// mapped to `_`.
fn prom_name(base: &str) -> String {
    let mut s = String::with_capacity(base.len() + 9);
    s.push_str("moonwalk_");
    for c in base.chars() {
        s.push(if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            c
        } else {
            '_'
        });
    }
    s
}

/// Split a composite registry key into `(base name, raw label body)` —
/// the inverse of [`series_key`]; the label body is empty for
/// unlabeled series.
fn split_key(key: &str) -> (&str, &str) {
    match key.find('{') {
        Some(i) => (&key[..i], key[i + 1..].trim_end_matches('}')),
        None => (key, ""),
    }
}

/// Format a float the way the Prometheus text format expects (`{}`
/// prints integral floats without a decimal point, which the format
/// accepts).
fn prom_num(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

/// Render every live source and registered metric in Prometheus text
/// exposition format v0.0.4 — `# TYPE` lines, one family per metric
/// name with all its labeled series grouped, and cumulative
/// `_bucket{le="…"}` / `_sum` / `_count` triplets for histograms.
/// Served at `/metrics` by [`crate::obs::http`].
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(4096);
    let mut fam =
        |out: &mut String, name: &str, kind: &str, series: &[(String, String)]| {
            out.push_str("# TYPE ");
            out.push_str(name);
            out.push(' ');
            out.push_str(kind);
            out.push('\n');
            for (label_line, value) in series {
                out.push_str(label_line);
                out.push(' ');
                out.push_str(value);
                out.push('\n');
            }
        };

    // Live sources first: module statics the hot paths already keep.
    let p = crate::runtime::pool::stats();
    let live_counters: [(&str, u64); 8] = [
        ("pool_regions", p.regions as u64),
        ("pool_wakes", p.wakes as u64),
        ("pool_parks", p.parks as u64),
        ("pool_workers_spawned", p.workers_spawned as u64),
        ("arena_hits", crate::tensor::arena::hits() as u64),
        ("arena_misses", crate::tensor::arena::misses() as u64),
        (
            "tracker_total_allocs",
            crate::tensor::tracker::total_allocs() as u64,
        ),
        (
            "tracker_total_frees",
            crate::tensor::tracker::total_frees() as u64,
        ),
    ];
    for (name, v) in live_counters {
        let full = format!("moonwalk_{name}");
        fam(&mut out, &full, "counter", &[(full.clone(), format!("{v}"))]);
    }
    let live_gauges: [(&str, f64); 3] = [
        ("arena_pooled", crate::tensor::arena::pooled() as f64),
        (
            "tracker_current_bytes",
            crate::tensor::tracker::current() as f64,
        ),
        ("tracker_peak_bytes", crate::tensor::tracker::peak() as f64),
    ];
    for (name, v) in live_gauges {
        let full = format!("moonwalk_{name}");
        fam(&mut out, &full, "gauge", &[(full.clone(), prom_num(v))]);
    }

    // Registered metrics: regroup composite keys into per-base-name
    // families so every family's series sit under one TYPE line (the
    // BTreeMap interleaves `foo.bar` between `foo` and `foo{…}`).
    let reg = lock(&REGISTRY);
    let mut families: BTreeMap<String, Vec<(&str, &Metric)>> = BTreeMap::new();
    for (k, m) in reg.iter() {
        let (base, labels) = split_key(k);
        families.entry(base.to_string()).or_default().push((labels, m));
    }
    let kind_of = |m: &Metric| match m {
        Metric::Counter(_) => "counter",
        Metric::Gauge(_) => "gauge",
        Metric::Hist { .. } => "histogram",
    };
    for (base, series) in &families {
        let name = prom_name(base);
        // A Prometheus family has exactly one type; if labeled and
        // unlabeled series under one base name ever disagree (a
        // programming error), render only the first kind and say so in
        // a comment rather than emitting an exposition scrapers reject.
        let kind = kind_of(series[0].1);
        let mut skipped = 0usize;
        out.push_str("# TYPE ");
        out.push_str(&name);
        out.push(' ');
        out.push_str(kind);
        out.push('\n');
        for (labels, m) in series {
            if kind_of(*m) != kind {
                skipped += 1;
                continue;
            }
            match m {
                Metric::Counter(v) => {
                    out.push_str(&name);
                    if !labels.is_empty() {
                        out.push('{');
                        out.push_str(labels);
                        out.push('}');
                    }
                    out.push(' ');
                    out.push_str(&format!("{v}\n"));
                }
                Metric::Gauge(v) => {
                    out.push_str(&name);
                    if !labels.is_empty() {
                        out.push('{');
                        out.push_str(labels);
                        out.push('}');
                    }
                    out.push(' ');
                    out.push_str(&prom_num(*v));
                    out.push('\n');
                }
                Metric::Hist {
                    count,
                    sum,
                    buckets,
                    ..
                } => {
                    let mut cum = 0u64;
                    for (bi, ub) in BUCKET_BOUNDS.iter().enumerate() {
                        cum += buckets[bi];
                        out.push_str(&name);
                        out.push_str("_bucket{");
                        if !labels.is_empty() {
                            out.push_str(labels);
                            out.push(',');
                        }
                        out.push_str(&format!("le=\"{}\"}} {cum}\n", prom_num(*ub)));
                    }
                    out.push_str(&name);
                    out.push_str("_bucket{");
                    if !labels.is_empty() {
                        out.push_str(labels);
                        out.push(',');
                    }
                    out.push_str(&format!("le=\"+Inf\"}} {count}\n"));
                    for (suffix, v) in [("_sum", prom_num(*sum)), ("_count", format!("{count}"))] {
                        out.push_str(&name);
                        out.push_str(suffix);
                        if !labels.is_empty() {
                            out.push('{');
                            out.push_str(labels);
                            out.push('}');
                        }
                        out.push(' ');
                        out.push_str(&v);
                        out.push('\n');
                    }
                }
            }
        }
        if skipped > 0 {
            out.push_str(&format!(
                "# moonwalk: skipped {skipped} series of {name} whose kind is not {kind}\n"
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_hists_snapshot() {
        // Unique names: the registry is process-global and unit tests
        // run concurrently.
        counter_add("unit.m.count", 2);
        counter_add("unit.m.count", 3);
        assert_eq!(counter("unit.m.count"), 5);
        gauge_set("unit.m.gauge", 1.5);
        observe("unit.m.hist", 2.0);
        observe("unit.m.hist", 4.0);
        let snap = snapshot();
        assert_eq!(snap.get("unit.m.count").as_usize(), Some(5));
        assert_eq!(snap.get("unit.m.gauge").as_f64(), Some(1.5));
        let h = snap.get("unit.m.hist");
        assert_eq!(h.req_usize("count").unwrap(), 2);
        assert_eq!(h.req_f64("mean").unwrap(), 3.0);
        assert_eq!(h.req_f64("max").unwrap(), 4.0);
        // Live sources are always present.
        assert!(snap.get("pool.regions").as_usize().is_some());
        assert!(snap.get("tracker.total_frees").as_usize().is_some());
    }

    #[test]
    fn absent_counter_reads_zero() {
        assert_eq!(counter("unit.m.never_written"), 0);
    }

    #[test]
    fn labeled_series_compose_and_read_back() {
        counter_add_labeled("unit.lbl.count", &[("replica", "3")], 7);
        assert_eq!(counter("unit.lbl.count{replica=\"3\"}"), 7);
        assert_eq!(counter("unit.lbl.count"), 0, "labeled != unlabeled");
        assert_eq!(
            series_key("a.b", &[("k", "v\"x\\y")]),
            "a.b{k=\"v\\\"x\\\\y\"}"
        );
        assert_eq!(series_key("a.b", &[]), "a.b");
    }

    #[test]
    fn with_label_merges_into_an_existing_label_block() {
        // Unlabeled keys gain a fresh block…
        assert_eq!(with_label("a.b", "replica", "3"), "a.b{replica=\"3\"}");
        // …while already-labeled keys merge into the existing one
        // instead of growing a second `{…}` split_key would misread.
        assert_eq!(
            with_label("a.b{k=\"v\"}", "replica", "3"),
            "a.b{k=\"v\",replica=\"3\"}"
        );
        assert_eq!(split_key("a.b{k=\"v\",replica=\"3\"}").0, "a.b");
        // Escaping applies to the merged value too.
        assert_eq!(
            with_label("a.b{k=\"v\"}", "r", "q\"z"),
            "a.b{k=\"v\",r=\"q\\\"z\"}"
        );
    }

    #[test]
    fn mixed_kind_family_keeps_one_type_and_skips_conflicting_series() {
        counter_add("unit.mixedkind.fam", 1);
        // A programming error: the same base name reused as a gauge on
        // a labeled series. The family must still render as exactly one
        // kind — the conflicting series is skipped, visibly.
        gauge_set(&series_key("unit.mixedkind.fam", &[("replica", "0")]), 2.0);
        let text = render_prometheus();
        assert!(text.contains("# TYPE moonwalk_unit_mixedkind_fam counter"));
        assert!(text.contains("moonwalk_unit_mixedkind_fam 1"));
        assert!(
            !text.contains("moonwalk_unit_mixedkind_fam{replica=\"0\"}"),
            "conflicting-kind series must not render under a counter TYPE: {text}"
        );
        assert!(
            text.contains("# moonwalk: skipped 1 series of moonwalk_unit_mixedkind_fam"),
            "the skip must be visible in the exposition: {text}"
        );
    }

    #[test]
    fn gauge_reads_back_and_rejects_other_kinds() {
        gauge_set("unit.g.read", 2.25);
        assert_eq!(gauge("unit.g.read"), Some(2.25));
        counter_add("unit.g.not_a_gauge", 1);
        assert_eq!(gauge("unit.g.not_a_gauge"), None);
        assert_eq!(gauge("unit.g.absent"), None);
    }

    #[test]
    fn prometheus_exposition_groups_families_and_buckets_are_cumulative() {
        counter_add_labeled("unit.prom.steps", &[("replica", "0")], 2);
        counter_add_labeled("unit.prom.steps", &[("replica", "1")], 4);
        observe_labeled("unit.prom.lat", &[("replica", "0")], 0.004);
        observe_labeled("unit.prom.lat", &[("replica", "0")], 0.09);
        observe_labeled("unit.prom.lat", &[("replica", "0")], 999.0); // +Inf only
        let text = render_prometheus();
        assert!(text.contains("# TYPE moonwalk_unit_prom_steps counter"));
        assert!(text.contains("moonwalk_unit_prom_steps{replica=\"0\"} 2"));
        assert!(text.contains("moonwalk_unit_prom_steps{replica=\"1\"} 4"));
        assert!(text.contains("# TYPE moonwalk_unit_prom_lat histogram"));
        assert!(text.contains("moonwalk_unit_prom_lat_sum{replica=\"0\"}"));
        assert!(text.contains("moonwalk_unit_prom_lat_count{replica=\"0\"} 3"));
        assert!(text.contains("moonwalk_unit_prom_lat_bucket{replica=\"0\",le=\"+Inf\"} 3"));
        // Cumulative monotonicity across the bucket ladder.
        let mut last = 0u64;
        let mut seen = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("moonwalk_unit_prom_lat_bucket{replica=\"0\",le=")
            {
                let v: u64 = rest.split(' ').next_back().unwrap().parse().unwrap();
                assert!(v >= last, "bucket counts must be cumulative: {line}");
                last = v;
                seen += 1;
            }
        }
        assert_eq!(seen, BUCKET_BOUNDS.len() + 1, "every bound plus +Inf");
        // Live sources render too.
        assert!(text.contains("# TYPE moonwalk_pool_regions counter"));
        assert!(text.contains("# TYPE moonwalk_tracker_current_bytes gauge"));
    }

    #[test]
    fn histogram_buckets_count_observations_at_or_below_bound() {
        observe("unit.bkt.h", 0.0005); // below first bound
        observe("unit.bkt.h", 0.001); // exactly the first bound (le = ≤)
        let text = render_prometheus();
        assert!(
            text.contains("moonwalk_unit_bkt_h_bucket{le=\"0.001\"} 2"),
            "le is inclusive: {text}"
        );
    }
}
