//! Typed counter/gauge/histogram registry — one place for the runtime
//! counters that PRs 1–7 scattered across modules as ad-hoc statics.
//!
//! Two kinds of source feed [`snapshot`]:
//!
//! * **Live sources** — counters that already exist as module statics
//!   with public readers (pool lifecycle, arena recycle rate, tracker
//!   bytes/allocs/frees). The snapshot *reads* them; their owners keep
//!   the hot-path `AtomicUsize` they always had, so absorbing them here
//!   costs the kernels nothing.
//! * **Registered metrics** — named counters/gauges/histograms written
//!   through [`counter_add`] / [`gauge_set`] / [`observe`] from cold
//!   paths (supervisor retries, respawns, heartbeat misses, backoff
//!   waits). These sit behind one mutex-guarded map — fine for events
//!   that happen at most a few times per step, wrong for per-element
//!   work (use a module static and add it to the snapshot instead).
//!
//! Key naming: `subsystem.metric`, flat (no nesting), e.g.
//! `supervisor.respawns`, `step.retries`. The glossary lives in
//! `docs/OBSERVABILITY.md`. Counters are process-global and monotone;
//! consumers that need per-run numbers (the trainer's `TrainReport`)
//! record a baseline with [`counter`] and report deltas.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::lock_ignore_poison as lock;

enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist { count: u64, sum: f64, min: f64, max: f64 },
}

static REGISTRY: Mutex<BTreeMap<String, Metric>> = Mutex::new(BTreeMap::new());

/// Add `delta` to the named monotone counter (created at 0 on first use).
pub fn counter_add(name: &str, delta: u64) {
    let mut reg = lock(&REGISTRY);
    match reg.get_mut(name) {
        Some(Metric::Counter(v)) => *v += delta,
        _ => {
            reg.insert(name.to_string(), Metric::Counter(delta));
        }
    }
}

/// Current value of a registered counter (0 if absent). Use this to
/// snapshot a baseline before a run and report deltas after it.
pub fn counter(name: &str) -> u64 {
    match lock(&REGISTRY).get(name) {
        Some(Metric::Counter(v)) => *v,
        _ => 0,
    }
}

/// Set the named gauge to `v` (last-write-wins).
pub fn gauge_set(name: &str, v: f64) {
    lock(&REGISTRY).insert(name.to_string(), Metric::Gauge(v));
}

/// Record one observation into the named histogram (count/sum/min/max —
/// enough for rates and means without bucket configuration).
pub fn observe(name: &str, v: f64) {
    let mut reg = lock(&REGISTRY);
    match reg.get_mut(name) {
        Some(Metric::Hist {
            count,
            sum,
            min,
            max,
        }) => {
            *count += 1;
            *sum += v;
            *min = min.min(v);
            *max = max.max(v);
        }
        _ => {
            reg.insert(
                name.to_string(),
                Metric::Hist {
                    count: 1,
                    sum: v,
                    min: v,
                    max: v,
                },
            );
        }
    }
}

/// Drop every registered metric (tests; live sources are unaffected).
pub fn reset() {
    lock(&REGISTRY).clear();
}

/// One flat JSON object with every live source and every registered
/// metric — the blob the trainer, `TrainReport` consumers and
/// `BENCH_perf_ops.json` share. Histograms render as
/// `{count, sum, min, max, mean}` sub-objects; everything else is a
/// number.
pub fn snapshot() -> Json {
    let mut out = Json::obj();
    let p = crate::runtime::pool::stats();
    out.set("pool.regions", p.regions.into());
    out.set("pool.wakes", p.wakes.into());
    out.set("pool.parks", p.parks.into());
    out.set("pool.workers_spawned", p.workers_spawned.into());
    out.set("arena.hits", crate::tensor::arena::hits().into());
    out.set("arena.misses", crate::tensor::arena::misses().into());
    out.set("arena.pooled", crate::tensor::arena::pooled().into());
    out.set(
        "tracker.current_bytes",
        crate::tensor::tracker::current().into(),
    );
    out.set("tracker.peak_bytes", crate::tensor::tracker::peak().into());
    out.set(
        "tracker.total_allocs",
        crate::tensor::tracker::total_allocs().into(),
    );
    out.set(
        "tracker.total_frees",
        crate::tensor::tracker::total_frees().into(),
    );
    for (k, m) in lock(&REGISTRY).iter() {
        match m {
            Metric::Counter(v) => {
                out.set(k, (*v as usize).into());
            }
            Metric::Gauge(v) => {
                out.set(k, (*v).into());
            }
            Metric::Hist {
                count,
                sum,
                min,
                max,
            } => {
                out.set(
                    k,
                    Json::from_pairs(vec![
                        ("count", (*count as usize).into()),
                        ("sum", (*sum).into()),
                        ("min", (*min).into()),
                        ("max", (*max).into()),
                        ("mean", (*sum / (*count).max(1) as f64).into()),
                    ]),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_hists_snapshot() {
        // Unique names: the registry is process-global and unit tests
        // run concurrently.
        counter_add("unit.m.count", 2);
        counter_add("unit.m.count", 3);
        assert_eq!(counter("unit.m.count"), 5);
        gauge_set("unit.m.gauge", 1.5);
        observe("unit.m.hist", 2.0);
        observe("unit.m.hist", 4.0);
        let snap = snapshot();
        assert_eq!(snap.get("unit.m.count").as_usize(), Some(5));
        assert_eq!(snap.get("unit.m.gauge").as_f64(), Some(1.5));
        let h = snap.get("unit.m.hist");
        assert_eq!(h.req_usize("count").unwrap(), 2);
        assert_eq!(h.req_f64("mean").unwrap(), 3.0);
        assert_eq!(h.req_f64("max").unwrap(), 4.0);
        // Live sources are always present.
        assert!(snap.get("pool.regions").as_usize().is_some());
        assert!(snap.get("tracker.total_frees").as_usize().is_some());
    }

    #[test]
    fn absent_counter_reads_zero() {
        assert_eq!(counter("unit.m.never_written"), 0);
    }
}
