//! The live telemetry plane: a std-only HTTP/1.1 listener (one accept
//! thread, one short-lived thread per connection) serving the metrics
//! registry while a run is in flight.
//!
//! Endpoints:
//!
//! * `GET /metrics` — Prometheus text exposition v0.0.4
//!   ([`crate::obs::metrics::render_prometheus`]): every registered
//!   counter/gauge/histogram (cumulative `_bucket`/`_sum`/`_count`
//!   series) plus the live pool/arena/tracker statics. Fleet-aggregated
//!   series carry a `replica="<logical shard>"` label, so one scrape of
//!   the coordinator shows the whole elastic fleet.
//! * `GET /snapshot` — the flat JSON [`crate::obs::metrics::snapshot`],
//!   unchanged from what trainer JSONL rows and `BENCH_perf_ops.json`
//!   embed.
//! * `GET /healthz` — liveness: compares the age of the trainer's
//!   `train.last_step_unix_us` gauge against the supervisor step
//!   deadline. `200` while steps complete on time (or before the first
//!   step finishes, or with the deadline disabled); `503` once the last
//!   completed step is older than the deadline.
//!
//! Enabled by `--metrics-listen HOST:PORT` (env twin
//! `MOONWALK_METRICS_LISTEN`); port `0` binds an ephemeral port, which
//! [`serve`] resolves and `cli::configure_runtime` prints at startup.
//!
//! **Determinism.** The server threads are read-only with respect to
//! the computation: they render from the metrics registry (a mutex shared
//! only with cold-path writers — supervisor events, per-step counters)
//! and the lock-free pool/arena/tracker atomics. Nothing any kernel
//! computes ever reads state the server writes, so the §2.6
//! zero-effect-on-results contract extends to scraping mid-run:
//! losses and gradients are bit-identical scraped or not
//! (`tests/metrics_http.rs`). A scrape can at worst delay a cold-path
//! counter bump by the render duration — observable only in timing,
//! never in values.
//!
//! The listener thread is detached and lives for the remainder of the
//! process (there is deliberately no shutdown path: the endpoint's job
//! is to stay readable until exit, and tests bind port 0 so parallel
//! servers never collide).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::lock_ignore_poison as lock;

/// Environment twin of `--metrics-listen`.
pub const METRICS_LISTEN_ENV: &str = "MOONWALK_METRICS_LISTEN";

/// Gauge key the trainer stamps after every completed optimizer step
/// (unix epoch microseconds, from [`crate::obs::span::now_us`]);
/// `/healthz` measures staleness against it.
pub const LAST_STEP_GAUGE: &str = "train.last_step_unix_us";

/// The most recently bound listener address (for tests and status
/// lines; [`serve`] also returns it directly).
static BOUND: Mutex<Option<SocketAddr>> = Mutex::new(None);

/// Bind `addr` (`HOST:PORT`; port 0 = ephemeral) and serve the
/// telemetry endpoints from a detached background thread. Returns the
/// resolved local address — with port 0 this is where the ephemeral
/// port surfaces. Errors if the bind fails (address in use, bad spec).
pub fn serve(addr: &str) -> anyhow::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("--metrics-listen {addr}: bind failed: {e}"))?;
    let local = listener.local_addr()?;
    *lock(&BOUND) = Some(local);
    std::thread::Builder::new()
        .name("moonwalk-metrics-http".into())
        .spawn(move || serve_loop(listener))?;
    Ok(local)
}

/// The most recently bound listener address, if any listener started.
pub fn bound_addr() -> Option<SocketAddr> {
    *lock(&BOUND)
}

fn serve_loop(listener: TcpListener) {
    // One short-lived thread per connection: scrapes are rare
    // (1–10 Hz) and responses small, but a stuck or idle client must
    // not stall `/healthz` for an external liveness probe sharing the
    // endpoint — the 5s read/write timeouts bound each handler
    // thread's lifetime, so the plane's footprint stays small.
    for conn in listener.incoming() {
        let Ok(mut stream) = conn else { continue };
        let _ = std::thread::Builder::new()
            .name("moonwalk-metrics-conn".into())
            .spawn(move || {
                let _ = handle(&mut stream);
            });
    }
}

/// Read one request head (everything through the blank line; any body
/// is ignored — the endpoints are all GET) and answer it. Request
/// parse failures answer 400; I/O errors just drop the connection.
fn handle(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() > 16 * 1024 {
            return respond(stream, 400, "text/plain", "request head too large\n");
        }
        let n = stream.read(&mut buf)?;
        if n == 0 {
            return Ok(()); // peer closed before completing the head
        }
        head.extend_from_slice(&buf[..n]);
    }
    let text = String::from_utf8_lossy(&head);
    let mut parts = text.lines().next().unwrap_or("").split_whitespace();
    let (method, target) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(stream, 405, "text/plain", "only GET is served here\n");
    }
    let path = target.split('?').next().unwrap_or("");
    match path {
        "/metrics" => {
            let body = crate::obs::metrics::render_prometheus();
            respond(stream, 200, "text/plain; version=0.0.4", &body)
        }
        "/snapshot" => {
            let body = crate::obs::metrics::snapshot().to_string();
            respond(stream, 200, "application/json", &body)
        }
        "/healthz" => {
            let (code, body) = healthz();
            respond(stream, code, "text/plain", &body)
        }
        _ => respond(stream, 404, "text/plain", "try /metrics, /snapshot or /healthz\n"),
    }
}

/// Health verdict: `(status code, body)`. Healthy before the first
/// completed step (the run may still be loading) and whenever the step
/// deadline is disabled; stale once the last completed step is older
/// than the deadline.
fn healthz() -> (u16, String) {
    let Some(last_us) = crate::obs::metrics::gauge(LAST_STEP_GAUGE) else {
        return (200, "ok: no steps completed yet\n".into());
    };
    let age_s = (crate::obs::span::now_us().saturating_sub(last_us as u64)) as f64 / 1e6;
    let deadline = crate::distributed::transport::Deadlines::resolve().step;
    match deadline {
        None => (200, format!("ok: last step {age_s:.3}s ago (no step deadline)\n")),
        Some(d) if age_s <= d.as_secs_f64() => (
            200,
            format!("ok: last step {age_s:.3}s ago (deadline {}s)\n", d.as_secs_f64()),
        ),
        Some(d) => (
            503,
            format!(
                "stale: last step {age_s:.3}s ago exceeds the {}s step deadline\n",
                d.as_secs_f64()
            ),
        ),
    }
}

fn respond(stream: &mut TcpStream, code: u16, content_type: &str, body: &str) -> std::io::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Minimal HTTP GET against a telemetry endpoint — `(status code,
/// body)`. Shared by the tests and the `metrics_rows` bench family so
/// neither needs an HTTP client dependency.
pub fn get(addr: SocketAddr, path: &str) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    stream.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw).into_owned();
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response from {addr}{path}"))?;
    let code: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("missing status code from {addr}{path}"))?;
    Ok((code, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One server instance shared by the unit tests (the listener
    /// thread is process-lived; binding one port keeps the test
    /// footprint small).
    fn test_server() -> SocketAddr {
        serve("127.0.0.1:0").expect("bind ephemeral")
    }

    #[test]
    fn metrics_snapshot_and_404_roundtrip() {
        let addr = test_server();
        crate::obs::metrics::counter_add("unit.http.pings", 3);
        let (code, body) = get(addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("# TYPE moonwalk_unit_http_pings counter"));
        assert!(body.contains("moonwalk_unit_http_pings 3"));
        assert!(body.contains("moonwalk_tracker_current_bytes"));
        let (code, body) = get(addr, "/snapshot").unwrap();
        assert_eq!(code, 200);
        let json = crate::util::json::Json::parse(&body).expect("snapshot is JSON");
        assert!(json.get("pool.regions").as_usize().is_some());
        assert!(json.get("unit.http.pings").as_usize().is_some());
        let (code, _) = get(addr, "/nope").unwrap();
        assert_eq!(code, 404);
    }

    #[test]
    fn healthz_transitions_from_fresh_to_verdict() {
        let addr = test_server();
        // Scope the gauge write: other tests in this process may also
        // exercise healthz, so only assert on states this test owns.
        let (code, body) = get(addr, "/healthz").unwrap();
        assert!(code == 200 || code == 503, "healthz always answers: {body}");
        crate::obs::metrics::gauge_set(LAST_STEP_GAUGE, crate::obs::span::now_us() as f64);
        let (code, body) = get(addr, "/healthz").unwrap();
        assert_eq!(code, 200, "a just-completed step is healthy: {body}");
        assert!(body.starts_with("ok"));
    }

    #[test]
    fn idle_connection_does_not_stall_healthz() {
        let addr = test_server();
        // Regression: a single-threaded serve loop let one idle client
        // (a stuck scraper that never sends a request head) hold every
        // endpoint hostage for the whole 5 s read timeout — long enough
        // for an external liveness probe on /healthz to time out. Each
        // connection now gets its own short-lived thread.
        let _idle = TcpStream::connect(addr).unwrap();
        let t0 = std::time::Instant::now();
        let (code, body) = get(addr, "/healthz").unwrap();
        assert!(code == 200 || code == 503, "healthz always answers: {body}");
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "healthz stalled {:?} behind an idle connection",
            t0.elapsed()
        );
    }

    #[test]
    fn non_get_methods_rejected() {
        let addr = test_server();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"POST /metrics HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).unwrap();
        let text = String::from_utf8_lossy(&raw);
        assert!(text.starts_with("HTTP/1.1 405"), "{text}");
    }
}
