//! Post-run profile reports: aggregate a Chrome trace (the
//! `--trace out.trace.json` artifact) into a per-layer × per-phase
//! time/bytes attribution table and an inferno/flamegraph.pl-compatible
//! folded-stack file — the `moonwalk report` subcommand.
//!
//! Two views over the same events:
//!
//! * **Attribution table** ([`ProfileReport::table`] /
//!   [`ProfileReport::to_json`]): every duration (`ph:"X"`) event is
//!   keyed by *phase* (the span-name prefix before the first `.` —
//!   `phase1`, `phase2`, `phase3`, `reduce`, `pool`, …) and *layer*
//!   (the span's `layer` arg, `-` when absent). Each cell sums the
//!   events' full durations and their net tracked-bytes deltas
//!   (`mem_close_bytes − mem_open_bytes`), so a phase's total equals
//!   the sum of that phase's span durations in the trace — the
//!   reconciliation `tests/report.rs` pins. Rows are *inclusive*
//!   (a parent span's row also covers time attributed to its children's
//!   rows — `moonwalk` includes `phase1`..`phase3`); the folded view
//!   below is where self-time lives.
//! * **Folded stacks** ([`ProfileReport::folded`]): per `(pid, tid)`
//!   lane, events are nested by timestamp containment and each frame is
//!   weighted by its **self** time (duration minus children), in
//!   microseconds. One `proc;frame;frame N` line per unique stack —
//!   feed to `inferno-flamegraph` or `flamegraph.pl` directly.
//!
//! This is the measured replacement for the analytic cost model: the
//! table's per-layer × per-phase seconds/bytes are exactly the observed
//! quantities the budget planner's DP consumes as predictions.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use crate::util::json::Json;

/// One duration event lifted out of the trace (the subset the report
/// aggregates).
#[derive(Clone, Debug)]
struct SpanEvent {
    name: String,
    pid: usize,
    tid: usize,
    /// Start, trace microseconds.
    ts: f64,
    /// Duration, microseconds.
    dur: f64,
    /// The span's `layer` arg, when recorded.
    layer: Option<i64>,
    /// `mem_close_bytes − mem_open_bytes`.
    net_bytes: f64,
}

/// One attribution cell: all spans sharing a `(phase, layer)` key.
#[derive(Clone, Debug, Default)]
pub struct Cell {
    /// Number of spans aggregated into this cell.
    pub count: usize,
    /// Sum of the spans' durations, microseconds.
    pub total_us: f64,
    /// Sum of the spans' net tracked-bytes deltas (may be negative:
    /// a span that frees more than it allocates).
    pub net_bytes: f64,
}

/// The aggregated profile of one Chrome trace.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    /// `(phase, layer label)` → aggregated cell. Layer label is the
    /// decimal `layer` arg or `-` for spans without one.
    pub cells: BTreeMap<(String, String), Cell>,
    /// Per-phase duration totals (microseconds) — the numbers that
    /// reconcile against the trace's span durations.
    pub phase_totals: BTreeMap<String, f64>,
    /// Folded-stack lines (`proc;frame;frame self_us`), one per unique
    /// stack, sorted.
    folded_lines: Vec<String>,
    /// Duration events aggregated.
    pub events: usize,
    /// Instant events seen (counted, not timed).
    pub instants: usize,
    /// Distinct processes in the trace.
    pub processes: usize,
}

/// Phase key: the span-name prefix before the first `.`
/// (`phase1.forward` → `phase1`, `reduce.layer` → `reduce`); names
/// without a dot are their own phase.
fn phase_of(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

/// Parse and aggregate a Chrome trace file written by
/// [`super::export::finish`] (any `{"traceEvents": […]}` JSON with
/// `ph`/`ts`/`dur`/`pid`/`tid` fields works).
pub fn from_file(path: &Path) -> anyhow::Result<ProfileReport> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading trace {}: {e}", path.display()))?;
    let json = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("trace {} is not valid JSON: {e}", path.display()))?;
    from_trace(&json)
}

/// Aggregate an already-parsed trace JSON (the testable core of
/// [`from_file`]).
pub fn from_trace(json: &Json) -> anyhow::Result<ProfileReport> {
    let events = json
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace has no traceEvents array"))?;
    let mut spans: Vec<SpanEvent> = Vec::new();
    let mut proc_names: BTreeMap<usize, String> = BTreeMap::new();
    let mut instants = 0usize;
    for e in events {
        let ph = e.get("ph").as_str().unwrap_or("");
        let pid = e.get("pid").as_usize().unwrap_or(0);
        match ph {
            "M" => {
                if e.get("name").as_str() == Some("process_name") {
                    if let Some(label) = e.get("args").get("name").as_str() {
                        proc_names.insert(pid, label.to_string());
                    }
                }
            }
            "i" => instants += 1,
            "X" => {
                let args = e.get("args");
                let open = args.get("mem_open_bytes").as_f64().unwrap_or(0.0);
                let close = args.get("mem_close_bytes").as_f64().unwrap_or(0.0);
                spans.push(SpanEvent {
                    name: e.get("name").as_str().unwrap_or("?").to_string(),
                    pid,
                    tid: e.get("tid").as_usize().unwrap_or(0),
                    ts: e.get("ts").as_f64().unwrap_or(0.0),
                    dur: e.get("dur").as_f64().unwrap_or(0.0),
                    layer: args.get("layer").as_f64().map(|v| v as i64),
                    net_bytes: close - open,
                });
            }
            _ => {} // counters and unknown phases: not aggregated
        }
    }

    // Attribution cells: full (inclusive) durations per (phase, layer).
    let mut cells: BTreeMap<(String, String), Cell> = BTreeMap::new();
    let mut phase_totals: BTreeMap<String, f64> = BTreeMap::new();
    for s in &spans {
        let phase = phase_of(&s.name).to_string();
        let layer = s.layer.map(|l| l.to_string()).unwrap_or_else(|| "-".into());
        let cell = cells.entry((phase.clone(), layer)).or_default();
        cell.count += 1;
        cell.total_us += s.dur;
        cell.net_bytes += s.net_bytes;
        *phase_totals.entry(phase).or_insert(0.0) += s.dur;
    }

    let folded_lines = fold_stacks(&spans, &proc_names);
    let processes = {
        let mut pids: Vec<usize> = spans.iter().map(|s| s.pid).collect();
        pids.extend(proc_names.keys().copied());
        pids.sort_unstable();
        pids.dedup();
        pids.len()
    };
    Ok(ProfileReport {
        cells,
        phase_totals,
        folded_lines,
        events: spans.len(),
        instants,
        processes,
    })
}

/// Nest each `(pid, tid)` lane's spans by timestamp containment and
/// weight every frame by its self time (duration minus children),
/// rounded to whole microseconds. Zero-self frames are elided (their
/// time lives entirely in their children).
fn fold_stacks(spans: &[SpanEvent], proc_names: &BTreeMap<usize, String>) -> Vec<String> {
    /// A span whose close we haven't passed yet.
    struct Open {
        name: String,
        end: f64,
        dur: f64,
        child_us: f64,
    }
    let mut lanes: BTreeMap<(usize, usize), Vec<&SpanEvent>> = BTreeMap::new();
    for s in spans {
        lanes.entry((s.pid, s.tid)).or_default().push(s);
    }
    let mut folded: BTreeMap<String, u64> = BTreeMap::new();
    for ((pid, _tid), mut lane) in lanes {
        // Parents first: earlier start, and at equal starts the longer
        // span encloses the shorter one.
        lane.sort_by(|a, b| {
            a.ts.partial_cmp(&b.ts)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.dur.partial_cmp(&a.dur).unwrap_or(std::cmp::Ordering::Equal))
        });
        let root = proc_names
            .get(&pid)
            .cloned()
            .unwrap_or_else(|| format!("pid-{pid}"));
        let mut stack: Vec<Open> = Vec::new();
        let mut close_top = |stack: &mut Vec<Open>, folded: &mut BTreeMap<String, u64>| {
            let top = stack.pop().expect("caller checked non-empty");
            let self_us = (top.dur - top.child_us).max(0.0).round() as u64;
            if self_us > 0 {
                let mut frames = Vec::with_capacity(stack.len() + 2);
                frames.push(root.as_str());
                for o in stack.iter() {
                    frames.push(o.name.as_str());
                }
                frames.push(top.name.as_str());
                *folded.entry(frames.join(";")).or_insert(0) += self_us;
            }
        };
        for s in lane {
            while stack
                .last()
                .map(|top| top.end <= s.ts)
                .unwrap_or(false)
            {
                close_top(&mut stack, &mut folded);
            }
            if let Some(parent) = stack.last_mut() {
                parent.child_us += s.dur;
            }
            stack.push(Open {
                name: s.name.clone(),
                end: s.ts + s.dur,
                dur: s.dur,
                child_us: 0.0,
            });
        }
        while !stack.is_empty() {
            close_top(&mut stack, &mut folded);
        }
    }
    folded
        .into_iter()
        .map(|(frames, us)| format!("{frames} {us}"))
        .collect()
}

impl ProfileReport {
    /// The stdout attribution table: one row per `(phase, layer)` cell,
    /// sorted by total time descending, followed by the per-phase
    /// totals line the acceptance reconciliation checks.
    pub fn table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "per-layer × per-phase attribution ({} span(s), {} instant(s), {} process(es)):",
            self.events, self.instants, self.processes
        );
        let _ = writeln!(
            out,
            "  {:<16} {:>6} {:>8} {:>12} {:>11} {:>14}",
            "phase", "layer", "count", "total ms", "mean µs", "net bytes"
        );
        let mut rows: Vec<(&(String, String), &Cell)> = self.cells.iter().collect();
        rows.sort_by(|a, b| {
            b.1.total_us
                .partial_cmp(&a.1.total_us)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for ((phase, layer), cell) in rows {
            let _ = writeln!(
                out,
                "  {:<16} {:>6} {:>8} {:>12.3} {:>11.1} {:>+14.0}",
                phase,
                layer,
                cell.count,
                cell.total_us / 1e3,
                cell.total_us / cell.count.max(1) as f64,
                cell.net_bytes,
            );
        }
        let _ = write!(out, "phase totals:");
        for (phase, us) in &self.phase_totals {
            let _ = write!(out, " {phase}={:.3}ms", us / 1e3);
        }
        out.push('\n');
        out
    }

    /// The machine-readable report (`--json out.json`): the rows and
    /// phase totals of [`Self::table`] plus the event counts.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .cells
            .iter()
            .map(|((phase, layer), cell)| {
                Json::from_pairs(vec![
                    ("phase", phase.as_str().into()),
                    ("layer", layer.as_str().into()),
                    ("count", cell.count.into()),
                    ("total_us", cell.total_us.into()),
                    ("net_bytes", cell.net_bytes.into()),
                ])
            })
            .collect();
        let mut totals = Json::obj();
        for (phase, us) in &self.phase_totals {
            totals.set(phase, (*us).into());
        }
        Json::from_pairs(vec![
            ("events", self.events.into()),
            ("instants", self.instants.into()),
            ("processes", self.processes.into()),
            ("rows", Json::Arr(rows)),
            ("phase_totals_us", totals),
        ])
    }

    /// The folded-stack file body (`--folded out.folded`): one
    /// `proc;frame;frame self_us` line per unique stack, ready for
    /// `inferno-flamegraph` / `flamegraph.pl`.
    pub fn folded(&self) -> String {
        let mut out = self.folded_lines.join("\n");
        if !out.is_empty() {
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic two-process trace: coordinator with a nested pair of
    /// spans (outer 100µs containing inner 30µs) plus a worker span
    /// carrying a layer arg.
    fn fixture() -> Json {
        let ev = |name: &str, ph: &str, pid: usize, tid: usize, ts: f64, dur: f64, layer: Option<i64>| {
            let mut args = Json::obj();
            if let Some(l) = layer {
                args.set("layer", (l as f64).into());
            }
            args.set("mem_open_bytes", 100usize.into());
            args.set("mem_close_bytes", 164usize.into());
            let mut e = Json::obj();
            e.set("name", name.into());
            e.set("ph", ph.into());
            e.set("pid", pid.into());
            e.set("tid", tid.into());
            e.set("ts", ts.into());
            if ph == "X" {
                e.set("dur", dur.into());
            }
            e.set("args", args);
            e
        };
        let mut pmeta = Json::obj();
        pmeta.set("name", "process_name".into());
        pmeta.set("ph", "M".into());
        pmeta.set("pid", 1usize.into());
        pmeta.set("tid", 0usize.into());
        let mut margs = Json::obj();
        margs.set("name", "coordinator".into());
        pmeta.set("args", margs);
        Json::from_pairs(vec![(
            "traceEvents",
            Json::Arr(vec![
                pmeta,
                ev("moonwalk.phase1", "X", 1, 7, 0.0, 100.0, None),
                ev("phase1.forward", "X", 1, 7, 10.0, 30.0, Some(2)),
                ev("phase2.cotangent", "X", 2, 3, 50.0, 40.0, Some(2)),
                ev("supervisor.straggler", "i", 1, 7, 60.0, 0.0, None),
            ]),
        )])
    }

    #[test]
    fn attribution_cells_and_phase_totals_reconcile() {
        let r = from_trace(&fixture()).unwrap();
        assert_eq!(r.events, 3);
        assert_eq!(r.instants, 1);
        assert_eq!(r.processes, 2);
        let c = &r.cells[&("phase1".to_string(), "2".to_string())];
        assert_eq!(c.count, 1);
        assert_eq!(c.total_us, 30.0);
        assert_eq!(c.net_bytes, 64.0);
        // Phase totals equal the sum of that phase's span durations —
        // the reconciliation contract.
        assert_eq!(r.phase_totals["moonwalk"], 100.0);
        assert_eq!(r.phase_totals["phase1"], 30.0);
        assert_eq!(r.phase_totals["phase2"], 40.0);
        let total: f64 = r.phase_totals.values().sum();
        assert_eq!(total, 170.0);
        let table = r.table();
        assert!(table.contains("phase1"), "{table}");
        assert!(table.contains("moonwalk=0.100ms"), "{table}");
    }

    #[test]
    fn folded_stacks_use_self_time_and_nest_by_containment() {
        let r = from_trace(&fixture()).unwrap();
        let folded = r.folded();
        // Outer span: 100µs minus the 30µs child = 70µs self.
        assert!(
            folded.contains("coordinator;moonwalk.phase1 70"),
            "{folded}"
        );
        // Nested child keeps its full 30µs.
        assert!(
            folded.contains("coordinator;moonwalk.phase1;phase1.forward 30"),
            "{folded}"
        );
        // The second process has no process_name metadata → pid label.
        assert!(folded.contains("pid-2;phase2.cotangent 40"), "{folded}");
    }

    #[test]
    fn json_view_matches_table_rows() {
        let r = from_trace(&fixture()).unwrap();
        let j = r.to_json();
        assert_eq!(j.req_usize("events").unwrap(), 3);
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), r.cells.len());
        assert!(j.get("phase_totals_us").get("phase2").as_f64() == Some(40.0));
        // Missing traceEvents is a clean error, not a panic.
        assert!(from_trace(&Json::obj()).is_err());
    }
}
