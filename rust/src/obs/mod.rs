//! Observability: zero-cost-off span tracing + a typed metrics
//! registry (ISSUE 8).
//!
//! The paper's claims are about *where* time and memory go — Moonwalk
//! matches backprop's runtime while the peak residual footprint
//! collapses — but end-of-step aggregates (trainer JSONL,
//! `tracker::peak`) can't show the Phase I–III structure, the reduce
//! overlap, or a straggler replica. This module adds the timeline:
//!
//! * [`span`] — thread-local ring-buffer span recorder behind the
//!   [`span!`](crate::span) RAII macro. Disabled (the default) it costs
//!   one relaxed atomic load per site; enabled, every span samples
//!   `tracker::current()` at open/close, so traces double as memory
//!   timelines.
//! * [`export`] — merges per-thread rings (and per-process worker
//!   spool files, for unix/TCP transports) into one Chrome trace-event
//!   JSON, loadable at <https://ui.perfetto.dev>. Wired to `--trace
//!   out.trace.json` on every CLI entry point.
//! * [`metrics`] — counter/gauge/histogram registry with one
//!   [`metrics::snapshot`] JSON view over both the registered metrics
//!   (supervisor retries, respawns, heartbeat misses, backoff waits)
//!   and the pre-existing live counters (pool, arena, tracker).
//!   Labeled series (`metrics::counter_add_labeled`) carry fleet
//!   dimensions like `replica="3"`, and
//!   [`metrics::render_prometheus`] renders the whole registry as
//!   Prometheus text exposition v0.0.4 (ISSUE 10).
//! * [`http`] — the live telemetry plane: a std-only HTTP/1.1 listener
//!   (`--metrics-listen`) serving `/metrics` (Prometheus), `/snapshot`
//!   (JSON) and `/healthz` while a run is in flight (ISSUE 10).
//! * [`report`] — the post-run profile report behind `moonwalk
//!   report`: aggregates a Chrome trace into a per-layer × per-phase
//!   time/bytes attribution table and an inferno-compatible
//!   folded-stack file (ISSUE 10).
//!
//! **Determinism contract:** tracing never perturbs computed values —
//! recording reads clocks and the tracker but takes no lock shared
//! with compute and registers no tracked allocations, so every
//! bit-equality suite holds with tracing enabled
//! (`rust/tests/trace.rs`). Span taxonomy, the Perfetto how-to and the
//! metrics glossary live in `docs/OBSERVABILITY.md`.

pub mod export;
pub mod http;
pub mod metrics;
pub mod report;
pub mod span;
