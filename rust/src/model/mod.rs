//! Networks: ordered layer stacks plus the architecture builders used by
//! the paper's experiments (§6.2 2-D submersive CNN, §6.3 1-D fragmental
//! CNN, §6.4 constrained-vs-unconstrained classifier, and the invertible
//! stack used by the RevBackprop baseline).

pub mod config;

use crate::nn::{
    Conv1d, Conv2d, CouplingBlock, Dense, LayerBox, LeakyRelu, MaxPool2d, MomentumBlock,
    ResidualBlock, Submersivity, Upsample,
};
use crate::tensor::Tensor;
use crate::util::Rng;

/// A sequential network (the paper's setting, §3.1).
pub struct Network {
    pub layers: Vec<LayerBox>,
}

impl Network {
    pub fn new(layers: Vec<LayerBox>) -> Network {
        Network { layers }
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum()
    }

    /// Plain inference pass.
    pub fn forward(&self, x0: &Tensor) -> Tensor {
        let mut x = x0.clone();
        for layer in &self.layers {
            x = layer.forward(&x);
        }
        x
    }

    /// The shape chain `[x0, x1, …, xL]` for an input shape.
    pub fn shape_chain(&self, in_shape: &[usize]) -> anyhow::Result<Vec<Vec<usize>>> {
        let mut shapes = vec![in_shape.to_vec()];
        for layer in &self.layers {
            let next = layer.out_shape(shapes.last().unwrap())?;
            shapes.push(next);
        }
        Ok(shapes)
    }

    /// Per-layer submersivity audit (used by engines and the planner).
    pub fn audit(&self) -> Vec<Submersivity> {
        self.layers.iter().map(|l| l.submersivity()).collect()
    }

    /// Is every layer submersive (the paper's "submersive network")?
    pub fn is_submersive(&self) -> bool {
        self.audit().iter().all(|s| s.is_submersive())
    }

    /// Project every layer onto its submersive constraint set (§6.4).
    pub fn project_submersive(&mut self) {
        for layer in &mut self.layers {
            layer.project_submersive();
        }
    }

    /// Copy every parameter tensor from `src`, shape-checked and
    /// bit-exact — the data-parallel **parameter broadcast** that puts a
    /// replica-local copy in sync with the source model at
    /// `distributed::ReplicaGroup` construction.
    pub fn copy_params_from(&mut self, src: &Network) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.depth() == src.depth(),
            "depth mismatch: {} vs {}",
            self.depth(),
            src.depth()
        );
        for (li, (dst, s)) in self.layers.iter_mut().zip(&src.layers).enumerate() {
            let sp = s.params();
            let mut dp = dst.params_mut();
            anyhow::ensure!(
                dp.len() == sp.len(),
                "layer {li}: parameter arity mismatch ({} vs {})",
                dp.len(),
                sp.len()
            );
            for (pi, (d, sv)) in dp.iter_mut().zip(&sp).enumerate() {
                anyhow::ensure!(
                    d.shape() == sv.shape(),
                    "layer {li} param {pi}: shape {:?} vs {:?}",
                    d.shape(),
                    sv.shape()
                );
                d.data_mut().copy_from_slice(sv.data());
            }
        }
        Ok(())
    }

    /// Owned snapshot of every parameter tensor, one list per layer
    /// (empty for parameter-free layers) — the inverse of
    /// [`Self::import_params`], shaped like the wire-format parameter
    /// payload. The live broadcast path borrows parameters directly
    /// (`distributed::transport`); this owned form is for snapshots,
    /// tests and future checkpointing.
    pub fn export_params(&self) -> Vec<Vec<Tensor>> {
        self.layers
            .iter()
            .map(|l| l.params().iter().map(|p| (*p).clone()).collect())
            .collect()
    }

    /// Install `params[layer][param]` into this network, shape-checked
    /// and bit-exact — the receiving half of the wire-format parameter
    /// broadcast (the decoded twin of [`Self::copy_params_from`]).
    pub fn import_params(&mut self, params: &[Vec<Tensor>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.depth(),
            "depth mismatch: {} layers vs {} parameter lists",
            self.depth(),
            params.len()
        );
        for (li, (dst, src)) in self.layers.iter_mut().zip(params).enumerate() {
            let mut dp = dst.params_mut();
            anyhow::ensure!(
                dp.len() == src.len(),
                "layer {li}: parameter arity mismatch ({} vs {})",
                dp.len(),
                src.len()
            );
            for (pi, (d, sv)) in dp.iter_mut().zip(src).enumerate() {
                anyhow::ensure!(
                    d.shape() == sv.shape(),
                    "layer {li} param {pi}: shape {:?} vs {:?}",
                    d.shape(),
                    sv.shape()
                );
                d.data_mut().copy_from_slice(sv.data());
            }
        }
        Ok(())
    }

    /// Flat gradient-shaped zero buffers, aligned with layer params.
    pub fn zero_grads(&self) -> Vec<Vec<Tensor>> {
        self.layers
            .iter()
            .map(|l| l.params().iter().map(|p| Tensor::zeros(p.shape())).collect())
            .collect()
    }
}

/// §6.2: the fully parallel submersive 2-D CNN. `Upsample(cin→c)` followed
/// by `depth` blocks of `[Conv2d(k=3, s=2, p=1, c→c, submersive),
/// LeakyReLU]`, then max-pool + dense projection to `classes`
/// (the paper's "max pooling and projects the feature map to a scalar").
pub struct SubmersiveCnn2dSpec {
    pub cin: usize,
    pub channels: usize,
    pub depth: usize,
    pub input_hw: usize,
    pub classes: usize,
    pub alpha: f32,
    pub constrained: bool,
}

impl Default for SubmersiveCnn2dSpec {
    fn default() -> Self {
        SubmersiveCnn2dSpec {
            cin: 3,
            channels: 32,
            depth: 4,
            input_hw: 64,
            classes: 8,
            alpha: 0.1,
            constrained: true,
        }
    }
}

pub fn build_cnn2d(spec: &SubmersiveCnn2dSpec, rng: &mut Rng) -> Network {
    let mut layers: Vec<LayerBox> = Vec::new();
    layers.push(Box::new(Upsample::new(spec.cin, spec.channels)));
    let mut hw = spec.input_hw;
    for _ in 0..spec.depth {
        let conv = if spec.constrained {
            Conv2d::new_submersive(3, spec.channels, spec.channels, 2, 1, false, rng)
        } else {
            Conv2d::new(3, spec.channels, spec.channels, 2, 1, false, rng)
        };
        layers.push(Box::new(conv));
        layers.push(Box::new(LeakyRelu::new(spec.alpha)));
        hw = (hw + 2 - 3) / 2 + 1;
    }
    // Final head: pool the remaining spatial grid away, then project.
    let pool = hw.min(2).max(1);
    if pool > 1 && hw % pool == 0 {
        layers.push(Box::new(MaxPool2d::new(pool)));
        hw /= pool;
    }
    layers.push(Box::new(Dense::new(
        hw * hw * spec.channels,
        spec.classes,
        true,
        rng,
    )));
    Network::new(layers)
}

/// §6.3: the 1-D resolution-preserving CNN (k=3, s=1, p=1) — NOT
/// submersive; exercised with fragmental checkpointing.
pub struct FragmentalCnn1dSpec {
    pub cin: usize,
    pub channels: usize,
    pub depth: usize,
    pub input_len: usize,
    pub classes: usize,
    pub alpha: f32,
}

impl Default for FragmentalCnn1dSpec {
    fn default() -> Self {
        FragmentalCnn1dSpec {
            cin: 3,
            channels: 64,
            depth: 4,
            input_len: 512,
            classes: 8,
            alpha: 0.1,
        }
    }
}

pub fn build_cnn1d_fragmental(spec: &FragmentalCnn1dSpec, rng: &mut Rng) -> Network {
    let mut layers: Vec<LayerBox> = Vec::new();
    layers.push(Box::new(crate::nn::pool::Upsample::new(
        spec.cin,
        spec.channels,
    )));
    for _ in 0..spec.depth {
        layers.push(Box::new(Conv1d::new_fragmental(
            3,
            spec.channels,
            spec.channels,
            rng,
        )));
        layers.push(Box::new(LeakyRelu::new(spec.alpha)));
    }
    layers.push(Box::new(Dense::new(
        spec.input_len * spec.channels,
        spec.classes,
        true,
        rng,
    )));
    Network::new(layers)
}

/// An exactly invertible stack for the RevBackprop baseline: alternating
/// triangular 1×1 convolutions and LeakyReLU (both invertible).
pub fn build_invertible_cnn2d(
    channels: usize,
    depth: usize,
    alpha: f32,
    rng: &mut Rng,
) -> Network {
    let mut layers: Vec<LayerBox> = Vec::new();
    for _ in 0..depth {
        layers.push(Box::new(Conv2d::new_submersive(
            1, channels, channels, 1, 0, false, rng,
        )));
        layers.push(Box::new(LeakyRelu::new(alpha)));
    }
    Network::new(layers)
}

/// Which reversible block family a [`RevNetSpec`] stacks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RevNetVariant {
    /// RevNet coupling blocks `y1 = x1 + f(x2); y2 = x2 + g(y1)` with
    /// dense branches — zero Phase-I residual bytes at any depth.
    Coupling,
    /// Momentum blocks `v' = γ·v + f(x); x' = x + v'`.
    Momentum,
    /// Channel-disjoint residual blocks `y = (xa, xb + f(xa))`.
    Residual,
    /// Cycle coupling → momentum → residual (the topology-stress mix).
    Mixed,
}

/// The reversible (100+-layer capable) network family: a headless stack
/// of reversible blocks on flat `[batch, channels]` state. Every layer
/// is submersive with an exact zero-residual vijp, so Moonwalk and the
/// planner traverse the whole depth on the cotangent chain alone — the
/// depth-×-memory regime of the paper's Table 2 (tracked peak flat in
/// depth while Backprop's activation tape grows linearly).
pub struct RevNetSpec {
    /// Trailing state width (must be even — the blocks split it in half).
    pub channels: usize,
    /// Number of reversible blocks.
    pub depth: usize,
    /// Block family to stack.
    pub variant: RevNetVariant,
    /// Velocity damping for momentum blocks.
    pub gamma: f32,
}

impl Default for RevNetSpec {
    fn default() -> Self {
        RevNetSpec {
            channels: 16,
            depth: 8,
            variant: RevNetVariant::Coupling,
            gamma: 0.9,
        }
    }
}

/// Build a [`RevNetSpec`] stack. The dense branches are scaled by
/// `1/√depth` so a 100+-layer stack neither explodes nor vanishes —
/// the standard RevNet depth-stability initialisation.
pub fn build_revnet(spec: &RevNetSpec, rng: &mut Rng) -> Network {
    assert!(spec.channels % 2 == 0, "revnet channels must be even");
    assert!(spec.channels >= 2 && spec.depth >= 1);
    let half = spec.channels / 2;
    let gain = 1.0 / (spec.depth as f32).sqrt();
    let mut branch = |rng: &mut Rng| -> LayerBox {
        let mut d = Dense::new(half, half, true, rng);
        for w in d.w.data_mut() {
            *w *= gain;
        }
        Box::new(d)
    };
    let mut layers: Vec<LayerBox> = Vec::with_capacity(spec.depth);
    for i in 0..spec.depth {
        let kind = match spec.variant {
            RevNetVariant::Coupling => 0,
            RevNetVariant::Momentum => 1,
            RevNetVariant::Residual => 2,
            RevNetVariant::Mixed => i % 3,
        };
        layers.push(match kind {
            0 => Box::new(CouplingBlock::new(branch(rng), branch(rng))),
            1 => Box::new(MomentumBlock::new(branch(rng), spec.gamma)),
            _ => Box::new(ResidualBlock::new(branch(rng))),
        });
    }
    Network::new(layers)
}

/// A small dense (MLP) network for micro-scale sweeps (Table 1 exponents)
/// where layer dims must be controlled independently of conv structure.
pub fn build_mlp(dims: &[usize], alpha: f32, rng: &mut Rng) -> Network {
    let mut layers: Vec<LayerBox> = Vec::new();
    for win in dims.windows(2) {
        layers.push(Box::new(Dense::new(win[0], win[1], true, rng)));
        layers.push(Box::new(LeakyRelu::new(alpha)));
    }
    Network::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnn2d_shapes_and_submersivity() {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 32,
            depth: 3,
            channels: 8,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let shapes = net.shape_chain(&[2, 32, 32, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![2, 8]);
        // Every layer except the leading Upsample must be submersive.
        let audit = net.audit();
        assert!(!audit[0].is_submersive());
        assert!(audit[1..].iter().all(|s| s.is_submersive()));
    }

    #[test]
    fn cnn2d_unconstrained_not_submersive() {
        let mut rng = Rng::new(1);
        let spec = SubmersiveCnn2dSpec {
            constrained: false,
            input_hw: 32,
            depth: 2,
            channels: 4,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        assert!(!net.is_submersive());
    }

    #[test]
    fn cnn1d_builds_and_runs() {
        let mut rng = Rng::new(2);
        let spec = FragmentalCnn1dSpec {
            input_len: 32,
            channels: 8,
            depth: 2,
            ..Default::default()
        };
        let net = build_cnn1d_fragmental(&spec, &mut rng);
        let x = Tensor::randn(&[2, 32, 3], 1.0, &mut rng);
        let y = net.forward(&x);
        assert_eq!(y.shape(), &[2, 8]);
    }

    #[test]
    fn invertible_stack_roundtrip() {
        let mut rng = Rng::new(3);
        let net = build_invertible_cnn2d(4, 3, 0.2, &mut rng);
        let x = Tensor::randn(&[1, 5, 5, 4], 1.0, &mut rng);
        let mut y = x.clone();
        for l in &net.layers {
            y = l.forward(&y);
        }
        for l in net.layers.iter().rev() {
            y = l.inverse(&y).unwrap();
        }
        crate::tensor::assert_close(&y, &x, 1e-3, "invertible roundtrip");
    }

    #[test]
    fn mlp_param_count() {
        let mut rng = Rng::new(4);
        let net = build_mlp(&[10, 8, 6], 0.1, &mut rng);
        assert_eq!(net.n_params(), 10 * 8 + 8 + 8 * 6 + 6);
    }

    #[test]
    fn copy_params_from_broadcasts_bit_exact() {
        let mut rng_a = Rng::new(20);
        let mut rng_b = Rng::new(21);
        let src = build_mlp(&[6, 5, 3], 0.1, &mut rng_a);
        let mut dst = build_mlp(&[6, 5, 3], 0.1, &mut rng_b);
        assert_ne!(src.layers[0].params()[0].data(), dst.layers[0].params()[0].data());
        dst.copy_params_from(&src).unwrap();
        for (ls, ld) in src.layers.iter().zip(&dst.layers) {
            for (ps, pd) in ls.params().iter().zip(ld.params()) {
                assert_eq!(ps.data(), pd.data());
            }
        }
        // Architecture mismatch is rejected.
        let mut other = build_mlp(&[6, 4, 3], 0.1, &mut rng_a);
        assert!(other.copy_params_from(&src).is_err());
        let mut shallow = build_mlp(&[6, 3], 0.1, &mut rng_a);
        assert!(shallow.copy_params_from(&src).is_err());
    }

    #[test]
    fn export_import_roundtrip() {
        let mut rng_a = Rng::new(30);
        let mut rng_b = Rng::new(31);
        let src = build_mlp(&[6, 5, 3], 0.1, &mut rng_a);
        let mut dst = build_mlp(&[6, 5, 3], 0.1, &mut rng_b);
        let exported = src.export_params();
        assert_eq!(exported.len(), src.depth());
        dst.import_params(&exported).unwrap();
        for (ls, ld) in src.layers.iter().zip(&dst.layers) {
            for (ps, pd) in ls.params().iter().zip(ld.params()) {
                assert_eq!(ps.data(), pd.data(), "roundtrip must be bit-exact");
            }
        }
        // Mismatched shapes are rejected.
        let mut other = build_mlp(&[6, 4, 3], 0.1, &mut rng_a);
        assert!(other.import_params(&exported).is_err());
        assert!(dst.import_params(&exported[..1].to_vec()).is_err());
    }

    #[test]
    fn project_makes_submersive() {
        let mut rng = Rng::new(5);
        let spec = SubmersiveCnn2dSpec {
            constrained: false,
            input_hw: 16,
            depth: 2,
            channels: 4,
            ..Default::default()
        };
        let mut net = build_cnn2d(&spec, &mut rng);
        assert!(!net.is_submersive());
        net.project_submersive();
        // Upsample stays non-submersive by construction; all convs fixed.
        assert!(net.audit()[1..].iter().all(|s| s.is_submersive()));
    }
}
