//! JSON-backed experiment configuration (the framework's config system).
//!
//! A config names an architecture family, its hyperparameters, the
//! gradient engine, and the training setup; `examples/` and the CLI load
//! these from files or inline JSON. Unknown fields are ignored so configs
//! stay forward-compatible.

use crate::model::{FragmentalCnn1dSpec, Network, RevNetSpec, RevNetVariant, SubmersiveCnn2dSpec};
use crate::util::json::Json;
use crate::util::Rng;

/// Architecture family selector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ArchKind {
    Cnn2d,
    Cnn1dFragmental,
    Invertible,
    Mlp,
    /// Reversible block stack (`model::build_revnet`); the `revnet_variant`
    /// field picks the block family.
    RevNet,
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub arch: ArchKind,
    pub depth: usize,
    pub channels: usize,
    pub input_hw: usize,
    pub input_len: usize,
    pub cin: usize,
    pub classes: usize,
    pub alpha: f32,
    pub constrained: bool,
    pub batch: usize,
    /// Gradient engine name (see `autodiff::engine_by_name`).
    pub engine: String,
    /// Fragmental block size (1-D configs).
    pub block: usize,
    /// Checkpoint segment count (checkpointed engines); 0 = auto √L.
    pub checkpoint_every: usize,
    /// Reversible block family for `arch = "revnet"`
    /// (`coupling` | `momentum` | `residual` | `mixed`).
    pub revnet_variant: String,
    /// Momentum-block damping γ for `arch = "revnet"`.
    pub gamma: f32,
    pub steps: usize,
    pub lr: f64,
    pub optimizer: String,
    pub seed: u64,
    pub dataset_size: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            arch: ArchKind::Cnn2d,
            depth: 4,
            channels: 32,
            input_hw: 64,
            input_len: 512,
            cin: 3,
            classes: 8,
            alpha: 0.1,
            constrained: true,
            batch: 4,
            engine: "moonwalk".into(),
            block: 4,
            checkpoint_every: 0,
            revnet_variant: "coupling".into(),
            gamma: 0.9,
            steps: 100,
            lr: 1e-3,
            optimizer: "adam".into(),
            seed: 0,
            dataset_size: 512,
        }
    }
}

impl Config {
    /// Parse from a JSON object; missing fields fall back to defaults.
    pub fn from_json(j: &Json) -> anyhow::Result<Config> {
        let d = Config::default();
        let arch = match j.opt_str("arch", "cnn2d") {
            "cnn2d" => ArchKind::Cnn2d,
            "cnn1d_fragmental" | "cnn1d" => ArchKind::Cnn1dFragmental,
            "invertible" => ArchKind::Invertible,
            "mlp" => ArchKind::Mlp,
            "revnet" => ArchKind::RevNet,
            other => anyhow::bail!("unknown arch `{other}`"),
        };
        let revnet_variant = j.opt_str("revnet_variant", &d.revnet_variant).to_string();
        match revnet_variant.as_str() {
            "coupling" | "momentum" | "residual" | "mixed" => {}
            other => anyhow::bail!("unknown revnet_variant `{other}`"),
        }
        Ok(Config {
            arch,
            depth: j.opt_usize("depth", d.depth),
            channels: j.opt_usize("channels", d.channels),
            input_hw: j.opt_usize("input_hw", d.input_hw),
            input_len: j.opt_usize("input_len", d.input_len),
            cin: j.opt_usize("cin", d.cin),
            classes: j.opt_usize("classes", d.classes),
            alpha: j.opt_f64("alpha", d.alpha as f64) as f32,
            constrained: j.opt_bool("constrained", d.constrained),
            batch: j.opt_usize("batch", d.batch),
            engine: j.opt_str("engine", &d.engine).to_string(),
            block: j.opt_usize("block", d.block),
            checkpoint_every: j.opt_usize("checkpoint_every", d.checkpoint_every),
            revnet_variant,
            gamma: j.opt_f64("gamma", d.gamma as f64) as f32,
            steps: j.opt_usize("steps", d.steps),
            lr: j.opt_f64("lr", d.lr),
            optimizer: j.opt_str("optimizer", &d.optimizer).to_string(),
            seed: j.opt_usize("seed", d.seed as usize) as u64,
            dataset_size: j.opt_usize("dataset_size", d.dataset_size),
        })
    }

    /// Load from a JSON file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Config::from_json(&j)
    }

    /// Serialize (for run provenance in metric logs).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "arch",
                match self.arch {
                    ArchKind::Cnn2d => "cnn2d",
                    ArchKind::Cnn1dFragmental => "cnn1d_fragmental",
                    ArchKind::Invertible => "invertible",
                    ArchKind::Mlp => "mlp",
                    ArchKind::RevNet => "revnet",
                }
                .into(),
            ),
            ("depth", self.depth.into()),
            ("channels", self.channels.into()),
            ("input_hw", self.input_hw.into()),
            ("input_len", self.input_len.into()),
            ("cin", self.cin.into()),
            ("classes", self.classes.into()),
            ("alpha", (self.alpha as f64).into()),
            ("constrained", self.constrained.into()),
            ("batch", self.batch.into()),
            ("engine", self.engine.as_str().into()),
            ("block", self.block.into()),
            ("checkpoint_every", self.checkpoint_every.into()),
            ("revnet_variant", self.revnet_variant.as_str().into()),
            ("gamma", (self.gamma as f64).into()),
            ("steps", self.steps.into()),
            ("lr", self.lr.into()),
            ("optimizer", self.optimizer.as_str().into()),
            ("seed", (self.seed as usize).into()),
            ("dataset_size", self.dataset_size.into()),
        ])
    }

    /// Build the configured network.
    pub fn build_network(&self, rng: &mut Rng) -> Network {
        match self.arch {
            ArchKind::Cnn2d => crate::model::build_cnn2d(
                &SubmersiveCnn2dSpec {
                    cin: self.cin,
                    channels: self.channels,
                    depth: self.depth,
                    input_hw: self.input_hw,
                    classes: self.classes,
                    alpha: self.alpha,
                    constrained: self.constrained,
                },
                rng,
            ),
            ArchKind::Cnn1dFragmental => crate::model::build_cnn1d_fragmental(
                &FragmentalCnn1dSpec {
                    cin: self.cin,
                    channels: self.channels,
                    depth: self.depth,
                    input_len: self.input_len,
                    classes: self.classes,
                    alpha: self.alpha,
                },
                rng,
            ),
            ArchKind::Invertible => crate::model::build_invertible_cnn2d(
                self.channels,
                self.depth,
                self.alpha,
                rng,
            ),
            ArchKind::Mlp => {
                let mut dims = vec![self.channels; self.depth + 1];
                dims[self.depth] = self.classes;
                crate::model::build_mlp(&dims, self.alpha, rng)
            }
            ArchKind::RevNet => crate::model::build_revnet(
                &RevNetSpec {
                    channels: self.channels,
                    depth: self.depth,
                    variant: match self.revnet_variant.as_str() {
                        "momentum" => RevNetVariant::Momentum,
                        "residual" => RevNetVariant::Residual,
                        "mixed" => RevNetVariant::Mixed,
                        _ => RevNetVariant::Coupling,
                    },
                    gamma: self.gamma,
                },
                rng,
            ),
        }
    }

    /// Input shape for one batch under this config.
    pub fn input_shape(&self) -> Vec<usize> {
        match self.arch {
            ArchKind::Cnn2d => vec![self.batch, self.input_hw, self.input_hw, self.cin],
            ArchKind::Cnn1dFragmental => vec![self.batch, self.input_len, self.cin],
            ArchKind::Invertible => {
                vec![self.batch, self.input_hw, self.input_hw, self.channels]
            }
            ArchKind::Mlp => vec![self.batch, self.channels],
            ArchKind::RevNet => vec![self.batch, self.channels],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let c = Config::default();
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.depth, c.depth);
        assert_eq!(c2.engine, c.engine);
        assert_eq!(c2.arch, c.arch);
    }

    #[test]
    fn parse_partial() {
        let j = Json::parse(r#"{"arch": "cnn1d", "depth": 7}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.arch, ArchKind::Cnn1dFragmental);
        assert_eq!(c.depth, 7);
        assert_eq!(c.channels, Config::default().channels);
    }

    #[test]
    fn unknown_arch_rejected() {
        let j = Json::parse(r#"{"arch": "transformer"}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn builds_each_arch() {
        let mut rng = Rng::new(0);
        for arch in ["cnn2d", "cnn1d", "invertible", "mlp", "revnet"] {
            let j = Json::parse(&format!(
                r#"{{"arch": "{arch}", "depth": 2, "channels": 4, "input_hw": 16, "input_len": 16, "batch": 1}}"#
            ))
            .unwrap();
            let c = Config::from_json(&j).unwrap();
            let net = c.build_network(&mut rng);
            let x = Tensor::randn(&c.input_shape(), 1.0, &mut rng);
            let y = net.forward(&x);
            assert!(!y.is_empty(), "{arch} produced empty output");
        }
    }

    use crate::tensor::Tensor;
}
