//! Global allocation tracker — the reproduction's analogue of
//! `jax.device.memory_stats()` in the paper's experimental setup (§6.1).
//!
//! Every [`crate::tensor::Tensor`] (and the sign-bit residual store)
//! registers its payload bytes on allocation and releases them on drop.
//! Gradient engines report the **peak live bytes** observed between
//! [`reset_peak`] and the end of a gradient computation; this ranks methods
//! exactly as GPU peak memory would, because peak residual footprint is a
//! property of what the algorithm keeps alive, not of the device.
//!
//! Measurements that must not interleave (e.g. two engines measured from
//! concurrent tests) serialize through [`measure_lock`].

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Mutex, MutexGuard};

static CURRENT: AtomicI64 = AtomicI64::new(0);
static PEAK: AtomicI64 = AtomicI64::new(0);
static TOTAL_ALLOCS: AtomicI64 = AtomicI64::new(0);
static TOTAL_FREES: AtomicI64 = AtomicI64::new(0);

static MEASURE_MUTEX: Mutex<()> = Mutex::new(());

/// Register an allocation of `bytes`.
pub fn alloc(bytes: usize) {
    let now = CURRENT.fetch_add(bytes as i64, Ordering::Relaxed) + bytes as i64;
    TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
    // Monotone peak update.
    let mut peak = PEAK.load(Ordering::Relaxed);
    while now > peak {
        match PEAK.compare_exchange_weak(peak, now, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(p) => peak = p,
        }
    }
}

/// Release an allocation of `bytes`.
pub fn free(bytes: usize) {
    let prev = CURRENT.fetch_sub(bytes as i64, Ordering::Relaxed);
    TOTAL_FREES.fetch_add(1, Ordering::Relaxed);
    // Every free pairs with an earlier alloc of the same buffer, and
    // ownership handoffs synchronize, so the running sum can only go
    // negative if some path double-frees (or frees more bytes than it
    // registered) — an accounting bug that would silently corrupt peak
    // ranking. Catch it in debug builds.
    debug_assert!(
        prev >= bytes as i64,
        "tracker::free({bytes}) would drive live bytes negative (was {prev}): \
         double-free or mismatched alloc/free size"
    );
}

/// Currently live tracked bytes.
pub fn current() -> usize {
    CURRENT.load(Ordering::Relaxed).max(0) as usize
}

/// Peak live bytes since the last [`reset_peak`].
pub fn peak() -> usize {
    PEAK.load(Ordering::Relaxed).max(0) as usize
}

/// Number of tracked allocations since process start (allocation-churn
/// metric used by the §Perf pass).
pub fn total_allocs() -> usize {
    TOTAL_ALLOCS.load(Ordering::Relaxed).max(0) as usize
}

/// Number of tracked releases since process start. Together with
/// [`total_allocs`] this exposes leak drift:
/// `total_allocs - total_frees` should track the live object count.
pub fn total_frees() -> usize {
    TOTAL_FREES.load(Ordering::Relaxed).max(0) as usize
}

/// Reset the peak to the current live value.
pub fn reset_peak() {
    PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Acquire the global measurement lock. Hold this while measuring a
/// memory profile so that concurrent tests/threads do not pollute the peak.
pub fn measure_lock() -> MutexGuard<'static, ()> {
    match MEASURE_MUTEX.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A memory profile of a closure run.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemProfile {
    /// Peak live bytes during the run, minus the live bytes at entry —
    /// i.e. the *extra* memory the computation needed (the paper's
    /// "memory consumption ... extra amount of memory needed to compute
    /// gradients", §11).
    pub peak_extra_bytes: usize,
    /// Absolute peak during the run.
    pub peak_bytes: usize,
    /// Live bytes at entry (inputs, parameters).
    pub baseline_bytes: usize,
    /// Allocation count during the run.
    pub allocs: usize,
}

/// Run `f` under the measurement lock and report its memory profile.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, MemProfile) {
    let _guard = measure_lock();
    let baseline = current();
    let allocs0 = total_allocs();
    reset_peak();
    let out = f();
    let profile = MemProfile {
        peak_extra_bytes: peak().saturating_sub(baseline),
        peak_bytes: peak(),
        baseline_bytes: baseline,
        allocs: total_allocs() - allocs0,
    };
    (out, profile)
}

/// Pretty-print a byte count.
pub fn fmt_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn measure_tracks_peak_and_balance() {
        let (live_before, profile) = {
            let live_before = current();
            let (_, p) = measure(|| {
                let a = Tensor::zeros(&[1024]); // 4 KiB
                let b = Tensor::zeros(&[2048]); // 8 KiB
                drop(a);
                let c = Tensor::zeros(&[512]);
                drop(b);
                drop(c);
            });
            (live_before, p)
        };
        // Peak extra should be >= 12 KiB (a+b live together).
        assert!(profile.peak_extra_bytes >= 12 * 1024, "{profile:?}");
        // All freed: live returns to the pre-run value.
        assert_eq!(current(), live_before);
        assert!(profile.allocs >= 3);
    }

    /// The parallel tensor runtime allocates from pool workers; the
    /// tracker's atomics must stay balanced and the peak monotone under
    /// concurrent alloc/free traffic. Unit tests in other modules run
    /// concurrently and also allocate (outside `measure_lock`), so this
    /// asserts bounded drift rather than exact equality: this test's own
    /// traffic (4 threads × 400 × ~100 KiB) would drift far past the
    /// slack if add/sub updates were being lost.
    #[test]
    fn concurrent_allocs_stay_balanced() {
        let _guard = measure_lock();
        let live0 = current() as i64;
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    for i in 0..400usize {
                        let tns = Tensor::zeros(&[16 * 1024 + t * 64 + i]);
                        drop(tns);
                    }
                });
            }
        });
        let drift = (current() as i64 - live0).abs();
        assert!(
            drift < (4 << 20),
            "alloc/free drifted by {drift} bytes across threads"
        );
        // measure_lock is held, so nobody resets the peak under us.
        assert!(peak() as i64 >= live0);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }
}
