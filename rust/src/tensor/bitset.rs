//! Bit-packed residual storage.
//!
//! Paper §4.5 ("Residual Impact"): for LeakyReLU layers, Moonwalk needs only
//! the *sign* of each pre-activation to evaluate the activation vjp/vijp —
//! 1 bit per element instead of a 32-bit float, a 32× reduction that is the
//! main source of Phase-I memory savings. `BitTensor` stores exactly that,
//! and its (byte-rounded) size is registered with the allocation tracker so
//! memory profiles reflect the compression.

use crate::tensor::{tracker, Tensor};

/// A bit-per-element tensor (packed into u64 words).
#[derive(Debug)]
pub struct BitTensor {
    words: Vec<u64>,
    len: usize,
    shape: Vec<usize>,
}

impl BitTensor {
    /// Record the signs (`x >= 0`) of a tensor's elements.
    pub fn from_signs(x: &Tensor) -> BitTensor {
        let len = x.len();
        let n_words = (len + 63) / 64;
        tracker::alloc(n_words * 8);
        let mut words = vec![0u64; n_words];
        for (i, &v) in x.data().iter().enumerate() {
            if v >= 0.0 {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        BitTensor {
            words,
            len,
            shape: x.shape().to_vec(),
        }
    }

    /// Bit `i` (true = non-negative).
    #[inline(always)]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Tracked payload bytes (the 32× compression vs f32 storage).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

impl Drop for BitTensor {
    fn drop(&mut self) {
        tracker::free(self.words.len() * 8);
    }
}

impl Clone for BitTensor {
    fn clone(&self) -> BitTensor {
        tracker::alloc(self.words.len() * 8);
        BitTensor {
            words: self.words.clone(),
            len: self.len,
            shape: self.shape.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signs_roundtrip() {
        let x = Tensor::from_vec(vec![1.0, -2.0, 0.0, -0.5, 3.0], &[5]);
        let b = BitTensor::from_signs(&x);
        assert!(b.get(0));
        assert!(!b.get(1));
        assert!(b.get(2)); // zero counts as non-negative
        assert!(!b.get(3));
        assert!(b.get(4));
    }

    #[test]
    fn compression_ratio() {
        let x = Tensor::zeros(&[1024]);
        let b = BitTensor::from_signs(&x);
        assert_eq!(b.bytes(), 128); // 1024 bits = 128 bytes vs 4096 bytes f32
        assert_eq!(x.bytes() / b.bytes(), 32);
    }

    #[test]
    fn tracker_balance() {
        let live0 = tracker::current();
        {
            let x = Tensor::zeros(&[100]);
            let _b = BitTensor::from_signs(&x);
        }
        assert_eq!(tracker::current(), live0);
    }
}
