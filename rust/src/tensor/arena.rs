//! Thread-aware scratch-buffer arena (§Perf iteration 5).
//!
//! The conv/GEMM hot paths need transient buffers — per-tap gathers,
//! transposed tap weights, the vijp channel-major workspace, and the
//! im2col/Winograd conv workspaces (sized by
//! [`crate::tensor::conv_algo::workspace_bytes`]) — that the
//! seed implementation allocated as fresh [`Tensor`]s on every call,
//! dominating the allocation-churn metric (`tracker::total_allocs`).
//! This arena recycles those buffers process-wide so Moonwalk's Phase
//! I/II/III sweeps run **allocation-free in steady state**: after the
//! first step every `take` is a hit and the tracker records no new
//! allocations.
//!
//! Concurrency: a single mutex-guarded free list shared by all threads
//! (the persistent pool's workers included — buffers migrate freely
//! between workers, which keeps the list balanced when the team is
//! resized via `pool::set_threads`). Pool workers take/return at most a
//! few buffers per kernel call, so contention is negligible next to the
//! kernels. Which physical buffer a worker receives never affects
//! results: [`take`] leaves the contents unspecified and every caller
//! fully overwrites its lease, while accumulators use [`take_zeroed`].
//!
//! Accounting: a fresh allocation registers its capacity with the
//! [`tracker`] (so peak-memory profiles still see scratch); a recycled
//! hit does not re-register (the bytes are already live). Evicted or
//! [`clear`]ed buffers release their bytes.

use std::ops::{Deref, DerefMut};
use std::sync::Mutex;

use crate::tensor::tracker;

/// Max buffers kept on the free list; excess returns are freed.
const MAX_POOLED: usize = 64;

static POOL: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());

/// Arena misses (fresh allocations) since process start — the §Perf
/// steady-state metric: after warm-up this should stop moving.
static MISSES: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Arena hits (recycled leases) since process start — together with
/// [`misses`] this gives the recycle rate the perf harness reports.
static HITS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Fresh allocations performed by the arena since process start.
pub fn misses() -> usize {
    MISSES.load(std::sync::atomic::Ordering::Relaxed)
}

/// Recycled (allocation-free) leases since process start.
pub fn hits() -> usize {
    HITS.load(std::sync::atomic::Ordering::Relaxed)
}

fn lock() -> std::sync::MutexGuard<'static, Vec<Vec<f32>>> {
    match POOL.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A scratch buffer leased from the arena; returns to the free list on
/// drop. Derefs to `[f32]` of exactly the requested length.
pub struct Scratch {
    buf: Vec<f32>,
}

impl Deref for Scratch {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() == 0 {
            return;
        }
        let mut pool = lock();
        if pool.len() < MAX_POOLED {
            pool.push(buf);
            return;
        }
        // Pool full: keep the larger buffer. Evicting the smallest pooled
        // one (rather than always dropping the newcomer) prevents a full
        // pool of small buffers from forcing the biggest leases — the
        // most expensive ones — to miss on every step.
        let smallest = pool
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, b)| (i, b.capacity()));
        match smallest {
            Some((i, cap)) if cap < buf.capacity() => {
                let evicted = pool.swap_remove(i);
                tracker::free(evicted.capacity() * 4);
                pool.push(buf);
            }
            _ => tracker::free(buf.capacity() * 4),
        }
    }
}

/// Lease a scratch buffer of `len` f32s with **unspecified contents**
/// (recycled buffers keep stale data — callers must fully overwrite, or
/// use [`take_zeroed`]). Best-fit over the free list; allocates (and
/// tracker-registers) only on a miss.
pub fn take(len: usize) -> Scratch {
    if len == 0 {
        return Scratch { buf: Vec::new() };
    }
    let _sp = crate::span!("arena.take", len = len);
    let reused = {
        let mut pool = lock();
        // Best fit: the smallest pooled buffer that is large enough, so
        // big buffers stay available for big requests.
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in pool.iter().enumerate() {
            let cap = b.capacity();
            if cap < len {
                continue;
            }
            match best {
                Some((_, bc)) if cap >= bc => {}
                _ => best = Some((i, cap)),
            }
        }
        best.map(|(i, _)| pool.swap_remove(i))
    };
    let mut buf = match reused {
        Some(b) => {
            HITS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            b
        }
        None => {
            let b: Vec<f32> = Vec::with_capacity(len);
            tracker::alloc(b.capacity() * 4);
            MISSES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            b
        }
    };
    // Avoid the O(len) memset on the steady-state hit path: keep stale
    // contents when shrinking, zero-extend (safe Rust requires it) when
    // the recycled buffer's len is short of the request.
    if buf.len() >= len {
        buf.truncate(len);
    } else {
        buf.resize(len, 0.0);
    }
    Scratch { buf }
}

/// Lease a zero-filled scratch buffer (for accumulators).
pub fn take_zeroed(len: usize) -> Scratch {
    let mut s = take(len);
    s.fill(0.0);
    s
}

/// Drop every pooled buffer (releasing its tracked bytes). Mainly for
/// tests that assert tracker balance.
pub fn clear() {
    let mut pool = lock();
    for b in pool.drain(..) {
        tracker::free(b.capacity() * 4);
    }
}

/// Number of buffers currently pooled (diagnostics).
pub fn pooled() -> usize {
    lock().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_sized_and_take_zeroed_is_zeroed() {
        let s = take(37);
        assert_eq!(s.len(), 37);
        drop(s);
        // A recycled buffer may carry stale data through `take`...
        let mut s = take(37);
        s.fill(7.0);
        drop(s);
        // ...but take_zeroed must always hand back zeros.
        let z = take_zeroed(37);
        assert_eq!(z.len(), 37);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn recycle_avoids_fresh_allocations() {
        // Warm: force one allocation of this (unusual) size.
        let len = 12_345;
        drop(take(len));
        let misses0 = misses();
        for _ in 0..100 {
            let mut s = take(len);
            s[0] = 1.0; // use it
        }
        // Unit tests run concurrently and share the process-global free
        // list, so a neighbor can best-fit-steal this buffer in the gap
        // between our drop and the next take — each steal costs one
        // miss. Bound statistically: without recycling this loop alone
        // records 100 misses; steals hitting the tiny gap more than a
        // handful of times in 100 iterations is vanishingly unlikely.
        assert!(
            misses() - misses0 <= 10,
            "steady-state takes should be (nearly) allocation-free: {} misses in 100 takes",
            misses() - misses0
        );
    }

    #[test]
    fn distinct_leases_are_distinct_buffers() {
        let mut a = take(16);
        let mut b = take(16);
        a[0] = 1.0;
        b[0] = 2.0;
        assert_eq!(a[0], 1.0);
        assert_eq!(b[0], 2.0);
    }

    #[test]
    fn zero_len_is_fine() {
        let s = take(0);
        assert_eq!(s.len(), 0);
    }
}
