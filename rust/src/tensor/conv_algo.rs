//! Convolution algorithm dispatch + autotune cache (the cuDNN
//! `cudnnConvolutionFwdAlgo_t` idea, in-process).
//!
//! `GemmAlgo` picks *how a GEMM runs*; [`ConvAlgo`] picks *which
//! lowering a convolution uses* before any GEMM is reached:
//!
//! * [`ConvAlgo::Direct`] — the hand-tuned per-tap kernels in
//!   `nn/conv1d.rs` / `nn/conv2d.rs`. Always applicable; **the
//!   reference** every other algorithm is tested against, and the
//!   bit-compatibility anchor (see the determinism contract below).
//! * [`ConvAlgo::Im2col`] — lower the conv onto one large
//!   `[positions, k·k·Cin] · [k·k·Cin, Cout]` product and reuse the
//!   blocked/parallel GEMM dispatchers (`matmul_*_into_auto`).
//!   Applicable to conv1d/conv2d forward and `vjp_params`.
//! * [`ConvAlgo::Winograd`] — F(2×2, 3×3) fast convolution for
//!   stride-1 3×3 conv2d forward: 16 per-transform-position GEMMs of
//!   shape `[tiles, Cin] · [Cin, Cout]` replace the 9-tap direct sweep
//!   (2.25× fewer multiplies in the large-channel limit). The
//!   F(2×2,3×3) transform matrices are exact in binary floating point
//!   (entries in {0, ±1, ±½}), so the only rounding difference vs
//!   Direct is summation order.
//!
//! The vijp (Eq. 9) stays **Direct-only**: the triangular elimination /
//! wavefront schedules are tied to the pivot-tap structure and have no
//! im2col/Winograd analogue; a forced override simply falls back (see
//! [`applicable`]).
//!
//! Every algorithm declares its workspace ([`workspace_bytes`]) and
//! serves scratch from [`crate::tensor::arena`], so the tracker
//! accounting the planner relies on stays honest.
//!
//! # Selection and the determinism contract
//!
//! Resolution order ([`resolve`]): forced override (`--conv-algo` /
//! `MOONWALK_CONV`) → autotune-cache hit → Direct. There is **no lazy
//! self-timing in default paths**: wall-clock measurements inside a
//! forward pass would make results depend on machine load, breaking
//! the bit-exactness contracts (unix vs local transports, fixed-thread
//! run-to-run) that the test suite pins. Calibration happens only
//! through the explicit entry points — `Conv1d::autotune` /
//! `Conv2d::autotune`, `plan::calibrate_convs`, and the `conv_rows`
//! bench family — which time the applicable candidates once
//! ([`record`]s the winner) and persist the table via
//! [`crate::runtime::artifacts::TuneTable`] when a cache path is
//! configured (`--conv-cache` / `MOONWALK_CONV_CACHE`). Later runs and
//! respawned replica workers load the same table, so every process
//! sharing a cache file resolves every conv identically and compiles
//! identical plans. With no override and no cache entry the default is
//! exactly today's Direct kernels, bit for bit.
//!
//! Cache keys ([`key`]) are canonical `(op, shape, threads)` strings;
//! the thread component is the *kernel-effective* count (1 inside a
//! pool worker — where nested parallelism is suppressed — so
//! in-process replicas and single-threaded worker subprocesses agree
//! on the key and therefore on the resolution).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

use crate::runtime::artifacts::{TuneEntry, TuneTable};
use crate::runtime::pool;
use crate::util::lock_ignore_poison;

/// A convolution lowering. See the module docs for the lattice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ConvAlgo {
    /// The hand-tuned per-tap kernels — always applicable, the
    /// reference and the bit-compatibility anchor.
    Direct,
    /// Lower onto one blocked/parallel GEMM over gathered patches.
    Im2col,
    /// F(2×2, 3×3) Winograd fast convolution (conv2d forward,
    /// `k == 3 && s == 1` only).
    Winograd,
}

impl ConvAlgo {
    /// Stable lowercase label (cache files, CLI, bench JSON).
    pub fn label(self) -> &'static str {
        match self {
            ConvAlgo::Direct => "direct",
            ConvAlgo::Im2col => "im2col",
            ConvAlgo::Winograd => "winograd",
        }
    }

    /// Parse a [`ConvAlgo::label`] spelling. `None` for anything else
    /// (including `"auto"`, which is not an algorithm).
    pub fn parse(name: &str) -> Option<ConvAlgo> {
        match name.trim().to_ascii_lowercase().as_str() {
            "direct" => Some(ConvAlgo::Direct),
            "im2col" => Some(ConvAlgo::Im2col),
            "winograd" => Some(ConvAlgo::Winograd),
            _ => None,
        }
    }
}

/// Which convolution operator is being dispatched. Forward and
/// `vjp_params` are autotunable; the vijp entries exist so the lattice
/// covers the whole operator quartet (they resolve to Direct — the
/// elimination/wavefront schedules have no alternative lowering).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ConvOp {
    /// `Conv1d` forward (also jvp: same contraction, different data).
    Conv1dFwd,
    /// `Conv1d::vjp_params`.
    Conv1dVjpParams,
    /// `Conv1d::vijp` (Direct-only).
    Conv1dVijp,
    /// `Conv2d` forward (also jvp).
    Conv2dFwd,
    /// `Conv2d::vjp_params`.
    Conv2dVjpParams,
    /// `Conv2d::vijp` (Direct-only).
    Conv2dVijp,
}

impl ConvOp {
    /// Stable label used as the leading component of cache keys.
    pub fn label(self) -> &'static str {
        match self {
            ConvOp::Conv1dFwd => "conv1d_fwd",
            ConvOp::Conv1dVjpParams => "conv1d_vjpw",
            ConvOp::Conv1dVijp => "conv1d_vijp",
            ConvOp::Conv2dFwd => "conv2d_fwd",
            ConvOp::Conv2dVjpParams => "conv2d_vjpw",
            ConvOp::Conv2dVijp => "conv2d_vijp",
        }
    }
}

/// The geometry of one conv invocation — everything the cache key and
/// the workspace query need. For 1-D convs `w`/`wo` are 0 and `h`/`ho`
/// carry the length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvDims {
    /// Batch size.
    pub n: usize,
    /// Input spatial height (1-D: length).
    pub h: usize,
    /// Input spatial width (1-D: 0).
    pub w: usize,
    /// Output spatial height (1-D: output length).
    pub ho: usize,
    /// Output spatial width (1-D: 0).
    pub wo: usize,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Kernel size (square for 2-D).
    pub k: usize,
    /// Stride.
    pub s: usize,
    /// Zero padding.
    pub p: usize,
}

impl ConvDims {
    /// Output positions per image (`ho` for 1-D, `ho·wo` for 2-D).
    pub fn positions(&self) -> usize {
        self.ho * self.wo.max(1)
    }

    /// Patch row length for im2col (`k·cin` 1-D, `k²·cin` 2-D).
    pub fn patch_len(&self) -> usize {
        if self.wo == 0 {
            self.k * self.cin
        } else {
            self.k * self.k * self.cin
        }
    }
}

/// The thread count a cache key carries: the *kernel-effective* one.
/// Inside a pool worker nested parallelism is suppressed (kernels run
/// serial), so in-process replicas key on 1 — exactly like the
/// single-threaded worker subprocesses — and every executor sharing a
/// cache file resolves identically.
fn key_threads() -> usize {
    if pool::in_worker() {
        1
    } else {
        pool::threads()
    }
}

/// The canonical autotune-cache key for `(op, shape, threads)`, e.g.
/// `conv2d_fwd n2 hw32x32 c16>16 k3 s1 p1 t4`.
pub fn key(op: ConvOp, d: &ConvDims) -> String {
    format!(
        "{} n{} hw{}x{} c{}>{} k{} s{} p{} t{}",
        op.label(),
        d.n,
        d.h,
        d.w,
        d.cin,
        d.cout,
        d.k,
        d.s,
        d.p,
        key_threads()
    )
}

/// Whether `algo` can execute `op` on this geometry at all. Forcing an
/// inapplicable algorithm (e.g. `--conv-algo winograd` on a strided
/// conv, or anything non-Direct on a vijp) falls back to Direct rather
/// than erroring — an override is a preference lattice, not a promise.
pub fn applicable(algo: ConvAlgo, op: ConvOp, d: &ConvDims) -> bool {
    match algo {
        ConvAlgo::Direct => true,
        ConvAlgo::Im2col => matches!(
            op,
            ConvOp::Conv1dFwd
                | ConvOp::Conv1dVjpParams
                | ConvOp::Conv2dFwd
                | ConvOp::Conv2dVjpParams
        ),
        ConvAlgo::Winograd => op == ConvOp::Conv2dFwd && d.k == 3 && d.s == 1,
    }
}

/// The applicable candidate set for `(op, dims)`, Direct first.
pub fn candidates(op: ConvOp, d: &ConvDims) -> Vec<ConvAlgo> {
    [ConvAlgo::Direct, ConvAlgo::Im2col, ConvAlgo::Winograd]
        .into_iter()
        .filter(|a| applicable(*a, op, d))
        .collect()
}

/// Workspace bytes `algo` leases from `tensor::arena` for one `(op,
/// dims)` invocation — the declared scratch high-water mark per
/// in-flight image (the tracker measures the truth at run time; this
/// is the planning/documentation figure, like cuDNN's
/// `getWorkspaceSize`).
pub fn workspace_bytes(algo: ConvAlgo, op: ConvOp, d: &ConvDims) -> usize {
    let pos = d.positions();
    let f32s = match (algo, op) {
        // Direct conv2d forward/vjp gather one tap band at a time:
        // positions × Cin. Direct conv1d builds per-image patches.
        (ConvAlgo::Direct, ConvOp::Conv2dFwd | ConvOp::Conv2dVjpParams) => pos * d.cin,
        (ConvAlgo::Direct, ConvOp::Conv1dFwd) => pos * d.patch_len(),
        (ConvAlgo::Direct, ConvOp::Conv1dVjpParams) => 0,
        (ConvAlgo::Direct, ConvOp::Conv1dVijp | ConvOp::Conv2dVijp) => pos * d.cout,
        // Im2col materializes the full patch matrix.
        (ConvAlgo::Im2col, _) => pos * d.patch_len(),
        // Winograd: V (16·tiles·Cin) + U (16·Cin·Cout) + M
        // (16·tiles·Cout), tiles = ⌈ho/2⌉·⌈wo/2⌉.
        (ConvAlgo::Winograd, _) => {
            let tiles = d.ho.div_ceil(2) * d.wo.div_ceil(2);
            16 * (tiles * d.cin + d.cin * d.cout + tiles * d.cout)
        }
    };
    f32s * 4
}

// ----- override --------------------------------------------------------------

// Cached MOONWALK_CONV override: 0 unresolved, 1 auto, 2 direct,
// 3 im2col, 4 winograd (same idiom as ops::GEMM_OVERRIDE).
static CONV_OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn override_state() -> u8 {
    let v = CONV_OVERRIDE.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let v = match std::env::var("MOONWALK_CONV") {
        Err(_) => 1,
        Ok(raw) => match raw.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => 1,
            "direct" => 2,
            "im2col" => 3,
            "winograd" => 4,
            other => {
                // Warn exactly once (the result is cached): a perf knob
                // that is silently ignored produces wrong measurements.
                eprintln!(
                    "warning: MOONWALK_CONV=`{other}` not recognized \
                     (auto|direct|im2col|winograd); using auto"
                );
                1
            }
        },
    };
    CONV_OVERRIDE.store(v, Ordering::Relaxed);
    v
}

/// The forced algorithm, if any (`None` = auto: cache → Direct).
pub fn conv_override() -> Option<ConvAlgo> {
    match override_state() {
        2 => Some(ConvAlgo::Direct),
        3 => Some(ConvAlgo::Im2col),
        4 => Some(ConvAlgo::Winograd),
        _ => None,
    }
}

/// Force a conv algorithm globally: `"auto"`, `"direct"`, `"im2col"`
/// or `"winograd"` (the CLI's `--conv-algo`; `MOONWALK_CONV` is the
/// env spelling).
pub fn set_conv_override(name: &str) -> anyhow::Result<()> {
    let v = match name {
        "auto" => 1,
        "direct" => 2,
        "im2col" => 3,
        "winograd" => 4,
        other => {
            anyhow::bail!("unknown conv algorithm `{other}` (auto|direct|im2col|winograd)")
        }
    };
    CONV_OVERRIDE.store(v, Ordering::Relaxed);
    Ok(())
}

// ----- autotune cache --------------------------------------------------------

struct CacheState {
    /// Whether the persisted table (if any) has been loaded.
    loaded: bool,
    /// Explicit path (`set_cache_path`); else `MOONWALK_CONV_CACHE`.
    path: Option<PathBuf>,
    /// key → (winner, measured ms).
    entries: BTreeMap<String, (ConvAlgo, f64)>,
}

static CACHE: Mutex<CacheState> = Mutex::new(CacheState {
    loaded: false,
    path: None,
    entries: BTreeMap::new(),
});

fn ensure_loaded(state: &mut CacheState) {
    if state.loaded {
        return;
    }
    state.loaded = true;
    if state.path.is_none() {
        if let Ok(p) = std::env::var("MOONWALK_CONV_CACHE") {
            if !p.trim().is_empty() {
                state.path = Some(PathBuf::from(p));
            }
        }
    }
    if let Some(path) = state.path.clone() {
        let table = TuneTable::load(&path);
        for (k, e) in table.entries {
            if let Some(algo) = ConvAlgo::parse(&e.algo) {
                state.entries.insert(k, (algo, e.ms));
            }
            // Unknown labels (a newer writer) are skipped, not fatal:
            // resolution for that key falls back to Direct.
        }
    }
}

/// Point the cache at a table file and (re)load it. The CLI's
/// `--conv-cache`; `MOONWALK_CONV_CACHE` is the env spelling the
/// coordinator exports to worker subprocesses.
pub fn set_cache_path(path: &str) {
    let mut state = lock_ignore_poison(&CACHE);
    state.path = Some(PathBuf::from(path));
    state.loaded = false;
    state.entries.clear();
    ensure_loaded(&mut state);
}

/// The active cache path, if any (after lazy env resolution).
pub fn cache_path() -> Option<PathBuf> {
    let mut state = lock_ignore_poison(&CACHE);
    ensure_loaded(&mut state);
    state.path.clone()
}

/// Drop the in-memory table and reload from the configured path — what
/// a freshly spawned process sharing the cache file would see. Used by
/// the shared-cache tests and the `conv_rows` second-resolve column.
pub fn reload() {
    let mut state = lock_ignore_poison(&CACHE);
    state.entries.clear();
    state.loaded = false;
    ensure_loaded(&mut state);
}

/// The cached `(winner, ms)` for `(op, dims)` at the current
/// kernel-effective thread count, if one was ever recorded.
pub fn cached(op: ConvOp, d: &ConvDims) -> Option<(ConvAlgo, f64)> {
    let k = key(op, d);
    let mut state = lock_ignore_poison(&CACHE);
    ensure_loaded(&mut state);
    state.entries.get(&k).copied()
}

/// The cached winner's measured milliseconds for a canonical `key`
/// string (the timed-probe column's lookup; pure given a fixed table).
pub fn cached_time_ms(cache_key: &str) -> Option<f64> {
    let mut state = lock_ignore_poison(&CACHE);
    ensure_loaded(&mut state);
    state.entries.get(cache_key).map(|(_, ms)| *ms)
}

/// Number of in-memory cache entries (diagnostics / bench reporting).
pub fn cache_len() -> usize {
    let mut state = lock_ignore_poison(&CACHE);
    ensure_loaded(&mut state);
    state.entries.len()
}

/// Record a calibrated winner for `(op, dims)` and persist the table
/// if a cache path is configured (best-effort: a read-only filesystem
/// degrades to per-process calibration, never failure).
pub fn record(op: ConvOp, d: &ConvDims, algo: ConvAlgo, ms: f64) {
    let k = key(op, d);
    let mut state = lock_ignore_poison(&CACHE);
    ensure_loaded(&mut state);
    state.entries.insert(k, (algo, ms));
    if let Some(path) = state.path.clone() {
        let mut table = TuneTable::default();
        for (key, (algo, ms)) in &state.entries {
            table.entries.insert(
                key.clone(),
                TuneEntry {
                    algo: algo.label().to_string(),
                    ms: *ms,
                },
            );
        }
        if let Err(e) = table.save(&path) {
            crate::log_warn!("conv autotune table not persisted: {e:#}");
        }
    }
}

/// Resolve the algorithm for `(op, dims)`: forced override (if
/// applicable) → cache hit (if still applicable) → Direct. This is the
/// **deterministic-by-default** contract: no wall-clock enters the
/// decision, so for a fixed override/cache state every process picks
/// the same lowering (see the module docs).
pub fn resolve(op: ConvOp, d: &ConvDims) -> ConvAlgo {
    if let Some(forced) = conv_override() {
        return if applicable(forced, op, d) {
            forced
        } else {
            ConvAlgo::Direct
        };
    }
    match cached(op, d) {
        Some((algo, _)) if applicable(algo, op, d) => algo,
        _ => ConvAlgo::Direct,
    }
}

/// One calibration outcome (what `Conv1d::autotune` /
/// `Conv2d::autotune` return and the `conv_rows` bench reports).
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// The canonical cache key that was (re)calibrated.
    pub key: String,
    /// The winning algorithm.
    pub algo: ConvAlgo,
    /// The winner's measured median, ms.
    pub best_ms: f64,
    /// Every timed candidate: `(algo, median ms)`, Direct first.
    pub candidates: Vec<(ConvAlgo, f64)>,
    /// True when the result came from the cache (no timing ran).
    pub cached: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims2d() -> ConvDims {
        ConvDims {
            n: 2,
            h: 9,
            w: 9,
            ho: 9,
            wo: 9,
            cin: 3,
            cout: 3,
            k: 3,
            s: 1,
            p: 1,
        }
    }

    #[test]
    fn applicability_lattice() {
        let d = dims2d();
        assert!(applicable(ConvAlgo::Direct, ConvOp::Conv2dVijp, &d));
        assert!(!applicable(ConvAlgo::Im2col, ConvOp::Conv2dVijp, &d));
        assert!(!applicable(ConvAlgo::Winograd, ConvOp::Conv2dVjpParams, &d));
        assert!(applicable(ConvAlgo::Winograd, ConvOp::Conv2dFwd, &d));
        let strided = ConvDims { s: 2, ..d };
        assert!(!applicable(ConvAlgo::Winograd, ConvOp::Conv2dFwd, &strided));
        assert_eq!(
            candidates(ConvOp::Conv2dFwd, &d),
            vec![ConvAlgo::Direct, ConvAlgo::Im2col, ConvAlgo::Winograd]
        );
        assert_eq!(candidates(ConvOp::Conv1dVijp, &d), vec![ConvAlgo::Direct]);
    }

    #[test]
    fn labels_roundtrip() {
        for a in [ConvAlgo::Direct, ConvAlgo::Im2col, ConvAlgo::Winograd] {
            assert_eq!(ConvAlgo::parse(a.label()), Some(a));
        }
        assert_eq!(ConvAlgo::parse("auto"), None);
        assert_eq!(ConvAlgo::parse("fft"), None);
    }

    #[test]
    fn workspace_declared_for_every_candidate() {
        let d = dims2d();
        for op in [ConvOp::Conv2dFwd, ConvOp::Conv2dVjpParams, ConvOp::Conv2dVijp] {
            for a in candidates(op, &d) {
                // Direct conv1d vjp_params is the only zero-workspace
                // combination; every 2-D candidate leases scratch.
                assert!(workspace_bytes(a, op, &d) > 0, "{op:?}/{a:?}");
            }
        }
        // Winograd's workspace beats im2col's k²-fold patch matrix on
        // this shape in the channel terms it replaces.
        let wino = workspace_bytes(ConvAlgo::Winograd, ConvOp::Conv2dFwd, &d);
        assert!(wino > 0);
    }

    #[test]
    fn key_is_canonical_and_thread_tagged() {
        let d = dims2d();
        let k = key(ConvOp::Conv2dFwd, &d);
        assert!(k.starts_with("conv2d_fwd n2 hw9x9 c3>3 k3 s1 p1 t"), "{k}");
    }

    #[test]
    fn resolve_default_is_direct_and_record_sticks() {
        // Distinct geometry so this test cannot collide with others
        // sharing the process-global cache.
        let d = ConvDims {
            n: 7,
            h: 31,
            w: 31,
            ho: 31,
            wo: 31,
            cin: 5,
            cout: 5,
            k: 3,
            s: 1,
            p: 1,
        };
        assert_eq!(resolve(ConvOp::Conv2dFwd, &d), ConvAlgo::Direct);
        record(ConvOp::Conv2dFwd, &d, ConvAlgo::Winograd, 0.25);
        assert_eq!(resolve(ConvOp::Conv2dFwd, &d), ConvAlgo::Winograd);
        assert_eq!(cached(ConvOp::Conv2dFwd, &d), Some((ConvAlgo::Winograd, 0.25)));
        assert_eq!(cached_time_ms(&key(ConvOp::Conv2dFwd, &d)), Some(0.25));
        // A stale entry for an op the algo cannot serve resolves Direct.
        record(ConvOp::Conv2dVijp, &d, ConvAlgo::Winograd, 0.1);
        assert_eq!(resolve(ConvOp::Conv2dVijp, &d), ConvAlgo::Direct);
    }
}
