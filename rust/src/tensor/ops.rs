//! Elementwise and linear-algebra primitives over [`Tensor`].
//!
//! `matmul` is the hot primitive (conv lowers to im2col + matmul); it uses a
//! cache-blocked ikj loop with unchecked indexing. The §Perf pass iterates
//! on this file — see EXPERIMENTS.md §Perf.

use crate::tensor::Tensor;

// ----- elementwise -------------------------------------------------------

/// `a + b` (same shape).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip_map(a, b, |x, y| x + y)
}

/// `a - b` (same shape).
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip_map(a, b, |x, y| x - y)
}

/// Hadamard product.
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip_map(a, b, |x, y| x * y)
}

/// `a * s`.
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    let data = a.data().iter().map(|x| x * s).collect();
    Tensor::from_vec(data, a.shape())
}

/// In-place `a += s * b` (axpy); avoids an allocation in hot loops.
pub fn axpy_inplace(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += s * y;
    }
}

fn zip_map(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "elementwise shape mismatch");
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| f(*x, *y))
        .collect();
    Tensor::from_vec(data, a.shape())
}

// ----- reductions ---------------------------------------------------------

/// Sum of all elements.
pub fn sum(a: &Tensor) -> f32 {
    a.data().iter().sum()
}

/// Dot product of flattened tensors (used by ProjForward and grad checks).
pub fn dot(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    a.data().iter().zip(b.data()).map(|(x, y)| x * y).sum()
}

/// L2 norm of the flattened tensor.
pub fn norm(a: &Tensor) -> f32 {
    dot(a, a).sqrt()
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

// ----- matmul --------------------------------------------------------------

/// `C[m,n] = A[m,k] · B[k,n]`, cache-blocked.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim {k} != {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]` without materializing the transpose.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd, cd) = (a.data(), b.data(), c.data_mut());
    // c[i,j] += a[l,i] * b[l,j]: stream over l so both reads are rows.
    for l in 0..k {
        let arow = &ad[l * m..(l + 1) * m];
        let brow = &bd[l * n..(l + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd, cd) = (a.data(), b.data(), c.data_mut());
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += arow[l] * brow[l];
            }
            cd[i * n + j] = acc;
        }
    }
    c
}

/// Raw blocked matmul kernel: `c[m,n] += a[m,k] * b[k,n]` (c pre-zeroed by
/// callers that want assignment). ikj order with row-slice inner loops; the
/// compiler autovectorizes the j loop.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if n <= 128 {
        // Register/L1-blocked micro-kernel for the conv per-tap shapes
        // (n = channels ≤ 128): accumulate the whole c row across a
        // 4-way-unrolled k loop, so c traffic happens once per row and
        // the fma chains interleave (§Perf iteration 3).
        let mut acc = [0f32; 128];
        for i in 0..m {
            let accs = &mut acc[..n];
            accs.copy_from_slice(&c[i * n..(i + 1) * n]);
            let arow = &a[i * k..(i + 1) * k];
            let mut l = 0;
            while l + 4 <= k {
                let (a0, a1, a2, a3) = (arow[l], arow[l + 1], arow[l + 2], arow[l + 3]);
                let b0 = &b[l * n..(l + 1) * n];
                let b1 = &b[(l + 1) * n..(l + 2) * n];
                let b2 = &b[(l + 2) * n..(l + 3) * n];
                let b3 = &b[(l + 3) * n..(l + 4) * n];
                for j in 0..n {
                    accs[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                l += 4;
            }
            while l < k {
                let av = arow[l];
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    accs[j] += av * brow[j];
                }
                l += 1;
            }
            c[i * n..(i + 1) * n].copy_from_slice(accs);
        }
        return;
    }
    const BK: usize = 64; // k-blocking keeps b rows hot in L1/L2
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for l in k0..k1 {
                let av = a[i * k + l];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Raw kernel: `c[m,n] += a[m,k] · b[n,k]ᵀ` over slices (no allocation).
pub fn matmul_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += arow[l] * brow[l];
            }
            crow[j] += acc;
        }
    }
}

/// Raw kernel: `c[m,n] += a[k,m]ᵀ · b[k,n]` over slices (no allocation).
pub fn matmul_tn_into(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // 4-way unroll over the streamed k axis so each c row is touched
    // once per 4 contributions (§Perf iteration 3).
    let mut l = 0;
    while l + 4 <= k {
        let a0 = &a[l * m..(l + 1) * m];
        let a1 = &a[(l + 1) * m..(l + 2) * m];
        let a2 = &a[(l + 2) * m..(l + 3) * m];
        let a3 = &a[(l + 3) * m..(l + 4) * m];
        let b0 = &b[l * n..(l + 1) * n];
        let b1 = &b[(l + 1) * n..(l + 2) * n];
        let b2 = &b[(l + 2) * n..(l + 3) * n];
        let b3 = &b[(l + 3) * n..(l + 4) * n];
        for i in 0..m {
            let (v0, v1, v2, v3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += v0 * b0[j] + v1 * b1[j] + v2 * b2[j] + v3 * b3[j];
            }
        }
        l += 4;
    }
    while l < k {
        let arow = &a[l * m..(l + 1) * m];
        let brow = &b[l * n..(l + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
        l += 1;
    }
}

/// Transpose a 2-d tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            out.data_mut()[j * m + i] = a.data()[i * n + j];
        }
    }
    out
}

// ----- linear solves (dense vijp support) -----------------------------------

/// Solve `X · A = B` for X given square `A[n,n]`, `B[m,n]` → `X[m,n]`,
/// via Gaussian elimination with partial pivoting on `Aᵀ Xᵀ = Bᵀ`.
/// Used by the dense-layer right-inverse when `A = W Wᵀ` (Gram matrix).
pub fn solve_right(a: &Tensor, b: &Tensor) -> anyhow::Result<Tensor> {
    assert_eq!(a.rank(), 2);
    let n = a.shape()[0];
    assert_eq!(a.shape()[1], n, "solve_right needs square A");
    assert_eq!(b.shape()[1], n);
    let m = b.shape()[0];

    // Build augmented system on Aᵀ (X Aᵀᵀ = B ⇒ Aᵀ xᵀ = bᵀ per row of B).
    let mut lu: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let rhs: Vec<f64> = b.data().iter().map(|&x| x as f64).collect();
    // We solve A^T y = b^T for each row b of B; A^T[i][j] = a[j*n+i].
    // Materialize A^T once into `lu` (n x n, row-major).
    let mut at = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            at[i * n + j] = lu[j * n + i];
        }
    }
    lu = at;

    let mut perm: Vec<usize> = (0..n).collect();
    // LU with partial pivoting.
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = lu[perm[col] * n + col].abs();
        for r in col + 1..n {
            let v = lu[perm[r] * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            anyhow::bail!("solve_right: singular matrix (pivot {best:e} at col {col}) — layer is not submersive");
        }
        perm.swap(col, piv);
        let prow = perm[col];
        let pval = lu[prow * n + col];
        for r in col + 1..n {
            let row = perm[r];
            let factor = lu[row * n + col] / pval;
            lu[row * n + col] = factor; // store L
            for c in col + 1..n {
                lu[row * n + c] -= factor * lu[prow * n + c];
            }
        }
    }

    // Solve for each row of B.
    let mut out = Tensor::zeros(&[m, n]);
    let mut y = vec![0f64; n];
    for r in 0..m {
        // forward substitution (apply permutation)
        for i in 0..n {
            let mut acc = rhs[r * n + perm[i]];
            for j in 0..i {
                acc -= lu[perm[i] * n + j] * y[j];
            }
            y[i] = acc;
        }
        // back substitution
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in i + 1..n {
                acc -= lu[perm[i] * n + j] * y[j];
            }
            y[i] = acc / lu[perm[i] * n + i];
        }
        for i in 0..n {
            out.data_mut()[r * n + i] = y[i] as f32;
        }
    }
    // rhs unused further
    let _ = rhs;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn elementwise() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3]);
        let b = Tensor::from_vec(vec![4.0, 5.0, 6.0], &[3]);
        assert_eq!(add(&a, &b).data(), &[5.0, 7.0, 9.0]);
        assert_eq!(sub(&b, &a).data(), &[3.0, 3.0, 3.0]);
        assert_eq!(mul(&a, &b).data(), &[4.0, 10.0, 18.0]);
        assert_eq!(scale(&a, 2.0).data(), &[2.0, 4.0, 6.0]);
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(sum(&a), 6.0);
    }

    #[test]
    fn axpy() {
        let mut a = Tensor::from_vec(vec![1.0, 1.0], &[2]);
        let b = Tensor::from_vec(vec![2.0, 3.0], &[2]);
        axpy_inplace(&mut a, 0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.5]);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![5.0, 6.0, 7.0, 8.0], &[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let c = matmul(&a, &b);
        let c_tn = matmul_tn(&transpose(&a), &b);
        let c_nt = matmul_nt(&a, &transpose(&b));
        assert_close(&c_tn, &c, 1e-5, "matmul_tn");
        assert_close(&c_nt, &c, 1e-5, "matmul_nt");
    }

    #[test]
    fn matmul_blocked_large_k() {
        // exercise the BK blocking boundary
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[3, 130], 1.0, &mut rng);
        let b = Tensor::randn(&[130, 4], 1.0, &mut rng);
        let c = matmul(&a, &b);
        // naive reference
        let mut expect = Tensor::zeros(&[3, 4]);
        for i in 0..3 {
            for j in 0..4 {
                let mut acc = 0.0;
                for l in 0..130 {
                    acc += a.at2(i, l) * b.at2(l, j);
                }
                expect.data_mut()[i * 4 + j] = acc;
            }
        }
        assert_close(&c, &expect, 1e-5, "blocked matmul");
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let t = transpose(&transpose(&a));
        assert_eq!(a, t);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn solve_right_recovers() {
        // X A = B with known X
        let mut rng = Rng::new(3);
        let n = 6;
        let m = 4;
        // Build a well-conditioned A = M Mᵀ + I
        let mmat = Tensor::randn(&[n, n], 0.5, &mut rng);
        let mut a = matmul_nt(&mmat, &mmat);
        for i in 0..n {
            let idx = i * n + i;
            a.data_mut()[idx] += 1.0;
        }
        let x_true = Tensor::randn(&[m, n], 1.0, &mut rng);
        let b = matmul(&x_true, &a);
        let x = solve_right(&a, &b).unwrap();
        assert_close(&x, &x_true, 1e-3, "solve_right");
    }

    #[test]
    fn solve_right_singular_errors() {
        let a = Tensor::zeros(&[3, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(solve_right(&a, &b).is_err());
    }
}
