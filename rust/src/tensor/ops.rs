//! Elementwise and linear-algebra primitives over [`Tensor`].
//!
//! `matmul` is the hot primitive (conv lowers to im2col + matmul). Three
//! algorithms are available behind the [`GemmAlgo`] selector (the cuDNN
//! fwd-algo-enum idiom): a `Scalar` reference triple loop, the
//! cache-`Blocked` ikj kernel, and a row-`Parallel` variant that fans the
//! output rows across the persistent worker pool (`runtime::pool`) — rows
//! are disjoint, so the parallel result is bit-identical to the blocked
//! one.
//! Shape heuristics pick the algorithm; `MOONWALK_GEMM` /
//! [`set_gemm_override`] force one. The §Perf pass iterates on this file —
//! see EXPERIMENTS.md §Perf.

use crate::runtime::pool;
use crate::tensor::Tensor;

// ----- elementwise -------------------------------------------------------

/// `a + b` (same shape).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip_map(a, b, |x, y| x + y)
}

/// `a - b` (same shape).
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip_map(a, b, |x, y| x - y)
}

/// Hadamard product.
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip_map(a, b, |x, y| x * y)
}

/// `a * s`.
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    let data = a.data().iter().map(|x| x * s).collect();
    Tensor::from_vec(data, a.shape())
}

/// In-place `a += s * b` (axpy); avoids an allocation in hot loops.
pub fn axpy_inplace(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += s * y;
    }
}

fn zip_map(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "elementwise shape mismatch");
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| f(*x, *y))
        .collect();
    Tensor::from_vec(data, a.shape())
}

// ----- reductions ---------------------------------------------------------

/// Sum of all elements.
pub fn sum(a: &Tensor) -> f32 {
    a.data().iter().sum()
}

/// Dot product of flattened tensors (used by ProjForward and grad checks).
pub fn dot(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    a.data().iter().zip(b.data()).map(|(x, y)| x * y).sum()
}

/// L2 norm of the flattened tensor.
pub fn norm(a: &Tensor) -> f32 {
    dot(a, a).sqrt()
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

// ----- matmul --------------------------------------------------------------

/// GEMM algorithm selector (the cuDNN `cudnnConvolutionFwdAlgo_t` idiom:
/// explicit algorithm choice instead of one hardwired loop nest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmAlgo {
    /// Naive triple loop — the correctness reference.
    Scalar,
    /// Cache-blocked single-thread kernel (the seed's hot loop).
    Blocked,
    /// Row-blocked fan-out over the scoped worker pool. Output rows are
    /// disjoint, so results are bit-identical to `Blocked`.
    Parallel { threads: usize },
}

/// A worker needs at least this many output rows to amortize its share
/// of region dispatch. Retuned down from 16 when the scoped pool became
/// a persistent team (§Perf iteration 6): dispatch is a channel send +
/// park/wake round-trip per worker (~single-digit µs), not a thread
/// spawn, so much smaller row bands pay off.
const PAR_MIN_ROWS: usize = 8;
/// Below this FLOP count (2·m·k·n) the kernel stays single-threaded.
/// Also retuned (1e6 → 2.5e5) for the persistent team's cheaper regions.
const PAR_MIN_FLOPS: f64 = 2.5e5;

// Cached MOONWALK_GEMM override: 0 unresolved, 1 auto, 2/3/4 forced.
static GEMM_OVERRIDE: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

fn gemm_override() -> u8 {
    use std::sync::atomic::Ordering;
    let v = GEMM_OVERRIDE.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let v = match std::env::var("MOONWALK_GEMM") {
        Err(_) => 1,
        Ok(raw) => match raw.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => 1,
            "scalar" => 2,
            "blocked" => 3,
            "parallel" => 4,
            other => {
                // Warn exactly once (the result is cached): a perf knob
                // that is silently ignored produces wrong measurements.
                eprintln!(
                    "warning: MOONWALK_GEMM=`{other}` not recognized \
                     (auto|scalar|blocked|parallel); using auto"
                );
                1
            }
        },
    };
    GEMM_OVERRIDE.store(v, Ordering::Relaxed);
    v
}

/// Force a GEMM algorithm globally: `"auto"`, `"scalar"`, `"blocked"` or
/// `"parallel"` (the CLI's `--gemm`; `MOONWALK_GEMM` is the env spelling).
pub fn set_gemm_override(name: &str) -> anyhow::Result<()> {
    use std::sync::atomic::Ordering;
    let v = match name {
        "auto" => 1,
        "scalar" => 2,
        "blocked" => 3,
        "parallel" => 4,
        other => anyhow::bail!("unknown GEMM algorithm `{other}` (auto|scalar|blocked|parallel)"),
    };
    GEMM_OVERRIDE.store(v, Ordering::Relaxed);
    Ok(())
}

/// Pick a GEMM algorithm for a `[m,k]·[k,n]` product: forced override if
/// set, otherwise `Parallel` when the pool has idle workers AND the shape
/// is big enough to amortize them, else `Blocked`.
pub fn select_gemm_algo(m: usize, k: usize, n: usize) -> GemmAlgo {
    match gemm_override() {
        2 => return GemmAlgo::Scalar,
        3 => return GemmAlgo::Blocked,
        _ => {}
    }
    let t_raw = pool::effective_threads(m);
    let t = t_raw.min((m / PAR_MIN_ROWS).max(1));
    if gemm_override() == 4 {
        // Forced parallel still clamps the fan-out to the row count:
        // with fewer rows than PAR_MIN_ROWS·threads the extra shares
        // would be empty or degenerate (more partitions than rows), so
        // the override forces *the parallel kernel*, not a specific
        // share count. It skips only the FLOP threshold below.
        return if t > 1 {
            GemmAlgo::Parallel { threads: t }
        } else {
            GemmAlgo::Blocked
        };
    }
    if t <= 1 {
        return GemmAlgo::Blocked;
    }
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    if flops >= PAR_MIN_FLOPS {
        GemmAlgo::Parallel { threads: t }
    } else {
        GemmAlgo::Blocked
    }
}

/// `C[m,n] = A[m,k] · B[k,n]`, algorithm-selected.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim {k} != {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    matmul_into_auto(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]` without materializing the transpose.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_tn_into_auto(a.data(), b.data(), c.data_mut(), k, m, n);
    c
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` without materializing the transpose.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_nt_into_auto(a.data(), b.data(), c.data_mut(), m, k, n);
    c
}

// ----- algorithm-dispatched raw kernels -------------------------------------

/// Dispatched `c += a·b` over raw slices (`c` pre-zeroed for assignment).
pub fn matmul_into_auto(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    match select_gemm_algo(m, k, n) {
        GemmAlgo::Scalar => matmul_scalar_into(a, b, c, m, k, n),
        GemmAlgo::Blocked => matmul_into(a, b, c, m, k, n),
        GemmAlgo::Parallel { threads } => matmul_into_parallel(a, b, c, m, k, n, threads),
    }
}

/// Dispatched `c += a · bᵀ` over raw slices.
pub fn matmul_nt_into_auto(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    match select_gemm_algo(m, k, n) {
        GemmAlgo::Scalar => matmul_nt_scalar_into(a, b, c, m, k, n),
        GemmAlgo::Blocked => matmul_nt_into(a, b, c, m, k, n),
        GemmAlgo::Parallel { threads } => matmul_nt_into_parallel(a, b, c, m, k, n, threads),
    }
}

/// Dispatched `c += aᵀ · b` over raw slices (`a` is `[k,m]`).
pub fn matmul_tn_into_auto(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    match select_gemm_algo(m, k, n) {
        GemmAlgo::Scalar => matmul_tn_scalar_into(a, b, c, k, m, n),
        GemmAlgo::Blocked => matmul_tn_into(a, b, c, k, m, n),
        GemmAlgo::Parallel { threads } => matmul_tn_into_parallel(a, b, c, k, m, n, threads),
    }
}

/// Row-parallel `c += a·b`: fan disjoint output-row blocks across
/// `workers` persistent pool threads. Bit-identical to [`matmul_into`]
/// (each row is computed by the same kernel in the same order).
pub fn matmul_into_parallel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    pool::run_records(c, n, workers, |rows, chunk| {
        matmul_into(&a[rows.start * k..rows.end * k], b, chunk, rows.len(), k, n);
    });
}

/// Row-parallel `c += a · bᵀ`; bit-identical to [`matmul_nt_into`].
pub fn matmul_nt_into_parallel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    pool::run_records(c, n, workers, |rows, chunk| {
        matmul_nt_into(&a[rows.start * k..rows.end * k], b, chunk, rows.len(), k, n);
    });
}

/// Row-parallel `c += aᵀ · b` (`a` is `[k,m]`): each worker streams the
/// full `k` axis but only its own output-row band, so no reduction is
/// needed and results are bit-identical to [`matmul_tn_into`].
pub fn matmul_tn_into_parallel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    workers: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(c.len(), m * n);
    pool::run_records(c, n, workers, |rows, chunk| {
        matmul_tn_into_rows(a, b, chunk, k, m, n, rows.start, rows.end);
    });
}

/// Reference kernel: naive i-j-l triple loop, `c += a·b`.
pub fn matmul_scalar_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = c[i * n + j];
            for l in 0..k {
                acc += a[i * k + l] * b[l * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// Reference kernel: naive `c += a · bᵀ` (the seed's unblocked matmul_nt).
pub fn matmul_nt_scalar_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += arow[l] * brow[l];
            }
            c[i * n + j] += acc;
        }
    }
}

/// Reference kernel: naive `c += aᵀ · b` (`a` is `[k,m]`).
pub fn matmul_tn_scalar_into(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = c[i * n + j];
            for l in 0..k {
                acc += a[l * m + i] * b[l * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// Raw blocked matmul kernel: `c[m,n] += a[m,k] * b[k,n]` (c pre-zeroed by
/// callers that want assignment). ikj order with row-slice inner loops; the
/// compiler autovectorizes the j loop.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if n <= 128 {
        // Register/L1-blocked micro-kernel for the conv per-tap shapes
        // (n = channels ≤ 128): accumulate the whole c row across a
        // 4-way-unrolled k loop, so c traffic happens once per row and
        // the fma chains interleave (§Perf iteration 3).
        let mut acc = [0f32; 128];
        for i in 0..m {
            let accs = &mut acc[..n];
            accs.copy_from_slice(&c[i * n..(i + 1) * n]);
            let arow = &a[i * k..(i + 1) * k];
            let mut l = 0;
            while l + 4 <= k {
                let (a0, a1, a2, a3) = (arow[l], arow[l + 1], arow[l + 2], arow[l + 3]);
                let b0 = &b[l * n..(l + 1) * n];
                let b1 = &b[(l + 1) * n..(l + 2) * n];
                let b2 = &b[(l + 2) * n..(l + 3) * n];
                let b3 = &b[(l + 3) * n..(l + 4) * n];
                for j in 0..n {
                    accs[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                l += 4;
            }
            while l < k {
                let av = arow[l];
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    accs[j] += av * brow[j];
                }
                l += 1;
            }
            c[i * n..(i + 1) * n].copy_from_slice(accs);
        }
        return;
    }
    const BK: usize = 64; // k-blocking keeps b rows hot in L1/L2
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for l in k0..k1 {
                let av = a[i * k + l];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Raw kernel: `c[m,n] += a[m,k] · b[n,k]ᵀ` over slices (no allocation).
///
/// Cache-blocked like its siblings (the seed shipped this one as a naive
/// i-j-l loop of strided dots): the `k` axis is processed in `BK`-sized
/// blocks so the active `b` rows stay hot, and `j` is 4-way unrolled so
/// each `a` element loaded feeds four independent dot-product chains.
pub fn matmul_nt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    const BK: usize = 256;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k1];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 4 <= n {
                let b0 = &b[j * k + k0..j * k + k1];
                let b1 = &b[(j + 1) * k + k0..(j + 1) * k + k1];
                let b2 = &b[(j + 2) * k + k0..(j + 2) * k + k1];
                let b3 = &b[(j + 3) * k + k0..(j + 3) * k + k1];
                let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
                for (l, &av) in arow.iter().enumerate() {
                    s0 += av * b0[l];
                    s1 += av * b1[l];
                    s2 += av * b2[l];
                    s3 += av * b3[l];
                }
                crow[j] += s0;
                crow[j + 1] += s1;
                crow[j + 2] += s2;
                crow[j + 3] += s3;
                j += 4;
            }
            while j < n {
                let brow = &b[j * k + k0..j * k + k1];
                let mut acc = 0.0f32;
                for (l, &av) in arow.iter().enumerate() {
                    acc += av * brow[l];
                }
                crow[j] += acc;
                j += 1;
            }
        }
    }
}

/// Raw kernel: `c[m,n] += a[k,m]ᵀ · b[k,n]` over slices (no allocation).
pub fn matmul_tn_into(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    matmul_tn_into_rows(a, b, c, k, m, n, 0, m);
}

/// [`matmul_tn_into`] restricted to output rows `i0..i1` (`c` holds only
/// that band) — the unit of work of the row-parallel dispatcher. 4-way
/// unroll over the streamed k axis so each c row is touched once per 4
/// contributions (§Perf iteration 3).
fn matmul_tn_into_rows(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    i0: usize,
    i1: usize,
) {
    let rows = i1 - i0;
    debug_assert_eq!(c.len(), rows * n);
    let mut l = 0;
    while l + 4 <= k {
        let a0 = &a[l * m + i0..l * m + i1];
        let a1 = &a[(l + 1) * m + i0..(l + 1) * m + i1];
        let a2 = &a[(l + 2) * m + i0..(l + 2) * m + i1];
        let a3 = &a[(l + 3) * m + i0..(l + 3) * m + i1];
        let b0 = &b[l * n..(l + 1) * n];
        let b1 = &b[(l + 1) * n..(l + 2) * n];
        let b2 = &b[(l + 2) * n..(l + 3) * n];
        let b3 = &b[(l + 3) * n..(l + 4) * n];
        for i in 0..rows {
            let (v0, v1, v2, v3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += v0 * b0[j] + v1 * b1[j] + v2 * b2[j] + v3 * b3[j];
            }
        }
        l += 4;
    }
    while l < k {
        let arow = &a[l * m + i0..l * m + i1];
        let brow = &b[l * n..(l + 1) * n];
        for i in 0..rows {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
        l += 1;
    }
}

/// Transpose a 2-d tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            out.data_mut()[j * m + i] = a.data()[i * n + j];
        }
    }
    out
}

// ----- linear solves (dense vijp support) -----------------------------------

/// Solve `X · A = B` for X given square `A[n,n]`, `B[m,n]` → `X[m,n]`,
/// via Gaussian elimination with partial pivoting on `Aᵀ Xᵀ = Bᵀ`.
/// Used by the dense-layer right-inverse when `A = W Wᵀ` (Gram matrix).
pub fn solve_right(a: &Tensor, b: &Tensor) -> anyhow::Result<Tensor> {
    assert_eq!(a.rank(), 2);
    let n = a.shape()[0];
    assert_eq!(a.shape()[1], n, "solve_right needs square A");
    assert_eq!(b.shape()[1], n);
    let m = b.shape()[0];

    // Build augmented system on Aᵀ (X Aᵀᵀ = B ⇒ Aᵀ xᵀ = bᵀ per row of B).
    let mut lu: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let rhs: Vec<f64> = b.data().iter().map(|&x| x as f64).collect();
    // We solve A^T y = b^T for each row b of B; A^T[i][j] = a[j*n+i].
    // Materialize A^T once into `lu` (n x n, row-major).
    let mut at = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            at[i * n + j] = lu[j * n + i];
        }
    }
    lu = at;

    let mut perm: Vec<usize> = (0..n).collect();
    // LU with partial pivoting.
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = lu[perm[col] * n + col].abs();
        for r in col + 1..n {
            let v = lu[perm[r] * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            anyhow::bail!("solve_right: singular matrix (pivot {best:e} at col {col}) — layer is not submersive");
        }
        perm.swap(col, piv);
        let prow = perm[col];
        let pval = lu[prow * n + col];
        for r in col + 1..n {
            let row = perm[r];
            let factor = lu[row * n + col] / pval;
            lu[row * n + col] = factor; // store L
            for c in col + 1..n {
                lu[row * n + c] -= factor * lu[prow * n + c];
            }
        }
    }

    // Solve for each row of B.
    let mut out = Tensor::zeros(&[m, n]);
    let mut y = vec![0f64; n];
    for r in 0..m {
        // forward substitution (apply permutation)
        for i in 0..n {
            let mut acc = rhs[r * n + perm[i]];
            for j in 0..i {
                acc -= lu[perm[i] * n + j] * y[j];
            }
            y[i] = acc;
        }
        // back substitution
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in i + 1..n {
                acc -= lu[perm[i] * n + j] * y[j];
            }
            y[i] = acc / lu[perm[i] * n + i];
        }
        for i in 0..n {
            out.data_mut()[r * n + i] = y[i] as f32;
        }
    }
    // rhs unused further
    let _ = rhs;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn elementwise() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3]);
        let b = Tensor::from_vec(vec![4.0, 5.0, 6.0], &[3]);
        assert_eq!(add(&a, &b).data(), &[5.0, 7.0, 9.0]);
        assert_eq!(sub(&b, &a).data(), &[3.0, 3.0, 3.0]);
        assert_eq!(mul(&a, &b).data(), &[4.0, 10.0, 18.0]);
        assert_eq!(scale(&a, 2.0).data(), &[2.0, 4.0, 6.0]);
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(sum(&a), 6.0);
    }

    #[test]
    fn axpy() {
        let mut a = Tensor::from_vec(vec![1.0, 1.0], &[2]);
        let b = Tensor::from_vec(vec![2.0, 3.0], &[2]);
        axpy_inplace(&mut a, 0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.5]);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![5.0, 6.0, 7.0, 8.0], &[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 9], 1.0, &mut rng);
        let c = matmul(&a, &b);
        let c_tn = matmul_tn(&transpose(&a), &b);
        let c_nt = matmul_nt(&a, &transpose(&b));
        assert_close(&c_tn, &c, 1e-5, "matmul_tn");
        assert_close(&c_nt, &c, 1e-5, "matmul_nt");
    }

    #[test]
    fn matmul_blocked_large_k() {
        // exercise the BK blocking boundary
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[3, 130], 1.0, &mut rng);
        let b = Tensor::randn(&[130, 4], 1.0, &mut rng);
        let c = matmul(&a, &b);
        // naive reference
        let mut expect = Tensor::zeros(&[3, 4]);
        for i in 0..3 {
            for j in 0..4 {
                let mut acc = 0.0;
                for l in 0..130 {
                    acc += a.at2(i, l) * b.at2(l, j);
                }
                expect.data_mut()[i * 4 + j] = acc;
            }
        }
        assert_close(&c, &expect, 1e-5, "blocked matmul");
    }

    /// Satellite regression: the blocked `matmul_nt` must agree with the
    /// naive scalar reference across shapes that exercise the k-blocking
    /// boundary (k > 256) and the 4-way j-unroll remainders.
    #[test]
    fn matmul_nt_blocked_matches_scalar() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (8, 256, 8),
            (5, 300, 6),
            (7, 65, 9),
            (4, 513, 5),
            (2, 32, 4),
        ] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let mut c_ref = vec![0f32; m * n];
            matmul_nt_scalar_into(a.data(), b.data(), &mut c_ref, m, k, n);
            let mut c = vec![0f32; m * n];
            matmul_nt_into(a.data(), b.data(), &mut c, m, k, n);
            for (x, y) in c.iter().zip(&c_ref) {
                assert!(
                    (x - y).abs() <= 1e-4 * y.abs().max(1.0),
                    "nt blocked vs scalar mismatch at {m}x{k}x{n}: {x} vs {y}"
                );
            }
        }
    }

    /// Parallel row-blocked kernels must be bit-identical to the blocked
    /// serial ones (disjoint rows, same per-row op order).
    #[test]
    fn parallel_kernels_bit_identical() {
        let mut rng = Rng::new(8);
        for &(m, k, n) in &[(1usize, 4usize, 4usize), (7, 5, 9), (64, 33, 17), (130, 64, 130)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let bt = transpose(&b);
            let at = transpose(&a);
            for workers in [2usize, 4] {
                let mut c_s = vec![0f32; m * n];
                matmul_into(a.data(), b.data(), &mut c_s, m, k, n);
                let mut c_p = vec![0f32; m * n];
                matmul_into_parallel(a.data(), b.data(), &mut c_p, m, k, n, workers);
                assert_eq!(c_s, c_p, "nn {m}x{k}x{n} w={workers}");

                let mut c_s = vec![0f32; m * n];
                matmul_nt_into(a.data(), bt.data(), &mut c_s, m, k, n);
                let mut c_p = vec![0f32; m * n];
                matmul_nt_into_parallel(a.data(), bt.data(), &mut c_p, m, k, n, workers);
                assert_eq!(c_s, c_p, "nt {m}x{k}x{n} w={workers}");

                let mut c_s = vec![0f32; m * n];
                matmul_tn_into(at.data(), b.data(), &mut c_s, k, m, n);
                let mut c_p = vec![0f32; m * n];
                matmul_tn_into_parallel(at.data(), b.data(), &mut c_p, k, m, n, workers);
                assert_eq!(c_s, c_p, "tn {m}x{k}x{n} w={workers}");
            }
        }
    }

    #[test]
    fn scalar_references_agree_with_blocked() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (6usize, 70usize, 10usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let at = transpose(&a);
        let mut c_blocked = vec![0f32; m * n];
        matmul_into(a.data(), b.data(), &mut c_blocked, m, k, n);
        let mut c_scalar = vec![0f32; m * n];
        matmul_scalar_into(a.data(), b.data(), &mut c_scalar, m, k, n);
        let mut c_tn = vec![0f32; m * n];
        matmul_tn_scalar_into(at.data(), b.data(), &mut c_tn, k, m, n);
        for ((x, y), z) in c_blocked.iter().zip(&c_scalar).zip(&c_tn) {
            assert!((x - y).abs() <= 1e-4 * y.abs().max(1.0));
            assert!((z - y).abs() <= 1e-4 * y.abs().max(1.0));
        }
    }

    #[test]
    fn gemm_selection_respects_pool_nesting() {
        // Inside a pool worker the selector must never pick Parallel.
        let mut out = vec![0f32; 2];
        crate::runtime::pool::run_records(&mut out, 1, 2, |_, chunk| {
            match select_gemm_algo(4096, 64, 64) {
                GemmAlgo::Parallel { .. } => chunk[0] = f32::NAN,
                _ => chunk[0] = 1.0,
            }
        });
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn gemm_selection_small_shapes_stay_serial() {
        // Tiny products must not pay the fan-out cost regardless of the
        // pool size (8x8x8 = 1k flops << threshold).
        assert_eq!(select_gemm_algo(8, 8, 8), GemmAlgo::Blocked);
    }

    #[test]
    fn gemm_forced_parallel_clamps_to_row_count() {
        // Regression (ISSUE 7): `--gemm parallel` on a short-m GEMM used
        // to return `Parallel { threads: t_raw }` without the
        // m / PAR_MIN_ROWS clamp the auto path applies, yielding more
        // shares than rows. The override must force the parallel
        // *kernel*, never a degenerate partition count.
        set_gemm_override("parallel").unwrap();
        // m smaller than any plausible pool size: the clamp caps the
        // fan-out at (m / PAR_MIN_ROWS).max(1) = 1 ⇒ Blocked.
        let small = select_gemm_algo(PAR_MIN_ROWS - 1, 64, 64);
        // m big enough for exactly two shares: threads ≤ m / PAR_MIN_ROWS.
        let two = select_gemm_algo(2 * PAR_MIN_ROWS, 64, 64);
        set_gemm_override("auto").unwrap();
        assert_eq!(small, GemmAlgo::Blocked, "m < PAR_MIN_ROWS must stay serial");
        if let GemmAlgo::Parallel { threads } = two {
            assert!(
                threads <= 2,
                "forced parallel at m = 2·PAR_MIN_ROWS must clamp shares to 2, got {threads}"
            );
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let t = transpose(&transpose(&a));
        assert_eq!(a, t);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn solve_right_recovers() {
        // X A = B with known X
        let mut rng = Rng::new(3);
        let n = 6;
        let m = 4;
        // Build a well-conditioned A = M Mᵀ + I
        let mmat = Tensor::randn(&[n, n], 0.5, &mut rng);
        let mut a = matmul_nt(&mmat, &mmat);
        for i in 0..n {
            let idx = i * n + i;
            a.data_mut()[idx] += 1.0;
        }
        let x_true = Tensor::randn(&[m, n], 1.0, &mut rng);
        let b = matmul(&x_true, &a);
        let x = solve_right(&a, &b).unwrap();
        assert_close(&x, &x_true, 1e-3, "solve_right");
    }

    #[test]
    fn solve_right_singular_errors() {
        let a = Tensor::zeros(&[3, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(solve_right(&a, &b).is_err());
    }
}
