//! Dense f32 tensor library with allocation tracking.
//!
//! Row-major layout; shapes up to rank 4 are what the layer library uses
//! (`[batch, h, w, c]` channel-last, as in the paper's notation §3.1).
//! All payload allocations register with [`tracker`] so gradient engines
//! can report peak live bytes — the reproduction's substitute for the
//! paper's GPU memory measurements.

pub mod arena;
pub mod bitset;
pub mod conv_algo;
pub mod ops;
pub mod tracker;

pub use bitset::BitTensor;

/// A dense, row-major f32 tensor whose payload is allocation-tracked.
#[derive(Debug)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    // ----- construction -------------------------------------------------

    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        tracker::alloc(n * 4);
        Tensor {
            data: vec![0.0; n],
            shape: shape.to_vec(),
        }
    }

    /// Tensor from existing data (takes ownership; length must match).
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "data length {} != shape {:?}", data.len(), shape);
        tracker::alloc(n * 4);
        Tensor {
            data,
            shape: shape.to_vec(),
        }
    }

    /// Scalar tensor.
    pub fn scalar(x: f32) -> Tensor {
        Tensor::from_vec(vec![x], &[])
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], x: f32) -> Tensor {
        let n: usize = shape.iter().product();
        tracker::alloc(n * 4);
        Tensor {
            data: vec![x; n],
            shape: shape.to_vec(),
        }
    }

    /// I.i.d. normal entries with std `std`.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(rng.normal_vec(n, std), shape)
    }

    // ----- accessors ----------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Payload size in bytes (what the tracker accounts).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume, returning the raw payload (tracker releases the bytes).
    pub fn into_vec(self) -> Vec<f32> {
        // Drop impl frees the tracked bytes; move data out first.
        let mut this = self;
        std::mem::take(&mut this.data)
        // `this` drops here with shape intact; Drop frees based on data.len()
        // which is now 0 — so free the bytes explicitly:
        // handled in Drop via `freed` length check below.
    }

    /// Scalar value of a 0-d / 1-element tensor.
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar");
        self.data[0]
    }

    // ----- shape manipulation -------------------------------------------

    /// Reshape (same element count).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor::from_vec(self.data.clone(), shape)
    }

    /// In-place reshape (no copy, no extra tracked bytes).
    pub fn reshaped_inplace(mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // ----- indexing helpers ----------------------------------------------

    /// Flat offset of a 4-d index.
    #[inline(always)]
    pub fn idx4(&self, a: usize, b: usize, c: usize, d: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((a * self.shape[1] + b) * self.shape[2] + c) * self.shape[3] + d
    }

    /// Flat offset of a 3-d index.
    #[inline(always)]
    pub fn idx3(&self, a: usize, b: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 3);
        (a * self.shape[1] + b) * self.shape[2] + c
    }

    /// Flat offset of a 2-d index.
    #[inline(always)]
    pub fn idx2(&self, a: usize, b: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 2);
        a * self.shape[1] + b
    }

    #[inline(always)]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        self.data[self.idx4(a, b, c, d)]
    }

    #[inline(always)]
    pub fn at3(&self, a: usize, b: usize, c: usize) -> f32 {
        self.data[self.idx3(a, b, c)]
    }

    #[inline(always)]
    pub fn at2(&self, a: usize, b: usize) -> f32 {
        self.data[self.idx2(a, b)]
    }
}

impl Clone for Tensor {
    fn clone(&self) -> Tensor {
        Tensor::from_vec(self.data.clone(), &self.shape)
    }
}

impl Drop for Tensor {
    fn drop(&mut self) {
        // `into_vec` may have moved the payload out; only free what's held.
        if !self.data.is_empty() || self.shape.iter().product::<usize>() == 0 {
            tracker::free(self.data.len() * 4);
        } else {
            // Payload was moved out by into_vec: the original allocation is
            // released here (capacity was taken with it).
            let n: usize = self.shape.iter().product();
            tracker::free(n * 4);
        }
    }
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape && self.data == other.data
    }
}

/// Max |a-b| over two tensors (shape-checked).
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape(), b.shape(), "shape mismatch");
    a.data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Relative error ||a-b||_inf / (||b||_inf + eps).
pub fn rel_err(a: &Tensor, b: &Tensor) -> f32 {
    let scale = b.data().iter().map(|x| x.abs()).fold(0.0, f32::max) + 1e-8;
    max_abs_diff(a, b) / scale
}

/// Assert two tensors are close (used pervasively in tests).
pub fn assert_close(a: &Tensor, b: &Tensor, tol: f32, what: &str) {
    let err = rel_err(a, b);
    assert!(
        err <= tol,
        "{what}: relative error {err} > tol {tol} (shapes {:?})",
        a.shape()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_item() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.data().iter().sum::<f32>(), 0.0);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[test]
    fn tracker_balance_on_drop() {
        let (_, p) = tracker::measure(|| {
            let t = Tensor::zeros(&[256]);
            let u = t.clone();
            drop(t);
            drop(u);
        });
        assert!(p.peak_extra_bytes >= 2048);
        // measure() asserts balance implicitly via current(); double-check:
        let live0 = tracker::current();
        {
            let _t = Tensor::zeros(&[100]);
            assert_eq!(tracker::current(), live0 + 400);
        }
        assert_eq!(tracker::current(), live0);
    }

    #[test]
    fn into_vec_releases_bytes() {
        let live0 = tracker::current();
        let v = Tensor::zeros(&[64]).into_vec();
        assert_eq!(v.len(), 64);
        assert_eq!(tracker::current(), live0);
    }

    #[test]
    fn idx_helpers() {
        let t = Tensor::from_vec((0..24).map(|x| x as f32).collect(), &[2, 3, 4]);
        assert_eq!(t.at3(1, 2, 3), 23.0);
        assert_eq!(t.at3(0, 1, 0), 4.0);
        let m = t.reshape(&[6, 4]);
        assert_eq!(m.at2(5, 3), 23.0);
    }

    #[test]
    fn rel_err_and_assert_close() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![1.0, 2.0001], &[2]);
        assert!(rel_err(&a, &b) < 1e-3);
        assert_close(&a, &b, 1e-3, "close");
    }

    #[test]
    #[should_panic]
    fn reshape_mismatch_panics() {
        Tensor::zeros(&[4]).reshape(&[5]);
    }
}
