//! The budgeted per-layer planner: a Pareto dynamic program over the
//! layer chain that assigns every layer a cotangent [`Strategy`],
//! minimizing predicted step time subject to a peak-bytes budget.
//!
//! The strategy lattice per layer (cheapest-memory first):
//!
//! * [`Strategy::Vijp`] — submersive layer on an intact cotangent chain:
//!   store **nothing**, Phase III recovers the output cotangent with the
//!   paper's vijp (Eq. 9). Costs one extra vijp sweep in time (double
//!   for wavefront layers, where `s + p < k` serializes the
//!   elimination).
//! * [`Strategy::Fragment`] — non-submersive layer that supports §5.1:
//!   store the first `k−1` slices of each block of the output cotangent
//!   (bytes measured by the calibration probe per candidate block — the
//!   planner searches the block size), reconstruct in Phase III.
//! * [`Strategy::Residual`]`(Full)` — keep the **full output cotangent**
//!   as a Phase-II checkpoint (§4.1's fallback, also how submersive
//!   layers buy time under a loose budget: the checkpoint replaces the
//!   vijp sweep entirely).
//! * [`Strategy::Residual`]`(Minimal)` — keep nothing beyond the Phase-I
//!   minimal residual and let the cotangent chain break; legal only for
//!   parameter-free layers (nothing downstream of the break is owed a
//!   cotangent until the next `Residual(Full)` re-anchor). This is how
//!   the paper's h₁-seed anchor placement (§4.3) falls out of the DP:
//!   a break at a parameter-free expander is re-anchored at the first
//!   parameterized layer after it, where the activation is smallest.
//!
//! The DP walks the chain front-to-back with two states — cotangent
//! chain *intact* or *broken* — keeping, per state, the Pareto frontier
//! of `(aid bytes, extra time)` outcomes (dominated entries pruned).
//! The frontier is **budget-independent**: [`build_frontier`] runs once
//! and [`PlanFrontier::select`] answers any budget, which also makes
//! budget monotonicity exact — a tighter budget can never select a plan
//! with more predicted bytes (`tests/planner.rs` proves it on random
//! nets).
//!
//! ## Skip connections: the chain→DAG boundary
//!
//! The DP state walks a *chain* of layers, but the reversible blocks of
//! `nn::reversible` (residual, RevNet coupling, momentum) introduce the
//! repo's first skip connections. The chain DP stays sound because each
//! block *folds its skip edges inside a single chain node*: a
//! `CouplingBlock` is one `Layer` whose internal dataflow is a DAG, yet
//! whose external interface is exactly one input edge and one output
//! edge, with a composite Jacobian that is invertible as a whole. The
//! probe sees one node (submersive, zero Minimal residual, `fast_vijp`),
//! and the DP discovers the free-vijp assignment with no special casing
//! — at a tight budget every reversible layer lands on
//! [`Strategy::Vijp`] (`tests/reversible.rs::planner_assigns_vijp_…`).
//! Topologies whose skip edges cross *block boundaries* (a transformer's
//! residual stream spliced by attention, multi-branch merges) cannot be
//! folded this way; they need the DP state generalized from a chain
//! index to a DAG cut — the planned follow-up that this node-folding
//! contract is the first step toward (ROADMAP "reversible layer
//! family"). Until then, [`validate`] rejecting plans that assume an
//! intact chain across a break is what keeps the chain assumption
//! explicit rather than silent.

use crate::memsim;
use crate::plan::probe::LayerProbe;

/// How much of a layer's output cotangent Phase II preserves under the
/// `Residual` strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualTier {
    /// The full output cotangent (a §4.1 checkpoint) — re-anchors the
    /// chain; legal for every layer.
    Full,
    /// Nothing beyond the Phase-I minimal residual — the chain breaks;
    /// legal only for parameter-free layers.
    Minimal,
}

/// One layer's planned cotangent treatment (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Recover the output cotangent with vijp; store nothing.
    Vijp,
    /// Fragmental capture (§5.1) at the given block size.
    Fragment {
        /// Block size `B` handed to `fragment_capture`.
        block: usize,
    },
    /// Keep a residual tier of the output cotangent.
    Residual(ResidualTier),
}

impl Strategy {
    /// Short label for plan tables and bench JSON.
    pub fn label(&self) -> String {
        match self {
            Strategy::Vijp => "vijp".into(),
            Strategy::Fragment { block } => format!("frag(B={block})"),
            Strategy::Residual(ResidualTier::Full) => "ckpt".into(),
            Strategy::Residual(ResidualTier::Minimal) => "skip".into(),
        }
    }
}

/// One layer's compiled decision with its predicted costs.
#[derive(Clone, Debug)]
pub struct LayerDecision {
    /// The chosen strategy.
    pub strategy: Strategy,
    /// Bytes Phase II parks for Phase III under this strategy
    /// (checkpoint/fragment payload; zero for `Vijp`/`Minimal`).
    pub aid_bytes: usize,
    /// Extra Phase-III time the strategy costs, in forward-FLOP units
    /// (vijp/reconstruction sweeps; zero for checkpoints).
    pub extra_time: f64,
}

/// A compiled per-layer execution plan plus its predicted totals.
///
/// Two peak predictions ride along, answering two different questions:
/// [`Self::planned_peak`] uses exactly the Table-1 accounting of
/// [`memsim::predict_memory`] (residuals + aids + a two-activation
/// transient), so it is directly comparable against the whole-network
/// engine predictions in `memsim::plan`. [`Self::conservative_peak`]
/// bounds the worst *live* transient of the three-phase execution
/// (input + output activation, input + output cotangent, the kernel
/// scratch leases — `conservative_transient_bytes` in this module) and
/// is what the budget constraint is enforced against —
/// `conservative_peak ≤ budget` is what makes the
/// engine's **measured** `tracker` peak respect the budget
/// (`tests/planner.rs`).
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// One decision per layer, in layer order.
    pub decisions: Vec<LayerDecision>,
    /// Predicted peak extra bytes in Table-1 accounting (comparable to
    /// [`memsim::predict_memory`]): Phase-I minimal residuals + parked
    /// aids + two live activations.
    pub planned_peak: usize,
    /// Conservative peak bound the budget is enforced against (see type
    /// docs); always ≥ [`Self::planned_peak`].
    pub conservative_peak: usize,
    /// Predicted step time in forward-FLOP units (Phase I + II + III
    /// plus per-strategy extras).
    pub time_units: f64,
    /// The budget the plan was selected under (`None` = unbounded).
    pub budget: Option<usize>,
}

impl CompiledPlan {
    /// `"vijp=4 frag=2 ckpt=1 skip=1"`-style mix summary.
    pub fn mix(&self) -> String {
        let mut vijp = 0usize;
        let mut frag = 0usize;
        let mut ckpt = 0usize;
        let mut skip = 0usize;
        for d in &self.decisions {
            match d.strategy {
                Strategy::Vijp => vijp += 1,
                Strategy::Fragment { .. } => frag += 1,
                Strategy::Residual(ResidualTier::Full) => ckpt += 1,
                Strategy::Residual(ResidualTier::Minimal) => skip += 1,
            }
        }
        format!("vijp={vijp} frag={frag} ckpt={ckpt} skip={skip}")
    }
}

/// Extra vijp time factor for spatially coupled (wavefront) layers —
/// the elimination serializes over positions, so it is charged double a
/// forward sweep where the fast path is charged one.
const WAVEFRONT_TIME_FACTOR: f64 = 2.0;

/// Frontier cap per chain state. Dominance pruning keeps frontiers far
/// below this at realistic depths; the cap only bounds pathological
/// inputs, with deterministic (budget-independent) thinning so plan
/// selection stays reproducible.
const MAX_FRONTIER: usize = 4096;

/// One Pareto-frontier entry: cumulative aid bytes / extra time plus the
/// strategy path that produced them.
#[derive(Clone, Debug)]
struct Entry {
    aid_bytes: usize,
    extra_time: f64,
    path: Vec<Strategy>,
}

/// The budget-independent result of the DP: everything needed to answer
/// `select(budget)` for any budget.
#[derive(Clone, Debug)]
pub struct PlanFrontier {
    /// Non-dominated complete paths (both end chain states merged).
    entries: Vec<Entry>,
    /// Phase-I minimal-residual bytes plus the Table-1 two-activation
    /// transient — the base of the memsim-comparable `planned_peak`.
    base_model_bytes: usize,
    /// Phase-I minimal-residual bytes plus the conservative transient
    /// bound (`conservative_transient_bytes`) — the base of
    /// `conservative_peak`, which the budget constraint uses.
    base_conservative_bytes: usize,
    /// Budget-independent base time (Phase I fwd + Phase II vjp + Phase
    /// III fwd + param-vjp), in forward-FLOP units.
    base_time: f64,
}

/// Conservative transient-bytes bound for the Moonwalk phase structure:
/// the worst per-layer live set across the three phases — input and
/// output activation, input and output cotangent (the engine drops the
/// input cotangent before `vjp_params`, but both co-live while the
/// output one is produced), plus the kernel scratch leases (the conv
/// patch gathers hold up to `k` input-sized buffers; `4·in + 3·act`
/// covers `k = 3` resolution-preserving convs with an activation to
/// spare) — maximized over layers. Deliberately conservative so
/// `conservative_peak ≤ budget` implies the *measured* `tracker` peak
/// respects the budget too (`tests/planner.rs` enforces that
/// implication end-to-end).
fn conservative_transient_bytes(probes: &[LayerProbe]) -> usize {
    probes
        .iter()
        .map(|p| 4 * p.cost.in_bytes + 3 * p.measured_act)
        .max()
        .unwrap_or(0)
}

/// The Table-1 transient (two live activations), exactly what
/// [`memsim::predict_memory`] charges the Moonwalk family — kept
/// identical so `planned_peak` and the whole-network predictions are
/// comparable numbers.
fn model_transient_bytes(probes: &[LayerProbe]) -> usize {
    2 * probes
        .iter()
        .map(|p| p.measured_act.max(p.cost.in_bytes))
        .max()
        .unwrap_or(0)
}

/// Candidate strategies for one layer given the chain state at its
/// input. Returns `(strategy, aid_bytes, extra_time, chain_ok_out)`.
fn candidates(p: &LayerProbe, chain_ok: bool) -> Vec<(Strategy, usize, f64, bool)> {
    let mut out = Vec::with_capacity(3 + p.fragments.len());
    if chain_ok && p.cost.submersive {
        let factor = if p.cost.fast_vijp {
            1.0
        } else {
            WAVEFRONT_TIME_FACTOR
        };
        out.push((Strategy::Vijp, 0, p.cost.flops * factor, true));
    }
    if chain_ok {
        for f in &p.fragments {
            out.push((
                Strategy::Fragment { block: f.block },
                f.bytes,
                p.cost.flops,
                true,
            ));
        }
    }
    out.push((
        Strategy::Residual(ResidualTier::Full),
        p.measured_act,
        0.0,
        true,
    ));
    if p.cost.d_params == 0 {
        out.push((Strategy::Residual(ResidualTier::Minimal), 0, 0.0, false));
    }
    out
}

/// Dominance-prune a frontier in place: sort by `(bytes, time)` and keep
/// entries with strictly decreasing time; deterministic thinning if the
/// cap is exceeded.
fn prune(entries: &mut Vec<Entry>) {
    entries.sort_by(|a, b| {
        a.aid_bytes
            .cmp(&b.aid_bytes)
            .then(a.extra_time.total_cmp(&b.extra_time))
    });
    let mut kept: Vec<Entry> = Vec::with_capacity(entries.len().min(MAX_FRONTIER));
    let mut best_time = f64::INFINITY;
    for e in entries.drain(..) {
        if e.extra_time < best_time {
            best_time = e.extra_time;
            kept.push(e);
        }
    }
    if kept.len() > MAX_FRONTIER {
        // Keep the endpoints and an even byte-ordered stride between —
        // purely index-based, so thinning is budget-independent.
        let last = kept.len() - 1;
        let stride = (kept.len() + MAX_FRONTIER - 1) / MAX_FRONTIER;
        let mut thinned: Vec<Entry> = Vec::with_capacity(MAX_FRONTIER + 1);
        for (i, e) in kept.into_iter().enumerate() {
            if i == 0 || i == last || i % stride == 0 {
                thinned.push(e);
            }
        }
        kept = thinned;
    }
    *entries = kept;
}

/// Run the DP over `probes` and return the budget-independent frontier.
pub fn build_frontier(probes: &[LayerProbe]) -> PlanFrontier {
    // state frontiers: [chain intact, chain broken]
    let mut ok: Vec<Entry> = vec![Entry {
        aid_bytes: 0,
        extra_time: 0.0,
        path: Vec::new(),
    }];
    let mut broken: Vec<Entry> = Vec::new();
    for p in probes {
        let mut next_ok: Vec<Entry> = Vec::new();
        let mut next_broken: Vec<Entry> = Vec::new();
        for (state_ok, frontier) in [(true, &ok), (false, &broken)] {
            for entry in frontier.iter() {
                for (strategy, bytes, time, out_ok) in candidates(p, state_ok) {
                    let mut path = entry.path.clone();
                    path.push(strategy);
                    let e = Entry {
                        aid_bytes: entry.aid_bytes + bytes,
                        extra_time: entry.extra_time + time,
                        path,
                    };
                    if out_ok {
                        next_ok.push(e);
                    } else {
                        next_broken.push(e);
                    }
                }
            }
        }
        prune(&mut next_ok);
        prune(&mut next_broken);
        ok = next_ok;
        broken = next_broken;
    }
    let mut entries = ok;
    entries.extend(broken);
    prune(&mut entries);
    let base_time: f64 = probes
        .iter()
        .map(|p| p.cost.flops * 3.0 + if p.cost.d_params > 0 { p.cost.flops } else { 0.0 })
        .sum();
    let mx_sum: usize = probes.iter().map(|p| p.measured_mx).sum();
    PlanFrontier {
        entries,
        base_model_bytes: mx_sum + model_transient_bytes(probes),
        base_conservative_bytes: mx_sum + conservative_transient_bytes(probes),
        base_time,
    }
}

impl PlanFrontier {
    /// The smallest achievable **conservative** peak (the all-cheapest
    /// plan) — the lower end of any feasible budget, and what the
    /// infeasibility error reports.
    pub fn min_peak(&self) -> usize {
        self.base_conservative_bytes
            + self
                .entries
                .iter()
                .map(|e| e.aid_bytes)
                .min()
                .unwrap_or(0)
    }

    /// The conservative peak of the unbounded (fastest) plan — the upper
    /// end of any meaningful budget sweep (budgets above it change
    /// nothing).
    pub fn max_useful_peak(&self) -> usize {
        self.base_conservative_bytes
            + self
                .select_entry(None)
                .map(|e| e.aid_bytes)
                .unwrap_or(0)
    }

    /// Deterministic selection: among entries whose conservative peak
    /// fits the budget, the minimum `(time, bytes)` (in that order).
    /// `None` budget = unbounded.
    fn select_entry(&self, budget: Option<usize>) -> Option<&Entry> {
        self.entries
            .iter()
            .filter(|e| match budget {
                Some(b) => self.base_conservative_bytes + e.aid_bytes <= b,
                None => true,
            })
            .min_by(|a, b| {
                a.extra_time
                    .total_cmp(&b.extra_time)
                    .then(a.aid_bytes.cmp(&b.aid_bytes))
            })
    }

    /// Select the best plan under `budget` and materialize its per-layer
    /// decisions. Errs when even the all-cheapest plan exceeds the
    /// budget (the error names the minimum achievable peak).
    pub fn select(
        &self,
        probes: &[LayerProbe],
        budget: Option<usize>,
    ) -> anyhow::Result<CompiledPlan> {
        let entry = self.select_entry(budget).ok_or_else(|| {
            anyhow::anyhow!(
                "no per-layer plan fits a budget of {} bytes; the minimum \
                 achievable predicted peak for this network/shape is {} bytes",
                budget.unwrap_or(0),
                self.min_peak()
            )
        })?;
        let mut decisions = Vec::with_capacity(probes.len());
        let mut chain_ok = true;
        for (p, &strategy) in probes.iter().zip(&entry.path) {
            let found = candidates(p, chain_ok)
                .into_iter()
                .find(|(s, ..)| *s == strategy)
                .expect("path strategy must be a legal candidate");
            let (_, aid_bytes, extra_time, out_ok) = found;
            decisions.push(LayerDecision {
                strategy,
                aid_bytes,
                extra_time,
            });
            chain_ok = out_ok;
        }
        let plan = CompiledPlan {
            planned_peak: self.base_model_bytes + entry.aid_bytes,
            conservative_peak: self.base_conservative_bytes + entry.aid_bytes,
            time_units: self.base_time + entry.extra_time,
            decisions,
            budget,
        };
        validate(&plan.decisions, probes)?;
        Ok(plan)
    }
}

/// Compile the best plan for `probes` under `budget` (`None` =
/// unbounded): [`build_frontier`] + [`PlanFrontier::select`]. Callers
/// sweeping budgets should build the frontier once and select per
/// budget.
pub fn compile(probes: &[LayerProbe], budget: Option<usize>) -> anyhow::Result<CompiledPlan> {
    build_frontier(probes).select(probes, budget)
}

/// Check that `decisions` is executable against `probes`: chain-state
/// legality per strategy and a cotangent for every parameterized layer.
/// The planner always produces valid plans; the engine re-validates as
/// defense against hand-built ones.
pub fn validate(decisions: &[LayerDecision], probes: &[LayerProbe]) -> anyhow::Result<()> {
    anyhow::ensure!(
        decisions.len() == probes.len(),
        "plan has {} decisions for {} layers",
        decisions.len(),
        probes.len()
    );
    let mut chain_ok = true;
    for (i, (d, p)) in decisions.iter().zip(probes).enumerate() {
        match d.strategy {
            Strategy::Vijp => {
                anyhow::ensure!(
                    chain_ok && p.cost.submersive,
                    "layer {i} ({}): Vijp needs a submersive layer on an intact chain",
                    p.cost.name
                );
            }
            Strategy::Fragment { block } => {
                anyhow::ensure!(
                    chain_ok && p.cost.fragmental_ok,
                    "layer {i} ({}): Fragment needs fragmental support on an intact chain",
                    p.cost.name
                );
                anyhow::ensure!(
                    p.fragments.iter().any(|f| f.block == block),
                    "layer {i} ({}): block {block} was not probed",
                    p.cost.name
                );
            }
            Strategy::Residual(ResidualTier::Full) => {}
            Strategy::Residual(ResidualTier::Minimal) => {
                anyhow::ensure!(
                    p.cost.d_params == 0,
                    "layer {i} ({}): a parameterized layer cannot skip its cotangent",
                    p.cost.name
                );
            }
        }
        chain_ok = !matches!(d.strategy, Strategy::Residual(ResidualTier::Minimal));
    }
    Ok(())
}

/// Human-readable plan table: per-layer strategy, planned bytes, and the
/// probe's measured-vs-analytic columns, plus the totals line the CLI
/// prints. The `timed_ms` column shows the conv autotune cache's
/// calibrated forward time beside the analytic cost ("-" when the layer
/// has no cached calibration — see [`crate::plan::probe::attach_timed`]).
pub fn summary_table(plan: &CompiledPlan, probes: &[LayerProbe]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<4} {:<34} {:<12} {:>12} {:>12} {:>12} {:>9}",
        "#", "layer", "strategy", "aid_bytes", "mx_bytes", "act_bytes", "timed_ms"
    );
    for (i, (d, p)) in plan.decisions.iter().zip(probes).enumerate() {
        let timed = match p.timed_fwd_ms {
            Some(ms) => format!("{ms:.3}"),
            None => "-".into(),
        };
        let _ = writeln!(
            out,
            "{:<4} {:<34} {:<12} {:>12} {:>12} {:>12} {:>9}",
            i,
            p.cost.name,
            d.strategy.label(),
            d.aid_bytes,
            p.measured_mx,
            p.measured_act,
            timed
        );
    }
    let _ = writeln!(
        out,
        "plan: {} | planned_peak={} conservative_peak={} time={:.3e} fwd-flops{}",
        plan.mix(),
        crate::tensor::tracker::fmt_bytes(plan.planned_peak),
        crate::tensor::tracker::fmt_bytes(plan.conservative_peak),
        plan.time_units,
        match plan.budget {
            Some(b) => format!(
                " | budget={} ({})",
                b,
                crate::tensor::tracker::fmt_bytes(b)
            ),
            None => " | budget=unbounded".into(),
        }
    );
    out
}

/// Analytic fragment bytes for reporting parity with
/// [`memsim::fragment_checkpoint_bytes`] (re-exported here so plan-side
/// callers need not import memsim).
pub fn fragment_bytes(act_bytes: usize, block: usize, k: usize) -> usize {
    memsim::fragment_checkpoint_bytes(act_bytes, block, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        build_cnn1d_fragmental, build_cnn2d, FragmentalCnn1dSpec, SubmersiveCnn2dSpec,
    };
    use crate::plan::probe::{probe_network, DEFAULT_FRAG_BLOCKS};
    use crate::util::Rng;

    fn probes_2d(depth: usize) -> Vec<LayerProbe> {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth,
            channels: 4,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        probe_network(&net, &[2, 16, 16, 2], DEFAULT_FRAG_BLOCKS).unwrap()
    }

    fn probes_1d(depth: usize) -> Vec<LayerProbe> {
        let mut rng = Rng::new(1);
        let spec = FragmentalCnn1dSpec {
            input_len: 64,
            channels: 8,
            depth,
            ..Default::default()
        };
        let net = build_cnn1d_fragmental(&spec, &mut rng);
        probe_network(&net, &[2, 64, 3], DEFAULT_FRAG_BLOCKS).unwrap()
    }

    #[test]
    fn unbounded_plan_checkpoints_everything_checkpointable() {
        let probes = probes_2d(3);
        let plan = compile(&probes, None).unwrap();
        // With no budget pressure every layer takes the zero-extra-time
        // strategy: Residual(Full), except parameter-free layers where
        // Minimal is equally fast and strictly cheaper in bytes... but
        // Minimal breaks the chain, which is fine because Full re-anchors
        // downstream. Either way: no vijp/fragment time is paid.
        assert_eq!(plan.decisions.len(), probes.len());
        for d in &plan.decisions {
            assert_eq!(d.extra_time, 0.0, "{:?}", d.strategy);
        }
        validate(&plan.decisions, &probes).unwrap();
    }

    #[test]
    fn tight_budget_recovers_moonwalk_shape() {
        let probes = probes_2d(3);
        let frontier = build_frontier(&probes);
        let min = frontier.min_peak();
        let plan = frontier.select(&probes, Some(min)).unwrap();
        assert_eq!(plan.conservative_peak, min);
        assert!(plan.planned_peak <= plan.conservative_peak);
        // The minimum-byte plan on a submersive 2-D net is the Moonwalk
        // plan: vijp everywhere the chain allows, the h₁-anchor
        // checkpoint after the non-submersive Upsample break.
        assert!(matches!(
            plan.decisions[0].strategy,
            Strategy::Residual(ResidualTier::Minimal)
        ));
        assert!(matches!(
            plan.decisions[1].strategy,
            Strategy::Residual(ResidualTier::Full)
        ));
        for d in &plan.decisions[2..] {
            assert!(
                !matches!(d.strategy, Strategy::Residual(ResidualTier::Full)),
                "tight budget must not afford extra checkpoints: {:?}",
                d.strategy
            );
        }
        validate(&plan.decisions, &probes).unwrap();
    }

    #[test]
    fn fragmental_net_gets_fragment_strategies_under_budget() {
        let probes = probes_1d(3);
        let frontier = build_frontier(&probes);
        let plan = frontier.select(&probes, Some(frontier.min_peak())).unwrap();
        let frags = plan
            .decisions
            .iter()
            .filter(|d| matches!(d.strategy, Strategy::Fragment { .. }))
            .count();
        // The first conv re-anchors the chain the Upsample broke (a full
        // checkpoint — fragments need an intact chain); the remaining
        // convs fragment.
        assert!(frags >= 2, "plan should fragment the 1-D convs: {}", plan.mix());
        // Minimum-byte plan picks the largest probed block everywhere.
        for d in &plan.decisions {
            if let Strategy::Fragment { block } = d.strategy {
                assert_eq!(block, *DEFAULT_FRAG_BLOCKS.last().unwrap());
            }
        }
    }

    #[test]
    fn infeasible_budget_errs_with_minimum() {
        let probes = probes_2d(2);
        let err = compile(&probes, Some(16)).unwrap_err().to_string();
        assert!(err.contains("minimum achievable"), "{err}");
    }

    #[test]
    fn budget_monotone_and_respected() {
        let probes = probes_1d(4);
        let frontier = build_frontier(&probes);
        let lo = frontier.min_peak();
        let hi = frontier.max_useful_peak().max(lo + 1);
        let mut last_peak = 0usize;
        for i in 0..=8 {
            let budget = lo + (hi - lo) * i / 8;
            let plan = frontier.select(&probes, Some(budget)).unwrap();
            assert!(plan.conservative_peak <= budget, "peak over budget");
            assert!(plan.planned_peak <= plan.conservative_peak);
            assert!(
                plan.conservative_peak >= last_peak,
                "tighter budget produced more bytes: {} then {}",
                last_peak,
                plan.conservative_peak
            );
            last_peak = plan.conservative_peak;
            validate(&plan.decisions, &probes).unwrap();
        }
    }

    #[test]
    fn mixed_plan_beats_single_engine_frontier() {
        // The acceptance-criterion shape: at some budget the per-layer
        // plan strictly beats the best whole-network engine on predicted
        // peak bytes at equal-or-better predicted time. Depth 8 (so
        // BackpropCkpt's √L memory does not fit at the low end of the
        // sweep, where memsim must fall back to the 5×fwd Moonwalk
        // family) plus the per-layer block search (B=32 vs memsim's
        // fixed {8,16}) guarantees a win at the tight-budget end.
        let probes = probes_1d(8);
        let costs: Vec<memsim::LayerCost> = probes.iter().map(|p| p.cost.clone()).collect();
        let input_elems = 2 * 64 * 3;
        let frontier = build_frontier(&probes);
        let bp = memsim::predict_memory(&memsim::Method::Backprop, &costs)
            .max(frontier.min_peak());
        let mut found = false;
        for i in 0..8 {
            let budget = frontier.min_peak() + (bp - frontier.min_peak()) * i / 8;
            let plan = match frontier.select(&probes, Some(budget)) {
                Ok(p) => p,
                Err(_) => continue,
            };
            let single = match memsim::plan(&costs, budget, true, input_elems) {
                Some(s) => s,
                None => continue,
            };
            let planned_time_fwd = plan.time_units / costs.iter().map(|c| c.flops).sum::<f64>();
            let single_time_fwd =
                single.2 / costs.iter().map(|c| c.flops).sum::<f64>();
            if plan.planned_peak < single.1 && planned_time_fwd <= single_time_fwd {
                found = true;
                break;
            }
        }
        assert!(found, "no budget point where the mixed plan wins");
    }

    #[test]
    fn summary_table_lists_every_layer() {
        let probes = probes_2d(2);
        let plan = compile(&probes, None).unwrap();
        let table = summary_table(&plan, &probes);
        assert_eq!(table.lines().count(), probes.len() + 2);
        assert!(table.contains("planned_peak="));
        // Without calibration every layer's timed column is the "-"
        // placeholder (probe_network leaves timed_fwd_ms at None).
        assert!(table.contains("timed_ms"));
    }
}
