//! Calibration probe: per-layer residual-tier measurements on a concrete
//! input shape.
//!
//! The analytic Table-1 model ([`crate::memsim::profile`]) predicts each
//! layer's residual tiers from static accounting. The probe *measures*
//! them instead, by constructing the real objects the gradient engines
//! keep alive — one forward per residual tier plus one
//! [`crate::nn::Layer::fragment_capture`] per candidate block — and
//! reading off exactly the byte counts those objects register with
//! [`crate::tensor::tracker`] (every `Tensor`/`BitTensor`/index payload
//! registers its bytes on construction; `tests/planner.rs` cross-checks
//! the probe's numbers against live `tracker::current()` deltas while
//! the residuals are held). Both views ride in one [`LayerProbe`] so the
//! planner's report can show predicted-vs-measured drift per layer —
//! the analytic fragment formula, for instance, ignores the rounded-up
//! tail block that the real capture stores.
//!
//! The probe deliberately avoids the *global* tracker state
//! (`measure`/`reset_peak`/`measure_lock`): it may run lazily inside an
//! open `tracker::measure` window (the trainer's first step, a replica
//! worker), where taking the measurement lock would deadlock and
//! resetting the peak would corrupt the caller's profile. Determinism
//! matters for the same reason — plans compiled from a probe must be
//! identical across runs and replicas, so every number here is a pure
//! function of the network and input shape.

use crate::memsim::{self, LayerCost};
use crate::model::Network;
use crate::nn::{residual_bytes, ResidualKind};
use crate::tensor::Tensor;

/// Candidate fragmental block sizes the probe measures by default
/// (superset of the whole-network planner's `{8, 16}` candidates — the
/// per-layer search is exactly where larger blocks start to pay).
pub const DEFAULT_FRAG_BLOCKS: &[usize] = &[8, 16, 32];

/// One measured fragmental-capture candidate for a layer.
#[derive(Clone, Debug)]
pub struct FragmentProbe {
    /// Block size `B` handed to `fragment_capture`.
    pub block: usize,
    /// Bytes the captured [`crate::nn::Fragment`] actually holds
    /// (tracker-registered payload of its slice tensor).
    pub bytes: usize,
    /// The analytic prediction ([`memsim::fragment_checkpoint_bytes`])
    /// for the same block — kept beside the measurement so the plan
    /// report can show the drift (tail-block rounding).
    pub predicted_bytes: usize,
}

/// Per-layer calibration record: the analytic [`LayerCost`] beside the
/// measured residual tiers.
#[derive(Clone, Debug)]
pub struct LayerProbe {
    /// Analytic Table-1 costs for this layer on the probed shape.
    pub cost: LayerCost,
    /// Measured bytes of the `Minimal` residual (what Moonwalk Phase I
    /// keeps: sign bits, argmax indices — zero for conv/dense).
    pub measured_mx: usize,
    /// Measured *additional* bytes of the `Full` residual over `Minimal`
    /// (what Backprop's tape adds per layer).
    pub measured_m_theta: usize,
    /// Measured output-activation bytes (= the bytes of a full output
    /// cotangent checkpoint for this layer).
    pub measured_act: usize,
    /// Measured fragmental candidates (empty when the layer does not
    /// support §5.1 capture).
    pub fragments: Vec<FragmentProbe>,
}

impl LayerProbe {
    /// The measured bytes of the cheapest fragmental candidate, if any.
    pub fn best_fragment(&self) -> Option<&FragmentProbe> {
        self.fragments.iter().min_by_key(|f| f.bytes)
    }
}

/// Probe every layer of `net` on `in_shape`: one forward per residual
/// tier, plus one `fragment_capture` per applicable `frag_blocks`
/// candidate. Returns one [`LayerProbe`] per layer, in layer order.
///
/// Cost: two forward passes over the network plus the captures — plan
/// time, not training-hot-path time. Safe to call inside an open
/// `tracker::measure` window (see module docs), though the transient
/// probe tensors will then show up in that window's profile.
pub fn probe_network(
    net: &Network,
    in_shape: &[usize],
    frag_blocks: &[usize],
) -> anyhow::Result<Vec<LayerProbe>> {
    anyhow::ensure!(net.depth() > 0, "cannot probe an empty network");
    let costs = memsim::profile(net, in_shape)?;
    let mut probes = Vec::with_capacity(net.depth());
    let mut x = Tensor::zeros(in_shape);
    for (layer, cost) in net.layers.iter().zip(costs) {
        let (y, res_min) = layer.forward_res(&x, ResidualKind::Minimal);
        let (_, res_full) = layer.forward_res(&x, ResidualKind::Full);
        let measured_mx = residual_bytes(&res_min);
        let measured_full = residual_bytes(&res_full);
        let mut fragments = Vec::new();
        if cost.fragmental_ok {
            // The captured cotangent has the layer's *output* shape; a
            // zero tensor is enough — capture stores slices, its byte
            // count depends only on geometry.
            let h_out = Tensor::zeros(y.shape());
            for &block in frag_blocks {
                if let Ok(frag) = layer.fragment_capture(&h_out, block) {
                    fragments.push(FragmentProbe {
                        block,
                        bytes: frag.slices.bytes(),
                        predicted_bytes: memsim::fragment_checkpoint_bytes(
                            y.bytes(),
                            block,
                            kernel_taps(&cost),
                        ),
                    });
                }
            }
        }
        probes.push(LayerProbe {
            measured_mx,
            measured_m_theta: measured_full.saturating_sub(measured_mx),
            measured_act: y.bytes(),
            fragments,
            cost,
        });
        x = y;
    }
    Ok(probes)
}

/// Best-effort kernel width for the analytic fragment formula, recovered
/// from the layer label (`conv1d(k=3,...)`); the measured bytes are
/// authoritative, this only feeds the predicted-vs-measured column.
fn kernel_taps(cost: &LayerCost) -> usize {
    cost.name
        .split("k=")
        .nth(1)
        .and_then(|rest| {
            rest.split(|c: char| !c.is_ascii_digit())
                .next()
                .and_then(|d| d.parse::<usize>().ok())
        })
        .unwrap_or(3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_cnn1d_fragmental, build_cnn2d, FragmentalCnn1dSpec, SubmersiveCnn2dSpec};
    use crate::util::Rng;

    #[test]
    fn probe_matches_analytic_tiers_on_cnn2d() {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 4,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let probes = probe_network(&net, &[2, 16, 16, 2], DEFAULT_FRAG_BLOCKS).unwrap();
        assert_eq!(probes.len(), net.depth());
        for p in &probes {
            // memsim::profile computes the same tiers from the same
            // objects, so measured and analytic must agree exactly here;
            // the probe's value is catching any future divergence.
            assert_eq!(p.measured_mx, p.cost.mx, "{}", p.cost.name);
            assert_eq!(p.measured_m_theta, p.cost.m_theta, "{}", p.cost.name);
            assert_eq!(p.measured_act, p.cost.act_bytes, "{}", p.cost.name);
            // The 2-D net has no fragmental layers.
            assert!(p.fragments.is_empty());
        }
    }

    #[test]
    fn probe_measures_fragment_candidates_on_cnn1d() {
        let mut rng = Rng::new(1);
        let spec = FragmentalCnn1dSpec {
            input_len: 64,
            channels: 8,
            depth: 2,
            ..Default::default()
        };
        let net = build_cnn1d_fragmental(&spec, &mut rng);
        let probes = probe_network(&net, &[2, 64, 3], DEFAULT_FRAG_BLOCKS).unwrap();
        let frag_layers: Vec<&LayerProbe> =
            probes.iter().filter(|p| p.cost.fragmental_ok).collect();
        assert_eq!(frag_layers.len(), 2, "one probe per fragmental conv");
        for p in frag_layers {
            assert!(!p.fragments.is_empty(), "{}", p.cost.name);
            // Larger blocks store fewer slices.
            for w in p.fragments.windows(2) {
                assert!(w[0].block < w[1].block);
                assert!(w[0].bytes >= w[1].bytes);
            }
            // Measured vs analytic agree when the block divides the
            // length (64 here), i.e. no tail rounding.
            for f in &p.fragments {
                if 64 % f.block == 0 {
                    assert_eq!(f.bytes, f.predicted_bytes, "{} B={}", p.cost.name, f.block);
                }
            }
            assert_eq!(
                p.best_fragment().unwrap().block,
                *DEFAULT_FRAG_BLOCKS.last().unwrap()
            );
        }
    }
}
