//! Calibration probe: per-layer residual-tier measurements on a concrete
//! input shape.
//!
//! The analytic Table-1 model ([`crate::memsim::profile`]) predicts each
//! layer's residual tiers from static accounting. The probe *measures*
//! them instead, by constructing the real objects the gradient engines
//! keep alive — one forward per residual tier plus one
//! [`crate::nn::Layer::fragment_capture`] per candidate block — and
//! reading off exactly the byte counts those objects register with
//! [`crate::tensor::tracker`] (every `Tensor`/`BitTensor`/index payload
//! registers its bytes on construction; `tests/planner.rs` cross-checks
//! the probe's numbers against live `tracker::current()` deltas while
//! the residuals are held). Both views ride in one [`LayerProbe`] so the
//! planner's report can show predicted-vs-measured drift per layer —
//! the analytic fragment formula, for instance, ignores the rounded-up
//! tail block that the real capture stores.
//!
//! The probe deliberately avoids the *global* tracker state
//! (`measure`/`reset_peak`/`measure_lock`): it may run lazily inside an
//! open `tracker::measure` window (the trainer's first step, a replica
//! worker), where taking the measurement lock would deadlock and
//! resetting the peak would corrupt the caller's profile. Determinism
//! matters for the same reason — plans compiled from a probe must be
//! identical across runs and replicas, so every number here is a pure
//! function of the network and input shape.
//!
//! Reversible blocks (`nn::reversible`) need no special handling here:
//! each block is a single layer whose `forward_res` at `Minimal` yields
//! a `ResidualData::Block` holding only the inner branches' residuals,
//! so a pure-Dense coupling block probes to `measured_mx == 0` — the
//! zero-residual contract the planner's free-vijp assignment rests on
//! (`tests/reversible.rs` asserts it end to end).
//!
//! Wall-clock timing is the one exception, and it is opt-in only:
//! [`calibrate_convs`] runs the conv autotune (`plan --autotune`) and
//! [`attach_timed`] copies the resulting *cached* milliseconds onto the
//! probes as [`LayerProbe::timed_fwd_ms`]. Attaching is a pure cache
//! lookup — for a fixed cache file the timed column (and the compiled
//! plan) is identical across processes, which is what lets respawned
//! replica workers agree with their coordinator.

use crate::memsim::{self, LayerCost};
use crate::model::Network;
use crate::nn::{residual_bytes, ResidualKind};
use crate::tensor::Tensor;

/// Candidate fragmental block sizes the probe measures by default
/// (superset of the whole-network planner's `{8, 16}` candidates — the
/// per-layer search is exactly where larger blocks start to pay).
pub const DEFAULT_FRAG_BLOCKS: &[usize] = &[8, 16, 32];

/// One measured fragmental-capture candidate for a layer.
#[derive(Clone, Debug)]
pub struct FragmentProbe {
    /// Block size `B` handed to `fragment_capture`.
    pub block: usize,
    /// Bytes the captured [`crate::nn::Fragment`] actually holds
    /// (tracker-registered payload of its slice tensor).
    pub bytes: usize,
    /// The analytic prediction ([`memsim::fragment_checkpoint_bytes`])
    /// for the same block — kept beside the measurement so the plan
    /// report can show the drift (tail-block rounding).
    pub predicted_bytes: usize,
}

/// Per-layer calibration record: the analytic [`LayerCost`] beside the
/// measured residual tiers.
#[derive(Clone, Debug)]
pub struct LayerProbe {
    /// Analytic Table-1 costs for this layer on the probed shape.
    pub cost: LayerCost,
    /// Measured bytes of the `Minimal` residual (what Moonwalk Phase I
    /// keeps: sign bits, argmax indices — zero for conv/dense).
    pub measured_mx: usize,
    /// Measured *additional* bytes of the `Full` residual over `Minimal`
    /// (what Backprop's tape adds per layer).
    pub measured_m_theta: usize,
    /// Measured output-activation bytes (= the bytes of a full output
    /// cotangent checkpoint for this layer).
    pub measured_act: usize,
    /// Measured fragmental candidates (empty when the layer does not
    /// support §5.1 capture).
    pub fragments: Vec<FragmentProbe>,
    /// Timed forward milliseconds from the conv autotune cache, if the
    /// layer is a convolution whose forward op has been calibrated
    /// (`None` otherwise). [`probe_network`] always leaves this `None` —
    /// its numbers are a pure function of network and shape — and
    /// [`attach_timed`] fills it in from
    /// [`crate::tensor::conv_algo::cached_time_ms`] afterwards, which is
    /// a pure *lookup* (no wall-clock measurement ever happens here).
    pub timed_fwd_ms: Option<f64>,
}

impl LayerProbe {
    /// The measured bytes of the cheapest fragmental candidate, if any.
    pub fn best_fragment(&self) -> Option<&FragmentProbe> {
        self.fragments.iter().min_by_key(|f| f.bytes)
    }
}

/// Probe every layer of `net` on `in_shape`: one forward per residual
/// tier, plus one `fragment_capture` per applicable `frag_blocks`
/// candidate. Returns one [`LayerProbe`] per layer, in layer order.
///
/// Cost: two forward passes over the network plus the captures — plan
/// time, not training-hot-path time. Safe to call inside an open
/// `tracker::measure` window (see module docs), though the transient
/// probe tensors will then show up in that window's profile.
pub fn probe_network(
    net: &Network,
    in_shape: &[usize],
    frag_blocks: &[usize],
) -> anyhow::Result<Vec<LayerProbe>> {
    anyhow::ensure!(net.depth() > 0, "cannot probe an empty network");
    let costs = memsim::profile(net, in_shape)?;
    let mut probes = Vec::with_capacity(net.depth());
    let mut x = Tensor::zeros(in_shape);
    for (layer, cost) in net.layers.iter().zip(costs) {
        let (y, res_min) = layer.forward_res(&x, ResidualKind::Minimal);
        let (_, res_full) = layer.forward_res(&x, ResidualKind::Full);
        let measured_mx = residual_bytes(&res_min);
        let measured_full = residual_bytes(&res_full);
        let mut fragments = Vec::new();
        if cost.fragmental_ok {
            // The captured cotangent has the layer's *output* shape; a
            // zero tensor is enough — capture stores slices, its byte
            // count depends only on geometry.
            let h_out = Tensor::zeros(y.shape());
            for &block in frag_blocks {
                if let Ok(frag) = layer.fragment_capture(&h_out, block) {
                    fragments.push(FragmentProbe {
                        block,
                        bytes: frag.slices.bytes(),
                        predicted_bytes: memsim::fragment_checkpoint_bytes(
                            y.bytes(),
                            block,
                            kernel_taps(&cost),
                        ),
                    });
                }
            }
        }
        probes.push(LayerProbe {
            measured_mx,
            measured_m_theta: measured_full.saturating_sub(measured_mx),
            measured_act: y.bytes(),
            fragments,
            cost,
            timed_fwd_ms: None,
        });
        x = y;
    }
    Ok(probes)
}

/// Fill each probe's [`LayerProbe::timed_fwd_ms`] from the conv
/// autotune cache. Pure lookups only: the per-layer input shapes are
/// walked with [`crate::nn::Layer::out_shape`] (no forwards), each conv
/// layer's [`crate::nn::Layer::conv_tune_key`] is matched against
/// [`crate::tensor::conv_algo::cached_time_ms`], and layers without a
/// cached calibration stay `None`. Nothing here measures wall-clock
/// time, so attaching keeps plans deterministic for a fixed cache file —
/// exactly the property that lets a coordinator and its respawned
/// replica workers compile identical plans from a shared cache.
pub fn attach_timed(net: &Network, in_shape: &[usize], probes: &mut [LayerProbe]) {
    let mut shape = in_shape.to_vec();
    for (layer, probe) in net.layers.iter().zip(probes.iter_mut()) {
        if let Some(key) = layer.conv_tune_key(&shape) {
            probe.timed_fwd_ms = crate::tensor::conv_algo::cached_time_ms(&key);
        }
        match layer.out_shape(&shape) {
            Ok(next) => shape = next,
            Err(_) => break,
        }
    }
}

/// Calibrate every convolution layer of `net` on `in_shape`: run the
/// forward chain once to materialize each layer's concrete input, and
/// hand it to [`crate::nn::Layer::conv_autotune`], which times the
/// applicable [`crate::tensor::conv_algo::ConvAlgo`] candidates and
/// records the winners in the autotune cache (persisted when a cache
/// path is configured). Already-cached ops are *not* re-timed — their
/// outcomes come back with `cached == true` — so a second calibration
/// pass over the same network is near-free.
///
/// This is the planner-side explicit calibration entry point (`plan
/// --autotune`); nothing in the default resolve path ever measures
/// time. The probe input is pseudo-random rather than zero because the
/// direct conv kernels skip zero inputs (sparsity fast path), which
/// would bias the timings.
pub fn calibrate_convs(
    net: &Network,
    in_shape: &[usize],
) -> anyhow::Result<Vec<crate::tensor::conv_algo::TuneOutcome>> {
    anyhow::ensure!(net.depth() > 0, "cannot calibrate an empty network");
    let mut rng = crate::util::Rng::new(0x7a11);
    let mut x = Tensor::randn(in_shape, 0.5, &mut rng);
    let mut outcomes = Vec::new();
    for layer in net.layers.iter() {
        outcomes.extend(layer.conv_autotune(&x));
        x = layer.forward(&x);
    }
    Ok(outcomes)
}

/// Best-effort kernel width for the analytic fragment formula, recovered
/// from the layer label (`conv1d(k=3,...)`); the measured bytes are
/// authoritative, this only feeds the predicted-vs-measured column.
fn kernel_taps(cost: &LayerCost) -> usize {
    cost.name
        .split("k=")
        .nth(1)
        .and_then(|rest| {
            rest.split(|c: char| !c.is_ascii_digit())
                .next()
                .and_then(|d| d.parse::<usize>().ok())
        })
        .unwrap_or(3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_cnn1d_fragmental, build_cnn2d, FragmentalCnn1dSpec, SubmersiveCnn2dSpec};
    use crate::util::Rng;

    #[test]
    fn probe_matches_analytic_tiers_on_cnn2d() {
        let mut rng = Rng::new(0);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 4,
            cin: 2,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let probes = probe_network(&net, &[2, 16, 16, 2], DEFAULT_FRAG_BLOCKS).unwrap();
        assert_eq!(probes.len(), net.depth());
        for p in &probes {
            // memsim::profile computes the same tiers from the same
            // objects, so measured and analytic must agree exactly here;
            // the probe's value is catching any future divergence.
            assert_eq!(p.measured_mx, p.cost.mx, "{}", p.cost.name);
            assert_eq!(p.measured_m_theta, p.cost.m_theta, "{}", p.cost.name);
            assert_eq!(p.measured_act, p.cost.act_bytes, "{}", p.cost.name);
            // The 2-D net has no fragmental layers.
            assert!(p.fragments.is_empty());
        }
    }

    #[test]
    fn probe_measures_fragment_candidates_on_cnn1d() {
        let mut rng = Rng::new(1);
        let spec = FragmentalCnn1dSpec {
            input_len: 64,
            channels: 8,
            depth: 2,
            ..Default::default()
        };
        let net = build_cnn1d_fragmental(&spec, &mut rng);
        let probes = probe_network(&net, &[2, 64, 3], DEFAULT_FRAG_BLOCKS).unwrap();
        let frag_layers: Vec<&LayerProbe> =
            probes.iter().filter(|p| p.cost.fragmental_ok).collect();
        assert_eq!(frag_layers.len(), 2, "one probe per fragmental conv");
        for p in frag_layers {
            assert!(!p.fragments.is_empty(), "{}", p.cost.name);
            // Larger blocks store fewer slices.
            for w in p.fragments.windows(2) {
                assert!(w[0].block < w[1].block);
                assert!(w[0].bytes >= w[1].bytes);
            }
            // Measured vs analytic agree when the block divides the
            // length (64 here), i.e. no tail rounding.
            for f in &p.fragments {
                if 64 % f.block == 0 {
                    assert_eq!(f.bytes, f.predicted_bytes, "{} B={}", p.cost.name, f.block);
                }
            }
            assert_eq!(
                p.best_fragment().unwrap().block,
                *DEFAULT_FRAG_BLOCKS.last().unwrap()
            );
        }
    }

    #[test]
    fn calibrate_then_attach_fills_timed_column() {
        // Distinct geometry from every other cache-touching test so the
        // process-global autotune cache keys cannot collide.
        let mut rng = Rng::new(2);
        let spec = FragmentalCnn1dSpec {
            input_len: 48,
            channels: 6,
            depth: 2,
            ..Default::default()
        };
        let net = build_cnn1d_fragmental(&spec, &mut rng);
        let in_shape = [1usize, 48, 3];
        let mut probes = probe_network(&net, &in_shape, DEFAULT_FRAG_BLOCKS).unwrap();
        assert!(
            probes.iter().all(|p| p.timed_fwd_ms.is_none()),
            "probe_network must stay a pure function of network and shape"
        );
        let outcomes = calibrate_convs(&net, &in_shape).unwrap();
        assert!(!outcomes.is_empty(), "fragmental net has conv layers to tune");
        attach_timed(&net, &in_shape, &mut probes);
        let timed: Vec<&LayerProbe> =
            probes.iter().filter(|p| p.timed_fwd_ms.is_some()).collect();
        assert!(!timed.is_empty(), "calibrated conv layers gain a timed column");
        for p in &timed {
            assert!(p.timed_fwd_ms.unwrap() >= 0.0);
        }
        // A second calibration pass is served entirely from the cache.
        let again = calibrate_convs(&net, &in_shape).unwrap();
        assert!(again.iter().all(|o| o.cached), "re-calibration must not re-time");
    }
}
