//! Budgeted per-layer execution planning — the `--budget <bytes>` knob.
//!
//! The paper's core move is choosing, per layer, how much residual state
//! to keep: nothing for submersive layers (vijp recovers the cotangent,
//! Eq. 9), fragmental slices for non-submersive layers that support
//! them (§5.1), and full cotangent checkpoints otherwise (§4.1). The
//! whole-network planner in [`crate::memsim`] picks **one** engine for
//! the whole chain; this module mixes strategies *per layer*, which is
//! where the real memory/time Pareto frontier lives (cf. Beaumont et
//! al., *Optimal checkpointing for heterogeneous chains*, which solves
//! the analogous per-layer-under-budget problem for classic activation
//! checkpointing).
//!
//! Three pieces:
//!
//! * [`probe`] — the calibration probe: per-layer residual tiers
//!   *measured* on the concrete input shape (one forward per tier, one
//!   `fragment_capture` per candidate block), carried beside the
//!   analytic [`crate::memsim::LayerCost`] so predicted-vs-measured
//!   drift is visible in the plan report.
//! * [`planner`] — the Pareto DP over the layer chain: per layer one of
//!   `Vijp` / `Fragment { block }` / `Residual(Full | Minimal)`,
//!   minimizing predicted step time subject to a peak-bytes budget. The
//!   frontier is budget-independent (build once, select per budget),
//!   which makes budget monotonicity exact.
//! * [`crate::autodiff::PlannedEngine`] — executes a compiled plan in
//!   the Moonwalk Phase I–III structure, streaming gradients layer by
//!   layer like every other engine, so it drops into
//!   `ReplicaGroup`/`Transport` unchanged.
//!
//! The budget invariant: a selected plan's
//! [`planner::CompiledPlan::conservative_peak`] never exceeds the
//! budget, and the conservative transient bound is what makes the
//! engine's *measured* `tracker` peak respect the budget too —
//! `rust/tests/planner.rs` enforces both halves.

#![deny(missing_docs)]

pub mod planner;
pub mod probe;

pub use planner::{
    build_frontier, compile, summary_table, validate, CompiledPlan, LayerDecision, PlanFrontier,
    ResidualTier, Strategy,
};
pub use probe::{
    attach_timed, calibrate_convs, probe_network, FragmentProbe, LayerProbe, DEFAULT_FRAG_BLOCKS,
};
