//! Minimal CLI argument parser (substrate for the missing clap crate):
//! `binary <subcommand> [--flag value] [--switch]` with typed accessors
//! and helpful errors, plus the shared global-runtime-flag application
//! ([`configure_runtime`]) used by the binary and the bench harnesses.

use std::collections::BTreeMap;

/// Apply the global runtime flags shared by every entry point:
/// `--threads N` (worker-pool size), `--gemm auto|scalar|blocked|parallel`
/// (GEMM algorithm override), `--conv-algo
/// auto|direct|im2col|winograd` (conv lowering override;
/// `MOONWALK_CONV` is the env spelling), `--conv-cache PATH`
/// (persisted conv-autotune table; `MOONWALK_CONV_CACHE` is the env
/// spelling the coordinator exports to worker subprocesses),
/// `--replicas N` (data-parallel replica count; `MOONWALK_REPLICAS` is
/// the env spelling) and `--transport local|unix|tcp` (where replicas
/// execute — in-process on the pool or one worker subprocess each;
/// `MOONWALK_TRANSPORT` is the env spelling).
///
/// Supervision knobs for the socket transports (env spellings
/// `MOONWALK_STEP_TIMEOUT` / `MOONWALK_ACCEPT_TIMEOUT` /
/// `MOONWALK_HELLO_TIMEOUT`, seconds, and `MOONWALK_HEARTBEAT_MS`):
/// `--step-timeout S` (per-step compute deadline; `0` waits forever),
/// `--accept-timeout S` (worker spawn/accept + param-upload write
/// deadline), `--hello-timeout S` (handshake read deadline) and
/// `--heartbeat-ms MS` (worker liveness ticks while computing; `0`
/// disables, leaving only the step deadline to catch hangs).
///
/// `--trace PATH` (env spelling `MOONWALK_TRACE`) enables span capture
/// and arranges for a merged Chrome trace-event JSON at PATH — entry
/// points call `crate::obs::export::finish()` on success to write it.
///
/// `--metrics-listen HOST:PORT` (env spelling
/// `MOONWALK_METRICS_LISTEN`; port 0 binds an ephemeral port) starts
/// the live telemetry endpoint ([`crate::obs::http`]) and prints the
/// resolved address. Never started in `--replica-worker` mode: workers
/// inherit the coordinator's environment, and the fleet's series reach
/// the coordinator's endpoint over the wire instead.
/// `--straggler-z Z` (env spelling `MOONWALK_STRAGGLER_Z`) sets the
/// step-time z-score beyond which a replica is flagged as a straggler
/// (`0` disables).
///
/// The per-run `--budget` knob is *not* global state — resolve
/// it with [`budget_bytes`] where an engine is built. Call before any
/// tensor work. The persistent worker team is prewarmed here so the
/// first parallel region — often a sub-100 µs kernel in the benches —
/// doesn't pay spawn latency.
pub fn configure_runtime(args: &Args) -> anyhow::Result<()> {
    if let Some(t) = args.get_usize_opt("threads")? {
        crate::runtime::pool::set_threads(t);
    }
    if let Some(algo) = args.get("gemm") {
        crate::tensor::ops::set_gemm_override(algo)?;
    }
    if let Some(algo) = args.get("conv-algo") {
        crate::tensor::conv_algo::set_conv_override(algo)?;
    }
    if let Some(path) = args.get("conv-cache") {
        crate::tensor::conv_algo::set_cache_path(path);
    }
    if let Some(r) = args.get_usize_opt("replicas")? {
        anyhow::ensure!(r >= 1, "--replicas must be >= 1");
        crate::distributed::set_replicas(r);
    }
    if let Some(t) = args.get("transport") {
        crate::distributed::transport::set_kind(
            crate::distributed::transport::TransportKind::parse(t)?,
        );
    }
    {
        use crate::distributed::transport::supervisor;
        if let Some(s) = args.get("step-timeout") {
            let secs: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--step-timeout expects seconds, got `{s}`"))?;
            anyhow::ensure!(secs >= 0.0, "--step-timeout must be >= 0 (0 disables)");
            supervisor::set_step_timeout_secs(secs);
        }
        if let Some(s) = args.get("accept-timeout") {
            let secs: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--accept-timeout expects seconds, got `{s}`"))?;
            anyhow::ensure!(secs > 0.0, "--accept-timeout must be positive");
            supervisor::set_accept_timeout_secs(secs);
        }
        if let Some(s) = args.get("hello-timeout") {
            let secs: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--hello-timeout expects seconds, got `{s}`"))?;
            anyhow::ensure!(secs > 0.0, "--hello-timeout must be positive");
            supervisor::set_hello_timeout_secs(secs);
        }
        if let Some(ms) = args.get_usize_opt("heartbeat-ms")? {
            supervisor::set_heartbeat_ms(ms as u64);
        }
        if let Some(s) = args.get("straggler-z") {
            let z: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--straggler-z expects a number, got `{s}`"))?;
            anyhow::ensure!(
                z.is_finite() && z >= 0.0,
                "--straggler-z must be >= 0 (0 disables)"
            );
            supervisor::set_straggler_z(z);
        }
    }
    // The telemetry endpoint: flag > env. Worker subprocesses inherit
    // the coordinator's environment but must not bind their own
    // listener — their series travel to the coordinator over the wire
    // (Msg::Metrics) and surface on *its* endpoint.
    if !args.has("replica-worker") {
        let listen = args.get("metrics-listen").map(str::to_string).or_else(|| {
            std::env::var(crate::obs::http::METRICS_LISTEN_ENV)
                .ok()
                .filter(|s| !s.trim().is_empty())
        });
        if let Some(addr) = listen {
            let local = crate::obs::http::serve(addr.trim())?;
            // Port 0 resolves here; scrapers and the check.sh smoke
            // parse this line for the ephemeral port.
            println!("metrics endpoint listening on http://{local}/metrics");
        }
    }
    if let Some(path) = args.get("trace") {
        crate::obs::export::set_trace_path(path)?;
    } else if let Ok(path) = std::env::var("MOONWALK_TRACE") {
        if !path.trim().is_empty() {
            crate::obs::export::set_trace_path(path.trim())?;
        }
    }
    crate::runtime::pool::prewarm();
    Ok(())
}

/// Resolve the execution-planner byte budget: `--budget <bytes>` >
/// `MOONWALK_BUDGET` env var > `None` (unbounded). The flag accepts an
/// optional `kb`/`mb`/`gb` suffix (`--budget 64mb`); the env spelling is
/// plain bytes. A budget of zero is rejected — use no flag for
/// "unbounded".
pub fn budget_bytes(args: &Args) -> anyhow::Result<Option<usize>> {
    let parse = |v: &str| -> anyhow::Result<usize> {
        let v = v.trim().to_ascii_lowercase();
        let (digits, scale) = if let Some(d) = v.strip_suffix("gb") {
            (d.to_string(), 1usize << 30)
        } else if let Some(d) = v.strip_suffix("mb") {
            (d.to_string(), 1usize << 20)
        } else if let Some(d) = v.strip_suffix("kb") {
            (d.to_string(), 1usize << 10)
        } else {
            (v.clone(), 1usize)
        };
        let n: usize = digits
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--budget expects bytes (e.g. 1048576 or 64mb), got `{v}`"))?;
        anyhow::ensure!(n > 0, "--budget must be positive (omit it for unbounded)");
        n.checked_mul(scale)
            .ok_or_else(|| anyhow::anyhow!("--budget `{v}` overflows the byte range"))
    };
    if let Some(v) = args.get("budget") {
        return parse(v).map(Some);
    }
    if let Ok(v) = std::env::var("MOONWALK_BUDGET") {
        if !v.trim().is_empty() {
            return parse(&v).map(Some);
        }
    }
    Ok(None)
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    anyhow::bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process command line.
    pub fn from_env() -> anyhow::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    /// Optional integer flag: `None` when absent (so callers can
    /// distinguish "unset" from an explicit value — e.g. `--threads`).
    pub fn get_usize_opt(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config cfg.json --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --depth=8 --lr=0.01");
        assert_eq!(a.get_usize("depth", 0).unwrap(), 8);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("plan fig2 fig3");
        assert_eq!(a.positional, vec!["fig2", "fig3"]);
    }

    #[test]
    fn bad_numeric_rejected() {
        let a = parse("x --steps abc");
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn optional_usize() {
        let a = parse("bench --threads 4");
        assert_eq!(a.get_usize_opt("threads").unwrap(), Some(4));
        assert_eq!(a.get_usize_opt("depth").unwrap(), None);
        let bad = parse("bench --threads x");
        assert!(bad.get_usize_opt("threads").is_err());
    }

    #[test]
    fn replicas_flag_parses() {
        let a = parse("train --replicas 4");
        assert_eq!(a.get_usize_opt("replicas").unwrap(), Some(4));
        let bad = parse("train --replicas x");
        assert!(bad.get_usize_opt("replicas").is_err());
    }

    #[test]
    fn transport_flag_parses() {
        let a = parse("train --transport unix --replicas 2");
        assert_eq!(a.get("transport"), Some("unix"));
        // The worker mode's hidden flags parse as flag/switch mix.
        let w = parse("--replica-worker --connect /tmp/x.sock --replica 1");
        assert!(w.has("replica-worker"));
        assert_eq!(w.get("connect"), Some("/tmp/x.sock"));
        assert_eq!(w.get_usize("replica", 0).unwrap(), 1);
        assert_eq!(w.subcommand, None);
    }

    #[test]
    fn supervision_flags_validated() {
        // All three fail before any global knob is stored, so this test
        // cannot pollute the process-wide supervision state.
        assert!(configure_runtime(&parse("train --step-timeout abc")).is_err());
        assert!(configure_runtime(&parse("train --accept-timeout 0")).is_err());
        assert!(configure_runtime(&parse("train --heartbeat-ms x")).is_err());
    }

    #[test]
    fn conv_algo_flag_validated() {
        // Fails inside set_conv_override before any global state is
        // stored, so this test cannot pollute the process-wide override.
        assert!(configure_runtime(&parse("train --conv-algo fft")).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse("train --project");
        assert!(a.has("project"));
        assert_eq!(a.get("project"), None);
    }

    #[test]
    fn budget_flag_parses_with_suffixes() {
        assert_eq!(
            budget_bytes(&parse("train --budget 1048576")).unwrap(),
            Some(1 << 20)
        );
        assert_eq!(
            budget_bytes(&parse("train --budget 64mb")).unwrap(),
            Some(64 << 20)
        );
        assert_eq!(
            budget_bytes(&parse("train --budget 8kb")).unwrap(),
            Some(8 << 10)
        );
        assert_eq!(
            budget_bytes(&parse("train --budget 2gb")).unwrap(),
            Some(2 << 30)
        );
        assert!(budget_bytes(&parse("train --budget 0")).is_err());
        assert!(budget_bytes(&parse("train --budget lots")).is_err());
        // No flag (and no env var in this test's scope via flag
        // precedence): the flag path resolves first, env is only
        // consulted when the flag is absent.
        let a = parse("train --budget 10");
        assert_eq!(budget_bytes(&a).unwrap(), Some(10));
    }
}
