//! PJRT CPU client wrapper: compile-once, execute-many over the AOT
//! artifacts (pattern from the reference at /opt/xla-example/load_hlo).
//!
//! Marshalling: host [`Tensor`]s ⇄ `xla::Literal` (f32). AOT programs are
//! lowered with `return_tuple=True`, so every execution returns a tuple,
//! unpacked against the manifest's declared output shapes.

use std::collections::HashMap;

use crate::runtime::artifacts::{Manifest, OpSpec};
use crate::tensor::Tensor;

/// A compiled artifact set bound to a PJRT CPU client.
pub struct PjrtRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Load + compile every op in `dir/manifest.json`.
    pub fn load(dir: &std::path::Path) -> anyhow::Result<PjrtRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for (name, op) in &manifest.ops {
            let path = manifest.hlo_path(op);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                anyhow::anyhow!("parsing HLO for `{name}` from {path:?}: {e}")
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling `{name}`: {e}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(PjrtRuntime {
            manifest,
            client,
            exes,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn op_names(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }

    fn check_shapes(op: &OpSpec, inputs: &[&Tensor]) -> anyhow::Result<()> {
        if inputs.len() != op.inputs.len() {
            anyhow::bail!(
                "op `{}` expects {} inputs, got {}",
                op.name,
                op.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&op.inputs).enumerate() {
            if t.shape() != &spec[..] {
                anyhow::bail!(
                    "op `{}` input {i}: shape {:?} != manifest {:?}",
                    op.name,
                    t.shape(),
                    spec
                );
            }
        }
        Ok(())
    }

    /// Execute a compiled op on host tensors.
    pub fn execute(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let op = self.manifest.op(name)?;
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("op `{name}` not compiled"))?;
        Self::check_shapes(op, inputs)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data())
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("literal reshape: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing `{name}`: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching `{name}` result: {e}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling `{name}` result: {e}"))?;
        if parts.len() != op.outputs.len() {
            anyhow::bail!(
                "op `{name}` returned {} outputs, manifest says {}",
                parts.len(),
                op.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&op.outputs)
            .map(|(lit, shape)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("reading `{name}` output: {e}"))?;
                let expect: usize = shape.iter().product();
                if data.len() != expect {
                    anyhow::bail!(
                        "op `{name}` output has {} elements, manifest shape {:?}",
                        data.len(),
                        shape
                    );
                }
                Ok(Tensor::from_vec(data, shape))
            })
            .collect()
    }

    /// Execute an op with exactly one output.
    pub fn execute1(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Tensor> {
        let mut out = self.execute(name, inputs)?;
        if out.len() != 1 {
            anyhow::bail!("op `{name}` has {} outputs, expected 1", out.len());
        }
        Ok(out.pop().unwrap())
    }
}

// Compile/execute round-trip tests live in rust/tests/runtime_pjrt.rs
// (they need `make artifacts` to have produced the HLO files).
