//! Execution runtimes.
//!
//! * [`pool`] — the in-process **persistent** worker runtime that powers
//!   the parallel tensor kernels (row-blocked GEMM, batch- and
//!   spatial-parallel conv ops, Moonwalk phase loops). Workers spawn
//!   lazily, park between regions and receive per-region job
//!   descriptors, so even sub-100 µs kernels amortize dispatch. Std-only,
//!   deterministic partitioning, bit-identical to the PR 1 scoped pool
//!   at fixed thread counts.
//! * [`artifacts`] — manifest/loader for the AOT artifacts emitted by
//!   `python/compile/aot.py` (JAX/Pallas programs lowered to HLO text),
//!   plus the persisted conv-autotune table ([`TuneTable`]) behind
//!   `tensor::conv_algo`.
//! * `pjrt` — the PJRT client that compiles and executes those
//!   artifacts from the Rust hot path. Gated behind the `xla` feature
//!   because it needs the vendored `xla` crate, which not every build
//!   image carries; the default build is pure-std + anyhow/thiserror.

pub mod artifacts;
pub mod pool;

#[cfg(feature = "xla")]
pub mod pjrt;

pub use artifacts::{Manifest, OpSpec, TuneEntry, TuneTable};
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;
