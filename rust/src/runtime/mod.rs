//! Execution runtimes.
//!
//! * [`pool`] — the in-process scoped worker pool that powers the
//!   parallel tensor kernels (row-blocked GEMM, batch-parallel conv ops,
//!   Moonwalk phase loops). Std-only, deterministic partitioning.
//! * [`artifacts`] — manifest/loader for the AOT artifacts emitted by
//!   `python/compile/aot.py` (JAX/Pallas programs lowered to HLO text).
//! * [`pjrt`] — the PJRT client that compiles and executes those
//!   artifacts from the Rust hot path. Gated behind the `xla` feature
//!   because it needs the vendored `xla` crate, which not every build
//!   image carries; the default build is pure-std + anyhow/thiserror.

pub mod artifacts;
pub mod pool;

#[cfg(feature = "xla")]
pub mod pjrt;

pub use artifacts::{Manifest, OpSpec};
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;
