//! The PJRT runtime (L3 ⇄ L2/L1 bridge): loads the AOT artifacts emitted
//! by `python/compile/aot.py` (JAX/Pallas programs lowered to **HLO
//! text** — see DESIGN.md §3 for why text, not serialized protos),
//! compiles them once on the PJRT CPU client, and executes them from the
//! Rust hot path. After `make artifacts`, the binary is self-contained;
//! Python never runs at training/serving time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{Manifest, OpSpec};
pub use pjrt::PjrtRuntime;
