//! Scoped worker pool for data-parallel tensor kernels (§Perf iteration 5).
//!
//! Design constraints, in order:
//!
//! 1. **Determinism.** For a fixed thread count, every parallel kernel
//!    must produce bit-identical results across runs. Work is therefore
//!    split into *contiguous, deterministic* chunks ([`chunk_ranges`]) —
//!    never work-stolen — and reductions fold per-worker partials in
//!    worker order ([`run_reduce`]).
//! 2. **Safety.** No `unsafe`, no lifetime erasure: workers are spawned
//!    with [`std::thread::scope`], so they may borrow the caller's
//!    tensors directly and are joined before the kernel returns. Spawn
//!    cost (~tens of µs) is negligible against the multi-ms conv/GEMM
//!    kernels this pool exists for; tiny kernels stay serial via the
//!    shape heuristics in `tensor::ops`.
//! 3. **No oversubscription.** A kernel running *inside* a worker (e.g.
//!    a per-tap GEMM inside a batch-parallel convolution) sees
//!    [`effective_threads`]` == 1` and runs serially.
//!
//! Thread count resolution: explicit [`set_threads`] (the CLI's
//! `--threads`) > `MOONWALK_THREADS` env var > available parallelism.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Global thread budget; 0 = not yet resolved.
static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Set inside pool workers so nested kernels stay serial.
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("MOONWALK_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The configured worker count (resolving lazily on first use).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let t = resolve_default();
    THREADS.store(t, Ordering::Relaxed);
    t
}

/// Set the worker count explicitly (CLI `--threads`). Clamped to ≥ 1.
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Is the current thread a pool worker?
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// How many workers a kernel with `n_tasks` independent tasks should use:
/// `min(threads(), n_tasks)`, or 1 when already inside a worker (nested
/// parallelism would oversubscribe) or when there is nothing to split.
pub fn effective_threads(n_tasks: usize) -> usize {
    if n_tasks <= 1 || in_worker() {
        1
    } else {
        threads().min(n_tasks)
    }
}

/// Deterministic contiguous partition of `0..n` into at most `parts`
/// non-empty ranges; the first `n % parts` ranges get one extra item.
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    if n == 0 {
        return vec![0..0];
    }
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(record_range, records_slice)` over disjoint contiguous chunks of
/// `data`, which holds `data.len() / record_len` records of `record_len`
/// f32s each. `workers` is the requested parallelism (callers usually pass
/// [`effective_threads`]); it is clamped by the record count and forced to
/// 1 inside a worker. With one worker, `f` runs on the calling thread —
/// the serial path is the same code.
pub fn run_records<F>(data: &mut [f32], record_len: usize, workers: usize, f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert!(record_len > 0, "record_len must be positive");
    assert_eq!(
        data.len() % record_len,
        0,
        "data length {} not a multiple of record length {}",
        data.len(),
        record_len
    );
    let n_records = data.len() / record_len;
    let t = if in_worker() {
        1
    } else {
        workers.clamp(1, n_records.max(1))
    };
    if t <= 1 {
        f(0..n_records, data);
        return;
    }
    let ranges = chunk_ranges(n_records, t);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = data;
        for r in ranges {
            let take = r.len() * record_len;
            let tmp = rest;
            let (mine, tail) = tmp.split_at_mut(take);
            rest = tail;
            s.spawn(move || {
                IN_WORKER.with(|w| w.set(true));
                f(r, mine);
            });
        }
    });
}

/// Deterministic parallel map-reduce over `0..n_tasks`: each worker folds
/// its contiguous task range into a fresh accumulator (`init` + `work`),
/// and the per-worker accumulators are merged **in worker order** — so a
/// fixed thread count always reduces in the same order (bit-stable).
pub fn run_reduce<A, I, W, M>(n_tasks: usize, workers: usize, init: I, work: W, mut merge: M) -> A
where
    A: Send,
    I: Fn() -> A + Sync,
    W: Fn(Range<usize>, &mut A) + Sync,
    M: FnMut(&mut A, A),
{
    let t = if in_worker() {
        1
    } else {
        workers.clamp(1, n_tasks.max(1))
    };
    if t <= 1 || n_tasks == 0 {
        let mut acc = init();
        if n_tasks > 0 {
            work(0..n_tasks, &mut acc);
        }
        return acc;
    }
    let ranges = chunk_ranges(n_tasks, t);
    let mut partials: Vec<A> = Vec::with_capacity(t);
    std::thread::scope(|s| {
        let init = &init;
        let work = &work;
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                s.spawn(move || {
                    IN_WORKER.with(|w| w.set(true));
                    let mut acc = init();
                    work(r, &mut acc);
                    acc
                })
            })
            .collect();
        for h in handles {
            partials.push(h.join().expect("pool worker panicked"));
        }
    });
    let mut iter = partials.into_iter();
    let mut acc = iter.next().expect("at least one worker");
    for p in iter {
        merge(&mut acc, p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 7, 8, 9, 100] {
            for parts in [1usize, 2, 3, 4, 9] {
                let ranges = chunk_ranges(n, parts);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "contiguous");
                    next = r.end;
                }
                assert_eq!(next, n, "covers 0..{n}");
                if n > 0 {
                    assert!(ranges.len() <= parts.max(1));
                    assert!(ranges.iter().all(|r| !r.is_empty()));
                    // balanced: sizes differ by at most 1
                    let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                    let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(mx - mn <= 1);
                }
            }
        }
    }

    #[test]
    fn run_records_writes_every_record() {
        let mut data = vec![0f32; 7 * 3];
        run_records(&mut data, 3, 4, |records, chunk| {
            for (local, rec) in records.enumerate() {
                for j in 0..3 {
                    chunk[local * 3 + j] = (rec * 10 + j) as f32;
                }
            }
        });
        for rec in 0..7 {
            for j in 0..3 {
                assert_eq!(data[rec * 3 + j], (rec * 10 + j) as f32);
            }
        }
    }

    #[test]
    fn run_records_serial_matches_parallel() {
        let fill = |workers: usize| {
            let mut data = vec![0f32; 13 * 5];
            run_records(&mut data, 5, workers, |records, chunk| {
                for (local, rec) in records.enumerate() {
                    for j in 0..5 {
                        chunk[local * 5 + j] = (rec * j) as f32 * 0.5;
                    }
                }
            });
            data
        };
        assert_eq!(fill(1), fill(4));
    }

    #[test]
    fn run_reduce_deterministic_sum() {
        let sum = |workers: usize| {
            run_reduce(
                1000,
                workers,
                || 0f64,
                |r, acc| {
                    for i in r {
                        *acc += (i as f64).sqrt();
                    }
                },
                |a, b| *a += b,
            )
        };
        // Same worker count twice => bit-identical.
        assert_eq!(sum(4).to_bits(), sum(4).to_bits());
        // Different worker counts agree to fp tolerance.
        assert!((sum(1) - sum(3)).abs() < 1e-6 * sum(1).abs());
    }

    #[test]
    fn nested_parallelism_is_serialized() {
        let mut outer = vec![0f32; 4];
        run_records(&mut outer, 1, 4, |_, chunk| {
            // Inside a worker the pool must refuse to fan out again.
            assert!(in_worker());
            assert_eq!(effective_threads(64), 1);
            let mut inner = vec![0f32; 8];
            run_records(&mut inner, 1, 4, |r, c| {
                assert_eq!(r, 0..8, "nested call runs as one serial chunk");
                c.fill(1.0);
            });
            chunk[0] = inner.iter().sum();
        });
        assert_eq!(outer, vec![8.0; 4]);
    }

    #[test]
    fn threads_configurable() {
        // Note: global state; keep assertions order-independent.
        let before = threads();
        set_threads(3);
        assert_eq!(threads(), 3);
        assert_eq!(effective_threads(2), 2);
        assert_eq!(effective_threads(100), 3);
        set_threads(before);
    }
}
