//! Persistent worker-thread runtime for data-parallel tensor kernels
//! (§Perf iterations 5–6).
//!
//! PR 1 shipped a *scoped* pool: every parallel region spawned fresh
//! `std::thread::scope` workers. That is fine at multi-ms conv/GEMM
//! sizes but wasteful below ~100 µs — exactly the regime of Moonwalk's
//! small per-layer vijp and fragment kernels. This revision keeps a
//! **persistent team**: worker threads are spawned lazily on first use,
//! park between regions (blocked on their job channel), and receive work
//! through a per-region job descriptor. Dispatching a region is a
//! channel send + condvar round-trip per worker instead of a thread
//! spawn + join.
//!
//! Design constraints, in order (unchanged from PR 1 — the persistent
//! pool must be a drop-in contract-preserving replacement):
//!
//! 1. **Determinism.** For a fixed thread count, every parallel kernel
//!    produces bit-identical results across runs *and* bit-identical
//!    results to the PR 1 scoped pool: work is split into *contiguous,
//!    deterministic* chunks ([`chunk_ranges`]) — never work-stolen — and
//!    reductions fold per-share partials in share order ([`run_reduce`]).
//!    Which OS thread executes a share never affects the values written.
//! 2. **Safety.** The single `unsafe` surface is the lifetime erasure in
//!    `run_region`, which is sound because the submitting thread always
//!    blocks on the region latch before returning (workers can never
//!    observe the caller's borrows after the region ends — even when a
//!    share panics). Everything above it (slice partitioning, partial
//!    hand-off) uses safe `split_at_mut` walks and per-share `Mutex`
//!    cells.
//! 3. **No oversubscription.** A kernel running *inside* a worker (e.g.
//!    a per-tap GEMM inside a batch-parallel convolution) sees
//!    [`effective_threads`]` == 1` and runs serially. The calling thread
//!    executes share 0 of its own region *as* a worker (nested regions
//!    stay serial there too, exactly as under the scoped pool where every
//!    share ran on a spawned thread).
//! 4. **Resilience.** A panicking share is caught on the worker, the
//!    region latch still completes, the panic is re-raised on the
//!    submitting thread, and the team keeps running — later regions are
//!    unaffected (`tests/pool_stress.rs` proves it).
//!
//! Thread count resolution: explicit [`set_threads`] (the CLI's
//! `--threads`) > `MOONWALK_THREADS` env var > available parallelism.
//! [`set_threads`] may be called between regions at any time; shrinking
//! leaves surplus workers parked, growing spawns on demand (or eagerly
//! via [`prewarm`]). Lifecycle counters ([`stats`]) expose region /
//! wake / park counts for the trainer's JSONL metrics.

#![deny(missing_docs)]

use std::any::Any;
use std::cell::Cell;
use std::ops::Range;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex};

use crate::util::lock_ignore_poison as lock;

/// Global thread budget; 0 = not yet resolved.
static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Set inside pool workers (and on the caller while it runs its own
    /// share) so nested kernels stay serial.
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

// ----- lifecycle metrics ----------------------------------------------------

/// Parallel regions dispatched (regions that actually woke workers).
static REGIONS: AtomicUsize = AtomicUsize::new(0);
/// Jobs handed to parked workers (one per non-caller share).
static WAKES: AtomicUsize = AtomicUsize::new(0);
/// Jobs completed — the worker returned to its parked state.
static PARKS: AtomicUsize = AtomicUsize::new(0);
/// Worker threads spawned over the process lifetime.
static SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// Snapshot of the pool's lifecycle counters (monotone; log deltas).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Parallel regions dispatched (a region with `t` shares wakes
    /// `t - 1` workers; the caller runs share 0 itself).
    pub regions: usize,
    /// Worker wake-ups (jobs sent to parked workers).
    pub wakes: usize,
    /// Worker parks (jobs completed; the worker re-blocked on its
    /// channel). Absent worker failures — the overwhelmingly common
    /// case — `parks == wakes` after every region returns; a job lost
    /// to a dying worker counts as a wake but not a park.
    pub parks: usize,
    /// Worker threads spawned so far (they persist once spawned).
    pub workers_spawned: usize,
}

/// Current lifecycle counters. The mean region fan-out since process
/// start is `wakes / regions + 1`.
pub fn stats() -> PoolStats {
    PoolStats {
        regions: REGIONS.load(Ordering::Relaxed),
        wakes: WAKES.load(Ordering::Relaxed),
        parks: PARKS.load(Ordering::Relaxed),
        workers_spawned: SPAWNED.load(Ordering::Relaxed),
    }
}

// ----- thread-count resolution ----------------------------------------------

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("MOONWALK_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The configured worker count (resolving lazily on first use).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let t = resolve_default();
    THREADS.store(t, Ordering::Relaxed);
    t
}

/// Set the worker count explicitly (CLI `--threads`). Clamped to ≥ 1.
/// Resizing between regions is cheap: shrinking leaves surplus workers
/// parked on their channels; growing spawns lazily at the next region
/// (or eagerly via [`prewarm`]).
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Pin the pool to `t` workers for the duration of `f`, restoring the
/// previous setting afterwards even on panic. Test/bench helper — the
/// thread count is process-global, so callers comparing counts should
/// serialize (e.g. through a file-local mutex in test binaries).
pub fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    struct Guard(usize);
    impl Drop for Guard {
        fn drop(&mut self) {
            set_threads(self.0);
        }
    }
    let _guard = Guard(threads());
    set_threads(t);
    f()
}

/// Is the current thread a pool worker (or a caller inside its own
/// region share)?
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// How many workers a kernel with `n_tasks` independent tasks should use:
/// `min(threads(), n_tasks)`, or 1 when already inside a worker (nested
/// parallelism would oversubscribe) or when there is nothing to split.
pub fn effective_threads(n_tasks: usize) -> usize {
    if n_tasks <= 1 || in_worker() {
        1
    } else {
        threads().min(n_tasks)
    }
}

/// Deterministic contiguous partition of `0..n` into at most `parts`
/// non-empty ranges; the first `n % parts` ranges get one extra item.
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    if n == 0 {
        return vec![0..0];
    }
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

// ----- the persistent team --------------------------------------------------

/// Countdown latch a region waits on: workers decrement, the submitter
/// blocks until zero. The decrement and the wake happen under one lock
/// acquisition so the submitter cannot observe zero — and free the
/// stack-allocated latch — while a worker still holds the condvar.
/// The first panicking share parks its payload here so the submitter
/// can re-raise the *original* panic (matching the scoped pool, where
/// `thread::scope` propagated it), not a generic message.
struct Latch {
    remaining: Mutex<usize>,
    all_done: Condvar,
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: Mutex::new(n),
            all_done: Condvar::new(),
            panic_payload: Mutex::new(None),
        }
    }

    /// Mark one share complete. After the final call the latch may be
    /// freed by the waiting submitter at any moment — no access after.
    fn complete_one(&self) {
        let mut left = lock(&self.remaining);
        *left -= 1;
        if *left == 0 {
            self.all_done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = lock(&self.remaining);
        while *left > 0 {
            left = match self.all_done.wait(left) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

/// Per-region job descriptor handed to a parked worker: which share of
/// the region's closure to run, and the latch to report back to. The
/// `'static` lifetimes are a fiction maintained by [`run_region`], which
/// never returns before every job settled.
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    latch: &'static Latch,
    share: usize,
    /// Set by the worker once the task was actually invoked. A job
    /// dropped with `ran == false` never executed (its worker died with
    /// the job queued, or dispatch failed) — its Drop records a failure
    /// so the submitter panics instead of silently missing a share.
    ran: bool,
}

/// Settling the latch lives in `Drop`, so it happens on **every** exit
/// path: normal completion, a panic payload whose own `Drop` panics and
/// unwinds past the worker's catch, and jobs still queued on a dying
/// worker's channel (the `Receiver` drop drops them). A latch that never
/// settles would deadlock its submitter forever — `run_region` must
/// block for soundness.
impl Drop for Job {
    fn drop(&mut self) {
        if self.ran {
            // Only an executed job is a genuine wake→park round trip; a
            // job dropped undispatched (or on a dying worker's channel)
            // must not inflate the park count.
            PARKS.fetch_add(1, Ordering::Relaxed);
        } else {
            let mut slot = lock(&self.latch.panic_payload);
            if slot.is_none() {
                *slot = Some(Box::new(
                    "pool worker died before running this region share",
                ));
            }
        }
        // Last touch: after complete_one the submitter may free the latch.
        self.latch.complete_one();
    }
}

/// The team: one channel sender per spawned worker. Workers are spawned
/// lazily, never exit, and park on `Receiver::recv` between jobs.
static TEAM: Mutex<Vec<Sender<Job>>> = Mutex::new(Vec::new());

fn worker_loop(rx: Receiver<Job>) {
    IN_WORKER.with(|w| w.set(true));
    while let Ok(mut job) = rx.recv() {
        let _ss = crate::span!("pool.share", share = job.share);
        // Catch panics so one bad share cannot take the worker (and every
        // later region scheduled on it) down; the submitter re-raises the
        // first payload. The latch itself settles in `Job::drop`.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| (job.task)(job.share)));
        job.ran = true;
        if let Err(payload) = result {
            let mut slot = lock(&job.latch.panic_payload);
            if slot.is_none() {
                *slot = Some(payload);
            }
            // A discarded payload (a later panic of the same region)
            // drops here; if its own Drop panics, the unwind still
            // settles the latch via Job::drop below.
        }
        drop(job);
        crate::obs::span::instant("pool.park", None);
    }
    // All senders dropped — only happens at process teardown.
}

fn try_spawn_worker(idx: usize) -> Option<Sender<Job>> {
    let (tx, rx) = channel::<Job>();
    let spawned = std::thread::Builder::new()
        .name(format!("moonwalk-pool-{idx}"))
        .spawn(move || worker_loop(rx));
    match spawned {
        Ok(_) => {
            SPAWNED.fetch_add(1, Ordering::Relaxed);
            Some(tx)
        }
        Err(_) => None,
    }
}

/// Grow the team to `needed` workers. Called *before* any job of the
/// current region is in flight, so panicking on spawn failure here is
/// safe (no worker holds borrows into the caller's frame yet).
fn ensure_workers(team: &mut Vec<Sender<Job>>, needed: usize) {
    while team.len() < needed {
        let idx = team.len();
        let tx = try_spawn_worker(idx).expect("failed to spawn pool worker");
        team.push(tx);
    }
}

/// Eagerly spawn the team for the current [`threads`] setting so the
/// first parallel region doesn't pay spawn latency (the CLI calls this
/// from `configure_runtime`). Purely an optimization — the team also
/// grows lazily.
pub fn prewarm() {
    let t = threads();
    if t > 1 {
        let mut team = lock(&TEAM);
        ensure_workers(&mut team, t - 1);
    }
}

/// Execute `f(share)` for every `share in 0..parts`: shares `1..parts`
/// on persistent workers, share 0 on the calling thread (marked as a
/// worker for the duration, so nested regions stay serial). Returns only
/// after **all** shares finished — also on panic, so `f` may freely
/// borrow the caller's stack. Panics (caller's share first, then any
/// worker share) are re-raised here after the region settles.
fn run_region(parts: usize, f: &(dyn Fn(usize) + Sync)) {
    if parts <= 1 || in_worker() {
        // Degenerate or nested: run every share inline, in order.
        for share in 0..parts.max(1) {
            f(share);
        }
        return;
    }
    let extra = parts - 1;
    let _sp = crate::span!("pool.region", shares = parts);
    let latch = Latch::new(extra);
    // SAFETY: the only lifetime erasure in the runtime. `task` and
    // `latch_ref` point into this stack frame; workers use them only
    // while their job runs, every job completes (panics are caught)
    // before `latch.wait()` returns, and this function never returns —
    // or unwinds — before `latch.wait()` completes. Hence no worker can
    // dereference either pointer after this frame dies.
    let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
    let latch_ref: &'static Latch = unsafe { std::mem::transmute(&latch) };
    {
        let mut team = lock(&TEAM);
        // Grow first: a spawn panic here happens before any job is in
        // flight, so unwinding is safe.
        ensure_workers(&mut team, extra);
        for i in 0..extra {
            let job = Job {
                task,
                latch: latch_ref,
                share: i + 1,
                ran: false,
            };
            match team[i].send(job) {
                Ok(()) => {
                    WAKES.fetch_add(1, Ordering::Relaxed);
                }
                Err(returned) => {
                    // The worker died (a panic escaped the catch, e.g. a
                    // panicking panic payload). Replace it and
                    // re-dispatch — the team self-heals. If even the
                    // respawn fails (thread exhaustion), dropping the
                    // job settles the latch with a never-ran failure and
                    // the region panics cleanly below.
                    if let Some(tx) = try_spawn_worker(i) {
                        team[i] = tx;
                        if team[i].send(returned.0).is_ok() {
                            WAKES.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
    }
    REGIONS.fetch_add(1, Ordering::Relaxed);
    crate::obs::span::instant("pool.wake", Some(("workers", extra as i64)));
    // The caller runs share 0 as a worker: nested kernels must stay
    // serial exactly as under the scoped pool, where every share ran on
    // a spawned thread.
    let prev = IN_WORKER.with(|w| w.replace(true));
    let mine = std::panic::catch_unwind(AssertUnwindSafe(|| f(0)));
    IN_WORKER.with(|w| w.set(prev));
    // Always settle the region before unwinding: workers still hold
    // borrows into this frame until the latch completes (Job::drop
    // guarantees it completes on every path).
    latch.wait();
    let share_payload = lock(&latch.panic_payload).take();
    match mine {
        // The caller's own share panicking takes precedence; otherwise
        // re-raise the first worker share's original payload.
        Err(payload) => std::panic::resume_unwind(payload),
        Ok(()) => {
            if let Some(p) = share_payload {
                std::panic::resume_unwind(p);
            }
        }
    }
}

// ----- safe data-parallel entry points --------------------------------------

/// Run `f(span_index, sub_slice)` over caller-specified sub-slices of
/// `data`. `spans` must be ascending and non-overlapping (gaps are fine
/// and stay untouched); this is checked. Spans are grouped into at most
/// `workers` contiguous share groups via [`chunk_ranges`] — a share
/// processes its spans in ascending span order, so the serial
/// (`workers == 1`) execution order is the same code path. Used by
/// kernels whose natural parallel unit is irregular (e.g. conv1d
/// fragment blocks of unequal tail size).
pub fn run_spans<T, F>(data: &mut [T], spans: &[Range<usize>], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if spans.is_empty() {
        return;
    }
    let mut prev_end = 0usize;
    for (i, s) in spans.iter().enumerate() {
        assert!(
            s.start >= prev_end && s.end >= s.start,
            "span {i} ({s:?}) is unsorted or overlaps its predecessor"
        );
        prev_end = s.end;
    }
    assert!(
        prev_end <= data.len(),
        "spans end at {prev_end} but data has {} elements",
        data.len()
    );
    let t = if in_worker() {
        1
    } else {
        workers.clamp(1, spans.len())
    };
    if t <= 1 {
        // Serial: carve and call in one pass (same split walk as below).
        let mut rest = data;
        let mut consumed = 0usize;
        for (i, s) in spans.iter().enumerate() {
            let tmp = rest;
            let (_gap, tmp) = tmp.split_at_mut(s.start - consumed);
            let (mine, tail) = tmp.split_at_mut(s.end - s.start);
            f(i, mine);
            rest = tail;
            consumed = s.end;
        }
        return;
    }
    // Carve every span out of `data` with a safe sequential split walk.
    let mut slices: Vec<(usize, &mut [T])> = Vec::with_capacity(spans.len());
    {
        let mut rest = data;
        let mut consumed = 0usize;
        for (i, s) in spans.iter().enumerate() {
            let tmp = rest;
            let (_gap, tmp) = tmp.split_at_mut(s.start - consumed);
            let (mine, tail) = tmp.split_at_mut(s.end - s.start);
            slices.push((i, mine));
            rest = tail;
            consumed = s.end;
        }
    }
    // Hand each share its own span group through a Mutex cell (locked
    // exactly once, uncontended — shares touch only their own cell).
    let groups = chunk_ranges(spans.len(), t);
    let mut iter = slices.into_iter();
    let shares: Vec<Mutex<Vec<(usize, &mut [T])>>> = groups
        .iter()
        .map(|g| Mutex::new(iter.by_ref().take(g.len()).collect()))
        .collect();
    run_region(shares.len(), &|share| {
        let mut mine = lock(&shares[share]);
        for (idx, slice) in mine.iter_mut() {
            f(*idx, &mut **slice);
        }
    });
}

/// Run `f(record_range, records_slice)` over disjoint contiguous chunks of
/// `data`, which holds `data.len() / record_len` records of `record_len`
/// f32s each. `workers` is the requested parallelism (callers usually pass
/// [`effective_threads`]); it is clamped by the record count and forced to
/// 1 inside a worker. With one worker, `f` runs on the calling thread —
/// the serial path is the same code.
pub fn run_records<F>(data: &mut [f32], record_len: usize, workers: usize, f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert!(record_len > 0, "record_len must be positive");
    assert_eq!(
        data.len() % record_len,
        0,
        "data length {} not a multiple of record length {}",
        data.len(),
        record_len
    );
    let n_records = data.len() / record_len;
    let t = if in_worker() {
        1
    } else {
        workers.clamp(1, n_records.max(1))
    };
    if t <= 1 {
        f(0..n_records, data);
        return;
    }
    let ranges = chunk_ranges(n_records, t);
    let spans: Vec<Range<usize>> = ranges
        .iter()
        .map(|r| r.start * record_len..r.end * record_len)
        .collect();
    // One span per share, so the grouping inside run_spans is 1:1 and the
    // partitioning is exactly the scoped pool's.
    run_spans(data, &spans, t, |i, chunk| f(ranges[i].clone(), chunk));
}

/// Deterministic parallel map-reduce over `0..n_tasks`: each share folds
/// its contiguous task range into a fresh accumulator (`init` + `work`),
/// and the per-share accumulators are merged **in share order** — so a
/// fixed thread count always reduces in the same order (bit-stable, and
/// bit-identical to the PR 1 scoped pool's worker-ordered merge).
///
/// ```
/// use moonwalk::runtime::pool;
///
/// // Sum 0..100 across up to 4 workers; the share-ordered merge makes
/// // the result identical to the serial fold.
/// let sum = pool::run_reduce(
///     100,
///     4,
///     || 0u64,
///     |range, acc| {
///         for i in range {
///             *acc += i as u64;
///         }
///     },
///     |a, b| *a += b,
/// );
/// assert_eq!(sum, 4950);
/// ```
pub fn run_reduce<A, I, W, M>(n_tasks: usize, workers: usize, init: I, work: W, mut merge: M) -> A
where
    A: Send,
    I: Fn() -> A + Sync,
    W: Fn(Range<usize>, &mut A) + Sync,
    M: FnMut(&mut A, A),
{
    let t = if in_worker() {
        1
    } else {
        workers.clamp(1, n_tasks.max(1))
    };
    if t <= 1 || n_tasks == 0 {
        let mut acc = init();
        if n_tasks > 0 {
            work(0..n_tasks, &mut acc);
        }
        return acc;
    }
    let ranges = chunk_ranges(n_tasks, t);
    // Per-share result cells; each share writes only its own slot.
    let slots: Vec<Mutex<Option<A>>> = (0..t).map(|_| Mutex::new(None)).collect();
    run_region(t, &|share| {
        let mut acc = init();
        work(ranges[share].clone(), &mut acc);
        *lock(&slots[share]) = Some(acc);
    });
    // A panicking share propagates out of run_region, so every slot is
    // populated here. Merge in share (= task range) order.
    let mut iter = slots.into_iter().map(|s| {
        match s.into_inner() {
            Ok(v) => v,
            Err(poisoned) => poisoned.into_inner(),
        }
        .expect("pool share completed without a result")
    });
    let mut acc = iter.next().expect("at least one share");
    for p in iter {
        merge(&mut acc, p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 7, 8, 9, 100] {
            for parts in [1usize, 2, 3, 4, 9] {
                let ranges = chunk_ranges(n, parts);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "contiguous");
                    next = r.end;
                }
                assert_eq!(next, n, "covers 0..{n}");
                if n > 0 {
                    assert!(ranges.len() <= parts.max(1));
                    assert!(ranges.iter().all(|r| !r.is_empty()));
                    // balanced: sizes differ by at most 1
                    let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                    let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(mx - mn <= 1);
                }
            }
        }
    }

    #[test]
    fn run_records_writes_every_record() {
        let mut data = vec![0f32; 7 * 3];
        run_records(&mut data, 3, 4, |records, chunk| {
            for (local, rec) in records.enumerate() {
                for j in 0..3 {
                    chunk[local * 3 + j] = (rec * 10 + j) as f32;
                }
            }
        });
        for rec in 0..7 {
            for j in 0..3 {
                assert_eq!(data[rec * 3 + j], (rec * 10 + j) as f32);
            }
        }
    }

    #[test]
    fn run_records_serial_matches_parallel() {
        let fill = |workers: usize| {
            let mut data = vec![0f32; 13 * 5];
            run_records(&mut data, 5, workers, |records, chunk| {
                for (local, rec) in records.enumerate() {
                    for j in 0..5 {
                        chunk[local * 5 + j] = (rec * j) as f32 * 0.5;
                    }
                }
            });
            data
        };
        assert_eq!(fill(1), fill(4));
    }

    #[test]
    fn run_spans_respects_gaps() {
        // Spans with holes: untouched elements keep their sentinel.
        let mut data = vec![-1f32; 12];
        let spans = vec![1usize..3, 5..6, 8..12];
        run_spans(&mut data, &spans, 3, |idx, chunk| {
            for (o, c) in chunk.iter_mut().enumerate() {
                *c = (idx * 100 + o) as f32;
            }
        });
        let expect = vec![
            -1.0, 0.0, 1.0, -1.0, -1.0, 100.0, -1.0, -1.0, 200.0, 201.0, 202.0, 203.0,
        ];
        assert_eq!(data, expect);
        // Serial run is bit-identical.
        let mut serial = vec![-1f32; 12];
        run_spans(&mut serial, &spans, 1, |idx, chunk| {
            for (o, c) in chunk.iter_mut().enumerate() {
                *c = (idx * 100 + o) as f32;
            }
        });
        assert_eq!(serial, data);
    }

    #[test]
    fn run_reduce_deterministic_sum() {
        let sum = |workers: usize| {
            run_reduce(
                1000,
                workers,
                || 0f64,
                |r, acc| {
                    for i in r {
                        *acc += (i as f64).sqrt();
                    }
                },
                |a, b| *a += b,
            )
        };
        // Same worker count twice => bit-identical.
        assert_eq!(sum(4).to_bits(), sum(4).to_bits());
        // Different worker counts agree to fp tolerance.
        assert!((sum(1) - sum(3)).abs() < 1e-6 * sum(1).abs());
    }

    #[test]
    fn nested_parallelism_is_serialized() {
        let mut outer = vec![0f32; 4];
        run_records(&mut outer, 1, 4, |_, chunk| {
            // Inside a share the pool must refuse to fan out again.
            assert!(in_worker());
            assert_eq!(effective_threads(64), 1);
            let mut inner = vec![0f32; 8];
            run_records(&mut inner, 1, 4, |r, c| {
                assert_eq!(r, 0..8, "nested call runs as one serial chunk");
                c.fill(1.0);
            });
            chunk[0] = inner.iter().sum();
        });
        assert_eq!(outer, vec![8.0; 4]);
    }

    #[test]
    fn caller_is_not_marked_worker_between_regions() {
        let mut data = vec![0f32; 4];
        run_records(&mut data, 1, 4, |_, c| c.fill(1.0));
        assert!(!in_worker(), "IN_WORKER must be restored after a region");
    }

    #[test]
    fn worker_panic_propagates_and_team_recovers() {
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut data = vec![0f32; 8];
            run_records(&mut data, 1, 4, |records, _chunk| {
                if records.start >= 4 {
                    panic!("injected share panic");
                }
            });
        }));
        assert!(boom.is_err(), "worker panic must reach the caller");
        // The team must still serve later regions, with correct results.
        let mut data = vec![0f32; 16];
        run_records(&mut data, 1, 4, |records, chunk| {
            for (l, r) in records.enumerate() {
                chunk[l] = r as f32;
            }
        });
        let expect: Vec<f32> = (0..16).map(|r| r as f32).collect();
        assert_eq!(data, expect);
    }

    #[test]
    fn stats_count_regions_and_round_trips() {
        // Unit tests share the process, so only check monotone growth of
        // our own deltas (other tests may run concurrently).
        let before = stats();
        let mut data = vec![0f32; 64];
        run_records(&mut data, 1, 4, |records, chunk| {
            for (l, r) in records.enumerate() {
                chunk[l] = r as f32;
            }
        });
        let after = stats();
        assert!(after.regions > before.regions, "region counted");
        assert!(after.wakes >= before.wakes + 3, "3 workers woken");
        assert!(after.workers_spawned >= 3, "team spawned");
    }

    #[test]
    fn threads_configurable() {
        // Note: global state; keep assertions order-independent.
        let before = threads();
        set_threads(3);
        assert_eq!(threads(), 3);
        assert_eq!(effective_threads(2), 2);
        assert_eq!(effective_threads(100), 3);
        set_threads(before);
    }

    #[test]
    fn with_threads_restores_on_panic() {
        let before = threads();
        let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_threads(before + 5, || panic!("boom"));
        }));
        assert_eq!(threads(), before);
    }
}
