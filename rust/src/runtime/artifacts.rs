//! Artifact manifest: `artifacts/manifest.json` describes every compiled
//! op — its HLO file, input/output shapes and role — plus the flagship
//! model configuration the artifacts were lowered for. Produced by
//! `python/compile/aot.py`; consumed by `runtime::pjrt::PjrtRuntime` (behind the `xla` feature).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT-compiled operation.
#[derive(Clone, Debug)]
pub struct OpSpec {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input shapes (f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (f32); multiple outputs arrive as a tuple.
    pub outputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub ops: BTreeMap<String, OpSpec>,
    /// Flagship model config (opaque JSON the e2e driver interprets).
    pub config: Json,
}

fn parse_shapes(j: &Json, what: &str) -> anyhow::Result<Vec<Vec<usize>>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow::anyhow!("{what}: shape must be an array"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("{what}: bad dim"))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let mut ops = BTreeMap::new();
        for op in j
            .get("ops")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing `ops` array"))?
        {
            let name = op.req_str("name")?.to_string();
            let spec = OpSpec {
                name: name.clone(),
                file: op.req_str("file")?.to_string(),
                inputs: parse_shapes(op.get("inputs"), &format!("op {name} inputs"))?,
                outputs: parse_shapes(op.get("outputs"), &format!("op {name} outputs"))?,
            };
            if ops.insert(name.clone(), spec).is_some() {
                anyhow::bail!("duplicate op `{name}` in manifest");
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            ops,
            config: j.get("config").clone(),
        })
    }

    pub fn op(&self, name: &str) -> anyhow::Result<&OpSpec> {
        self.ops
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact op `{name}` not in manifest"))
    }

    pub fn hlo_path(&self, op: &OpSpec) -> PathBuf {
        self.dir.join(&op.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parse_minimal() {
        let dir = std::env::temp_dir().join("moonwalk_manifest_test");
        write_manifest(
            &dir,
            r#"{"config": {"depth": 2},
                "ops": [{"name": "f", "file": "f.hlo.txt",
                          "inputs": [[2,2],[2,2]], "outputs": [[2,2]]}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.ops.len(), 1);
        let op = m.op("f").unwrap();
        assert_eq!(op.inputs.len(), 2);
        assert_eq!(op.outputs[0], vec![2, 2]);
        assert_eq!(m.config.req_usize("depth").unwrap(), 2);
        assert!(m.op("g").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = std::env::temp_dir().join("moonwalk_manifest_missing");
        std::fs::remove_dir_all(&dir).ok();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn duplicate_ops_rejected() {
        let dir = std::env::temp_dir().join("moonwalk_manifest_dup");
        write_manifest(
            &dir,
            r#"{"ops": [
                {"name": "f", "file": "a", "inputs": [], "outputs": []},
                {"name": "f", "file": "b", "inputs": [], "outputs": []}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
