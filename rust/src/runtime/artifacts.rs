//! Persisted runtime artifacts.
//!
//! * [`Manifest`] — `artifacts/manifest.json` describes every compiled
//!   op — its HLO file, input/output shapes and role — plus the flagship
//!   model configuration the artifacts were lowered for. Produced by
//!   `python/compile/aot.py`; consumed by `runtime::pjrt::PjrtRuntime`
//!   (behind the `xla` feature).
//! * [`TuneTable`] — the conv-algorithm autotune cache
//!   (`tensor::conv_algo`): measured winners keyed on
//!   `(op, shape, threads)`, persisted so later runs and respawned
//!   replica workers skip calibration and compile identical plans.
//!   Loading is deliberately tolerant: a missing, corrupt or stale file
//!   yields an **empty** table (callers fall back to re-timing), never
//!   an error — a shared cache must not be able to brick a run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One AOT-compiled operation.
#[derive(Clone, Debug)]
pub struct OpSpec {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input shapes (f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (f32); multiple outputs arrive as a tuple.
    pub outputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub ops: BTreeMap<String, OpSpec>,
    /// Flagship model config (opaque JSON the e2e driver interprets).
    pub config: Json,
}

fn parse_shapes(j: &Json, what: &str) -> anyhow::Result<Vec<Vec<usize>>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow::anyhow!("{what}: shape must be an array"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("{what}: bad dim"))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let mut ops = BTreeMap::new();
        for op in j
            .get("ops")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing `ops` array"))?
        {
            let name = op.req_str("name")?.to_string();
            let spec = OpSpec {
                name: name.clone(),
                file: op.req_str("file")?.to_string(),
                inputs: parse_shapes(op.get("inputs"), &format!("op {name} inputs"))?,
                outputs: parse_shapes(op.get("outputs"), &format!("op {name} outputs"))?,
            };
            if ops.insert(name.clone(), spec).is_some() {
                anyhow::bail!("duplicate op `{name}` in manifest");
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            ops,
            config: j.get("config").clone(),
        })
    }

    pub fn op(&self, name: &str) -> anyhow::Result<&OpSpec> {
        self.ops
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact op `{name}` not in manifest"))
    }

    pub fn hlo_path(&self, op: &OpSpec) -> PathBuf {
        self.dir.join(&op.file)
    }
}

// ----- conv autotune table ---------------------------------------------------

/// One measured autotune winner: which algorithm won and its median
/// forward time when it was calibrated.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneEntry {
    /// Winning algorithm label (`"direct"` / `"im2col"` / `"winograd"`).
    pub algo: String,
    /// The winner's measured median, in milliseconds.
    pub ms: f64,
}

/// The persisted conv-algorithm autotune cache: canonical
/// `(op, shape, threads)` key → measured winner. See
/// `tensor::conv_algo` for the key format and the resolution order
/// (override → cache → Direct).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuneTable {
    /// Winner per canonical key, sorted (deterministic serialization).
    pub entries: BTreeMap<String, TuneEntry>,
}

/// Format version stamped into the persisted JSON; a table written by
/// an incompatible future format is treated as stale (→ empty).
const TUNE_TABLE_VERSION: usize = 1;

impl TuneTable {
    /// Load a persisted table. Missing, unreadable, corrupt or
    /// version-mismatched files all yield an **empty** table — the
    /// caller re-times on the next explicit calibration instead of
    /// erroring (the cache is an accelerator, never a dependency).
    pub fn load(path: &Path) -> TuneTable {
        let Ok(text) = std::fs::read_to_string(path) else {
            return TuneTable::default();
        };
        let Ok(j) = Json::parse(&text) else {
            return TuneTable::default();
        };
        TuneTable::from_json(&j).unwrap_or_default()
    }

    /// Parse from the JSON object [`TuneTable::to_json`] writes.
    /// `None` on any structural mismatch (treated as stale by `load`).
    pub fn from_json(j: &Json) -> Option<TuneTable> {
        if j.get("version").as_usize()? != TUNE_TABLE_VERSION {
            return None;
        }
        let mut entries = BTreeMap::new();
        for (key, e) in j.get("entries").as_obj()? {
            let algo = e.get("algo").as_str()?.to_string();
            let ms = e.get("ms").as_f64()?;
            entries.insert(key.clone(), TuneEntry { algo, ms });
        }
        Some(TuneTable { entries })
    }

    /// The persisted JSON form (versioned; keys sorted by `BTreeMap`).
    pub fn to_json(&self) -> Json {
        let mut entries = Json::obj();
        for (key, e) in &self.entries {
            entries.set(
                key,
                Json::from_pairs(vec![
                    ("algo", e.algo.as_str().into()),
                    ("ms", e.ms.into()),
                ]),
            );
        }
        Json::from_pairs(vec![
            ("version", TUNE_TABLE_VERSION.into()),
            ("entries", entries),
        ])
    }

    /// Persist to `path` (creating parent directories). Best-effort
    /// callers may ignore the result — a read-only filesystem degrades
    /// to per-process calibration, not failure.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(|e| anyhow::anyhow!("writing tune table {path:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parse_minimal() {
        let dir = std::env::temp_dir().join("moonwalk_manifest_test");
        write_manifest(
            &dir,
            r#"{"config": {"depth": 2},
                "ops": [{"name": "f", "file": "f.hlo.txt",
                          "inputs": [[2,2],[2,2]], "outputs": [[2,2]]}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.ops.len(), 1);
        let op = m.op("f").unwrap();
        assert_eq!(op.inputs.len(), 2);
        assert_eq!(op.outputs[0], vec![2, 2]);
        assert_eq!(m.config.req_usize("depth").unwrap(), 2);
        assert!(m.op("g").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = std::env::temp_dir().join("moonwalk_manifest_missing");
        std::fs::remove_dir_all(&dir).ok();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn tune_table_roundtrip() {
        let mut t = TuneTable::default();
        t.entries.insert(
            "conv2d_fwd n2 hw32x32 c16>16 k3 s1 p1 t4".to_string(),
            TuneEntry {
                algo: "winograd".to_string(),
                ms: 0.125,
            },
        );
        t.entries.insert(
            "conv1d_fwd n2 hw64x0 c8>8 k3 s1 p1 t1".to_string(),
            TuneEntry {
                algo: "im2col".to_string(),
                ms: 0.5,
            },
        );
        let path = std::env::temp_dir().join("moonwalk_tune_roundtrip/tune.json");
        t.save(&path).unwrap();
        assert_eq!(TuneTable::load(&path), t);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn tune_table_corrupt_or_stale_is_empty_not_error() {
        let dir = std::env::temp_dir().join("moonwalk_tune_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        // Missing file.
        assert!(TuneTable::load(&dir.join("absent.json")).entries.is_empty());
        // Corrupt JSON.
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{not json at all").unwrap();
        assert!(TuneTable::load(&bad).entries.is_empty());
        // Structurally wrong.
        let wrong = dir.join("wrong.json");
        std::fs::write(&wrong, r#"{"version": 1, "entries": [1, 2]}"#).unwrap();
        assert!(TuneTable::load(&wrong).entries.is_empty());
        // Stale version.
        let stale = dir.join("stale.json");
        std::fs::write(&stale, r#"{"version": 999, "entries": {}}"#).unwrap();
        assert!(TuneTable::load(&stale).entries.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_ops_rejected() {
        let dir = std::env::temp_dir().join("moonwalk_manifest_dup");
        write_manifest(
            &dir,
            r#"{"ops": [
                {"name": "f", "file": "a", "inputs": [], "outputs": []},
                {"name": "f", "file": "b", "inputs": [], "outputs": []}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
