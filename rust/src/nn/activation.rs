//! LeakyReLU — the paper's running example of a *cheap-residual* layer
//! (§4.5): its Jacobian is diagonal with entries `1` or `α ≠ 0`, so it is
//! everywhere invertible (hence submersive), and both vjp and vijp need
//! only the **sign** of each input element — 1 bit instead of 32, the
//! "16–32× smaller than full-precision activations" saving.

use crate::nn::{
    Layer, LayerError, Residual, ResidualData, ResidualKind, Submersivity,
};
use crate::tensor::{BitTensor, Tensor};

/// Elementwise LeakyReLU with slope `alpha` on the negative side.
pub struct LeakyRelu {
    pub alpha: f32,
}

impl LeakyRelu {
    pub fn new(alpha: f32) -> LeakyRelu {
        assert!(alpha != 0.0, "alpha = 0 (plain ReLU) is not submersive");
        LeakyRelu { alpha }
    }

    fn signs_of<'a>(&self, res: &'a Residual) -> SignView<'a> {
        match &res.kind {
            ResidualData::Signs(b) => SignView::Bits(b),
            ResidualData::Input(x) => SignView::Input(x),
            other => panic!("LeakyRelu residual must be Signs or Input, got {other:?}"),
        }
    }
}

enum SignView<'a> {
    Bits(&'a BitTensor),
    Input(&'a Tensor),
}

impl SignView<'_> {
    #[inline(always)]
    fn non_negative(&self, i: usize) -> bool {
        match self {
            SignView::Bits(b) => b.get(i),
            SignView::Input(x) => x.data()[i] >= 0.0,
        }
    }
}

impl Layer for LeakyRelu {
    fn name(&self) -> String {
        format!("leaky_relu({})", self.alpha)
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        Ok(in_shape.to_vec())
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        let a = self.alpha;
        let y = Tensor::from_vec(
            x.data().iter().map(|&v| if v >= 0.0 { v } else { a * v }).collect(),
            x.shape(),
        );
        // Even Backprop only needs the signs here; storing bits for both
        // tiers reflects what a careful implementation (e.g. the paper's
        // JAX one) would do. The *savings* relative to Backprop come from
        // the conv layers, whose Full residual is the entire input.
        let res = Residual {
            in_shape: x.shape().to_vec(),
            kind: ResidualData::Signs(BitTensor::from_signs(x)),
        };
        let _ = kind;
        (y, res)
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        let signs = self.signs_of(res);
        let a = self.alpha;
        let data = grad_out
            .data()
            .iter()
            .enumerate()
            .map(|(i, &g)| if signs.non_negative(i) { g } else { a * g })
            .collect();
        Tensor::from_vec(data, grad_out.shape())
    }

    fn vjp_params(&self, _x: &Tensor, _grad_out: &Tensor) -> Vec<Tensor> {
        Vec::new()
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        // Diagonal Jacobian ⇒ the right-inverse is the reciprocal diagonal.
        let signs = self.signs_of(res);
        let inv_a = 1.0 / self.alpha;
        let data = h_in
            .data()
            .iter()
            .enumerate()
            .map(|(i, &h)| if signs.non_negative(i) { h } else { inv_a * h })
            .collect();
        Ok(Tensor::from_vec(data, h_in.shape()))
    }

    fn jvp_input(&self, x: &Tensor, u: &Tensor) -> Tensor {
        let a = self.alpha;
        let data = x
            .data()
            .iter()
            .zip(u.data())
            .map(|(&xv, &uv)| if xv >= 0.0 { uv } else { a * uv })
            .collect();
        Tensor::from_vec(data, u.shape())
    }

    fn jvp_params(&self, x: &Tensor, _dparams: &[Tensor]) -> Tensor {
        Tensor::zeros(x.shape())
    }

    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError> {
        // α > 0 preserves signs, so the inverse is exact.
        if self.alpha < 0.0 {
            return Err(LayerError::NotInvertible {
                layer: self.name(),
                reason: "negative slope does not preserve signs".into(),
            });
        }
        let inv_a = 1.0 / self.alpha;
        Ok(Tensor::from_vec(
            y.data()
                .iter()
                .map(|&v| if v >= 0.0 { v } else { inv_a * v })
                .collect(),
            y.shape(),
        ))
    }

    fn submersivity(&self) -> Submersivity {
        Submersivity::Submersive { fast_path: true }
    }

    fn params(&self) -> Vec<&Tensor> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn forward_values() {
        let l = LeakyRelu::new(0.1);
        let x = Tensor::from_vec(vec![2.0, -3.0, 0.0], &[3]);
        let y = l.forward(&x);
        assert_eq!(y.data(), &[2.0, -0.3, 0.0]);
    }

    #[test]
    fn vjp_and_jvp_adjoint() {
        let l = LeakyRelu::new(0.2);
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[2, 5, 5, 3], 1.0, &mut rng);
        testutil::check_vjp_input_against_fd(&l, &x, 60, 1e-3);
    }

    #[test]
    fn vijp_right_inverse() {
        let l = LeakyRelu::new(0.3);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[2, 4, 4, 2], 1.0, &mut rng);
        testutil::check_vijp_right_inverse(&l, &x, 61, 1e-4);
    }

    #[test]
    fn inverse_exact() {
        let l = LeakyRelu::new(0.25);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[64], 1.0, &mut rng);
        let y = l.forward(&x);
        assert_close(&l.inverse(&y).unwrap(), &x, 1e-5, "lrelu inverse");
    }

    #[test]
    fn residual_is_bits() {
        let l = LeakyRelu::new(0.1);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[1024], 1.0, &mut rng);
        let (_, res) = l.forward_res(&x, ResidualKind::Full);
        // 1024 bits = 128 bytes, a 32x saving vs the 4096-byte input.
        assert_eq!(crate::nn::residual_bytes(&res), 128);
    }

    #[test]
    #[should_panic]
    fn zero_alpha_rejected() {
        LeakyRelu::new(0.0);
    }
}
