//! 1-D convolution (channel-last) with **fragmental gradient
//! checkpointing** (paper §5.1, Appendix 10, Algorithm 3).
//!
//! Layout: input `x ∈ [N, L, Cin]`, kernel `w ∈ [k, Cin, Cout]`, output
//! `x' ∈ [N, L', Cout]` with `L' = (L + 2p − k)/s + 1`:
//!
//! `x'[n,i',c'] = Σ_{j,c} w[j,c,c'] · x[n, s·i'+j−p, c]`
//!
//! Two regimes:
//! * `s > p` (+ pivot-tap triangularity): submersive, same elimination as
//!   2-D (Lemma 1) — vijp works directly.
//! * `s = 1, p = 1` (the paper's Fig.-3 resolution-preserving setting):
//!   **not** submersive (the Jacobian has a non-trivial cokernel). The
//!   output cotangent is reconstructed from stored *fragments*: the first
//!   `k−1` spatial slices of each block of `B` positions (Alg. 3), plus
//!   the tap-0 triangularity assumptions of Appendix 10
//!   (`w[0,c,c'] = 0 for c < c'`, `w[0,c',c'] ≠ 0`).

use crate::nn::{
    Fragment, Layer, LayerError, Residual, ResidualData, ResidualKind, Submersivity,
};
use crate::runtime::pool;
use crate::tensor::conv_algo::{self, ConvAlgo, ConvDims, ConvOp};
use crate::tensor::{arena, ops, Tensor};
use crate::util::Rng;

use super::conv2d::DIAG_FLOOR;

thread_local! {
    /// Per-thread f64 workspace for the Alg.-3 block recurrence
    /// (`fragment_reconstruct`). Pool workers are persistent, so this
    /// amortizes to zero allocations in steady state — the f64 analogue
    /// of the f32 `tensor::arena`.
    static RECON_BUF: std::cell::RefCell<Vec<f64>> = std::cell::RefCell::new(Vec::new());
}

/// A channel-last 1-D convolution layer.
pub struct Conv1d {
    /// Kernel `[k, Cin, Cout]`.
    pub w: Tensor,
    pub bias: Option<Tensor>,
    pub k: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
    label: String,
}

impl Conv1d {
    pub fn new(
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        bias: bool,
        rng: &mut Rng,
    ) -> Conv1d {
        assert!(k > 0 && stride > 0);
        let fan_in = (k * cin) as f32;
        let w = Tensor::randn(&[k, cin, cout], (2.0 / fan_in).sqrt(), rng);
        Conv1d {
            w,
            bias: bias.then(|| Tensor::zeros(&[cout])),
            k,
            cin,
            cout,
            stride,
            pad,
            label: format!("conv1d(k={k},s={stride},p={pad},{cin}->{cout})"),
        }
    }

    /// Init + project onto the fragmental constraint set (Appendix 10):
    /// tap-0 channel triangularity with a unit-ish diagonal.
    pub fn new_fragmental(
        k: usize,
        cin: usize,
        cout: usize,
        rng: &mut Rng,
    ) -> Conv1d {
        let mut conv = Conv1d::new(k, cin, cout, 1, 1, false, rng);
        for c in 0..cout.min(cin) {
            let idx = (c) * cout + c; // tap 0
            conv.w.data_mut()[idx] = 1.0 + conv.w.data()[idx];
        }
        conv.project_submersive();
        conv
    }

    /// Init + project onto the Lemma-1 (submersive, s>p) constraint set.
    pub fn new_submersive(
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        rng: &mut Rng,
    ) -> Conv1d {
        let mut conv = Conv1d::new(k, cin, cout, stride, pad, false, rng);
        for c in 0..cout.min(cin) {
            let idx = (pad * cin + c) * cout + c;
            conv.w.data_mut()[idx] = 1.0 + conv.w.data()[idx];
        }
        conv.project_submersive();
        conv
    }

    /// Which kernel tap is the elimination pivot? `p` in the submersive
    /// regime (Lemma 1), `0` in the fragmental regime (Appendix 10).
    fn pivot_tap(&self) -> usize {
        if self.stride > self.pad {
            self.pad
        } else {
            0
        }
    }

    fn out_len(&self, l: usize) -> Result<usize, LayerError> {
        let (k, s, p) = (self.k, self.stride, self.pad);
        if l + 2 * p < k {
            return Err(LayerError::Shape {
                layer: self.label.clone(),
                reason: format!("input length {l} < kernel {k} with pad {p}"),
            });
        }
        Ok((l + 2 * p - k) / s + 1)
    }

    /// The [`ConvDims`] geometry for an `[N,L,Cin]` input (`w`/`wo` are
    /// 0 for 1-D) — what the conv-algo dispatcher keys its autotune
    /// cache on.
    fn conv_dims(&self, n: usize, l: usize, lo: usize) -> ConvDims {
        ConvDims {
            n,
            h: l,
            w: 0,
            ho: lo,
            wo: 0,
            cin: self.cin,
            cout: self.cout,
            k: self.k,
            s: self.stride,
            p: self.pad,
        }
    }

    /// Forward convolution, dispatched through the [`ConvAlgo`] lattice
    /// (`tensor::conv_algo`): forced override → autotune-cache hit →
    /// Direct. Shared by `forward`, `jvp_input` and `jvp_params`.
    fn conv_with(&self, x: &Tensor, wdata: &[f32], bias: Option<&Tensor>) -> Tensor {
        assert_eq!(x.rank(), 3, "conv1d expects [N,L,C]");
        assert_eq!(x.shape()[2], self.cin);
        let (n, l) = (x.shape()[0], x.shape()[1]);
        let lo = self.out_len(l).expect("shape checked");
        match conv_algo::resolve(ConvOp::Conv1dFwd, &self.conv_dims(n, l, lo)) {
            ConvAlgo::Im2col => self.conv_with_im2col(x, wdata, bias, lo),
            _ => self.conv_with_direct(x, wdata, bias, lo),
        }
    }

    /// Gather im2col patch rows for images `imgs` into `buf`
    /// (row `a` of image `img` = the `k·Cin` receptive field of output
    /// position `a`, taps contiguous — the row-major flattening of the
    /// `[k,Cin,Cout]` kernel).
    fn gather_patches(
        &self,
        x: &Tensor,
        imgs: std::ops::Range<usize>,
        lo: usize,
        buf: &mut [f32],
    ) {
        let (l, cin) = (x.shape()[1], self.cin);
        let (k, s, p) = (self.k, self.stride, self.pad);
        let row_len = k * cin;
        debug_assert_eq!(buf.len(), imgs.len() * lo * row_len);
        let xd = x.data();
        for (local, img) in imgs.enumerate() {
            let b_img = &mut buf[local * lo * row_len..(local + 1) * lo * row_len];
            for a in 0..lo {
                for j in 0..k {
                    let ii = (s * a + j) as isize - p as isize;
                    let dst = a * row_len + j * cin;
                    if ii >= 0 && (ii as usize) < l {
                        let src = (img * l + ii as usize) * cin;
                        b_img[dst..dst + cin].copy_from_slice(&xd[src..src + cin]);
                    } else {
                        b_img[dst..dst + cin].fill(0.0);
                    }
                }
            }
        }
    }

    /// The Direct lowering: batch-parallel per-image im2col + GEMM —
    /// each worker leases its own patch buffer and the GEMMs run serial
    /// inside the fan-out.
    fn conv_with_direct(
        &self,
        x: &Tensor,
        wdata: &[f32],
        bias: Option<&Tensor>,
        lo: usize,
    ) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let row_len = k * cin;
        let mut out = Tensor::zeros(&[n, lo, cout]);
        let img_out = lo * cout;
        let workers = pool::effective_threads(n);
        pool::run_records(out.data_mut(), img_out, workers, |imgs, chunk| {
            let mut patches = arena::take(lo * row_len);
            for (local, img) in imgs.enumerate() {
                self.gather_patches(x, img..img + 1, lo, &mut patches);
                ops::matmul_into_auto(
                    &patches,
                    wdata,
                    &mut chunk[local * img_out..(local + 1) * img_out],
                    lo,
                    row_len,
                    cout,
                );
            }
        });
        if let Some(b) = bias {
            for chunk in out.data_mut().chunks_mut(cout) {
                for (o, bv) in chunk.iter_mut().zip(b.data()) {
                    *o += bv;
                }
            }
        }
        out
    }

    /// The Im2col lowering: gather *all* images into one
    /// `[N·L', k·Cin]` patch matrix and run a single GEMM, letting the
    /// GEMM dispatcher (`select_gemm_algo`) own the parallelism — the
    /// opposite split from Direct's batch fan-out, which is exactly
    /// what the autotuner arbitrates.
    fn conv_with_im2col(
        &self,
        x: &Tensor,
        wdata: &[f32],
        bias: Option<&Tensor>,
        lo: usize,
    ) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let row_len = k * cin;
        let mut out = Tensor::zeros(&[n, lo, cout]);
        let mut patches = arena::take(n * lo * row_len);
        self.gather_patches(x, 0..n, lo, &mut patches);
        ops::matmul_into_auto(&patches, wdata, out.data_mut(), n * lo, row_len, cout);
        if let Some(b) = bias {
            for chunk in out.data_mut().chunks_mut(cout) {
                for (o, bv) in chunk.iter_mut().zip(b.data()) {
                    *o += bv;
                }
            }
        }
        out
    }

    /// The Im2col lowering of the weight gradient: one
    /// `[N·L', k·Cin]ᵀ·[N·L', Cout]` GEMM over the batched patch matrix
    /// (vs Direct's image-parallel sparsity-skipping reduction).
    fn vjp_params_dw_im2col(&self, x: &Tensor, grad_out: &Tensor, lo: usize) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let row_len = k * cin;
        let mut dw = Tensor::zeros(&[k, cin, cout]);
        let mut patches = arena::take(n * lo * row_len);
        self.gather_patches(x, 0..n, lo, &mut patches);
        ops::matmul_tn_into_auto(
            &patches,
            grad_out.data(),
            dw.data_mut(),
            n * lo,
            row_len,
            cout,
        );
        dw
    }

    /// The Direct lowering of the weight gradient: image-parallel
    /// reduction with worker-ordered (deterministic) merge of
    /// per-worker dw accumulators, leased from the arena so they are
    /// tracker-visible and recycled. Skips zero input values — a win
    /// on sparse activations that the dense im2col GEMM cannot have.
    fn vjp_params_dw_direct(&self, x: &Tensor, grad_out: &Tensor, lo: usize) -> Tensor {
        let (n, l) = (x.shape()[0], x.shape()[1]);
        let (k, s, p, cin, cout) = (self.k, self.stride, self.pad, self.cin, self.cout);
        let wlen = k * cin * cout;
        let xd = x.data();
        let gd = grad_out.data();
        let workers = pool::effective_threads(n);
        let acc = pool::run_reduce(
            n,
            workers,
            || arena::take_zeroed(wlen),
            |imgs, dwd| {
                for img in imgs {
                    for a in 0..lo {
                        let grow = &gd[(img * lo + a) * cout..(img * lo + a + 1) * cout];
                        for j in 0..k {
                            let ii = (s * a + j) as isize - p as isize;
                            if ii < 0 || ii as usize >= l {
                                continue;
                            }
                            let xrow = &xd
                                [(img * l + ii as usize) * cin..(img * l + ii as usize + 1) * cin];
                            for c in 0..cin {
                                let xv = xrow[c];
                                if xv == 0.0 {
                                    continue;
                                }
                                let drow =
                                    &mut dwd[(j * cin + c) * cout..(j * cin + c + 1) * cout];
                                for c2 in 0..cout {
                                    drow[c2] += xv * grow[c2];
                                }
                            }
                        }
                    }
                }
            },
            |a, b| {
                for (av, bv) in a.iter_mut().zip(b.iter()) {
                    *av += *bv;
                }
            },
        );
        let mut dw = Tensor::zeros(&[k, cin, cout]);
        dw.data_mut().copy_from_slice(&acc);
        dw
    }

    /// Calibrate this layer's autotunable conv ops (forward and
    /// `vjp_params`) for input `x` — the 1-D analogue of
    /// `Conv2d::autotune`; see `tensor::conv_algo` for the determinism
    /// contract (no timing ever happens outside explicit calibration).
    pub fn autotune(&self, x: &Tensor) -> Vec<conv_algo::TuneOutcome> {
        self.autotune_with(x, 1, 3)
    }

    /// [`Self::autotune`] with explicit bench warmup/iteration counts.
    pub fn autotune_with(
        &self,
        x: &Tensor,
        warmup: usize,
        iters: usize,
    ) -> Vec<conv_algo::TuneOutcome> {
        let (n, l) = (x.shape()[0], x.shape()[1]);
        let lo = self.out_len(l).expect("autotune needs a valid input shape");
        let dims = self.conv_dims(n, l, lo);
        let mut outcomes = Vec::new();
        for op in [ConvOp::Conv1dFwd, ConvOp::Conv1dVjpParams] {
            if let Some((algo, ms)) = conv_algo::cached(op, &dims) {
                outcomes.push(conv_algo::TuneOutcome {
                    key: conv_algo::key(op, &dims),
                    algo,
                    best_ms: ms,
                    candidates: Vec::new(),
                    cached: true,
                });
                continue;
            }
            let g = Tensor::full(&[n, lo, self.cout], 0.5);
            let mut cands = Vec::new();
            for algo in conv_algo::candidates(op, &dims) {
                let stats = crate::util::timer::bench(warmup, iters, || match op {
                    ConvOp::Conv1dFwd => {
                        let _ = if algo == ConvAlgo::Im2col {
                            self.conv_with_im2col(x, self.w.data(), self.bias.as_ref(), lo)
                        } else {
                            self.conv_with_direct(x, self.w.data(), self.bias.as_ref(), lo)
                        };
                    }
                    _ => {
                        let _ = if algo == ConvAlgo::Im2col {
                            self.vjp_params_dw_im2col(x, &g, lo)
                        } else {
                            self.vjp_params_dw_direct(x, &g, lo)
                        };
                    }
                });
                cands.push((algo, stats.median_ms()));
            }
            let &(best, best_ms) = cands
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .expect("Direct is always a candidate");
            conv_algo::record(op, &dims, best, best_ms);
            outcomes.push(conv_algo::TuneOutcome {
                key: conv_algo::key(op, &dims),
                algo: best,
                best_ms,
                candidates: cands,
                cached: false,
            });
        }
        outcomes
    }

    /// Transpose convolution: `h[n,i,c] = Σ_{j,c'} w[j,c,c'] h'[n,(i−j+p)/s,c']`.
    /// Batch-parallel: images scatter into disjoint output chunks.
    fn transpose_conv(&self, g: &Tensor, in_shape: &[usize]) -> Tensor {
        let (n, l) = (in_shape[0], in_shape[1]);
        let lo = g.shape()[1];
        let (k, s, p, cin, cout) = (self.k, self.stride, self.pad, self.cin, self.cout);
        let mut out = Tensor::zeros(&[n, l, cin]);
        let gd = g.data();
        let wd = self.w.data();
        let img_in = l * cin;
        let workers = pool::effective_threads(n);
        pool::run_records(out.data_mut(), img_in, workers, |imgs, chunk| {
            for (local, img) in imgs.enumerate() {
                let o_img = &mut chunk[local * img_in..(local + 1) * img_in];
                for a in 0..lo {
                    let grow = &gd[(img * lo + a) * cout..(img * lo + a + 1) * cout];
                    for j in 0..k {
                        let ii = (s * a + j) as isize - p as isize;
                        if ii < 0 || ii as usize >= l {
                            continue;
                        }
                        let dst = (ii as usize) * cin;
                        for c in 0..cin {
                            let wrow = &wd[(j * cin + c) * cout..(j * cin + c + 1) * cout];
                            let mut acc = 0.0f32;
                            for c2 in 0..cout {
                                acc += wrow[c2] * grow[c2];
                            }
                            o_img[dst + c] += acc;
                        }
                    }
                }
            }
        });
        out
    }

    /// Submersive-regime elimination (1-D analogue of the 2-D vijp).
    fn vijp_eliminate(&self, h: &Tensor, out_shape: &[usize]) -> Result<Tensor, LayerError> {
        if let Submersivity::NonSubmersive { reason, .. } = self.submersivity() {
            return Err(LayerError::NotSubmersive {
                layer: self.label.clone(),
                reason,
            });
        }
        let (n, ll) = (h.shape()[0], h.shape()[1]);
        let (lo, cout) = (out_shape[1], out_shape[2]);
        let (k, s, p, cin) = (self.k, self.stride, self.pad, self.cin);
        if s * (lo - 1) >= ll {
            return Err(LayerError::NotSubmersive {
                layer: self.label.clone(),
                reason: format!("spatial bound violated: n={ll} !> s(n'-1)={}", s * (lo - 1)),
            });
        }
        let mut hp = Tensor::zeros(&[n, lo, cout]);
        let wd = self.w.data();
        let hd = h.data();
        let reach = (k - 1 - p.min(k - 1)) / s;
        let img_h = ll * cin;
        let img_hp = lo * cout;
        // Images are independent; the in-image elimination is sequential.
        let workers = pool::effective_threads(n);
        pool::run_records(hp.data_mut(), img_hp, workers, |imgs, chunk| {
            for (local, img) in imgs.enumerate() {
                let h_img = &hd[img * img_h..(img + 1) * img_h];
                let hp_img = &mut chunk[local * img_hp..(local + 1) * img_hp];
                for a in 0..lo {
                    for co in 0..cout {
                        let mut acc = h_img[(s * a) * cin + co];
                        for a2 in a.saturating_sub(reach)..=a {
                            let j = s * (a - a2) + p;
                            if j >= k {
                                continue;
                            }
                            let c_end = if a2 == a { co } else { cout };
                            let hprow = a2 * cout;
                            let wrow = (j * cin + co) * cout;
                            for c2 in 0..c_end {
                                acc -= wd[wrow + c2] * hp_img[hprow + c2];
                            }
                        }
                        let diag = wd[(p * cin + co) * cout + co];
                        hp_img[a * cout + co] = acc / diag;
                    }
                }
            }
        });
        Ok(hp)
    }

    /// Is this layer in the fragmental-checkpointing regime of §5.1
    /// (s = 1, p = 1, tap-0 triangular with non-zero diagonal)?
    pub fn fragmental_ready(&self) -> Result<(), String> {
        if self.stride != 1 || self.pad != 1 {
            return Err(format!(
                "fragmental reconstruction implemented for s=1, p=1 (got s={}, p={})",
                self.stride, self.pad
            ));
        }
        if self.k < 2 {
            return Err("fragmental reconstruction needs k ≥ 2".into());
        }
        if self.cout > self.cin {
            return Err(format!(
                "tap-0 triangularity needs Cout ≤ Cin ({} > {})",
                self.cout, self.cin
            ));
        }
        let wd = self.w.data();
        for co in 0..self.cout {
            if wd[co * self.cout + co].abs() < 1e-8 {
                return Err(format!("zero tap-0 diagonal at channel {co}"));
            }
            for ci in 0..co {
                if wd[ci * self.cout + co] != 0.0 {
                    return Err(format!(
                        "tap-0 triangularity violated at w[0,{ci},{co}]"
                    ));
                }
            }
        }
        Ok(())
    }
}

impl Layer for Conv1d {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        if in_shape.len() != 3 || in_shape[2] != self.cin {
            return Err(LayerError::Shape {
                layer: self.label.clone(),
                reason: format!("expected [N,L,{}], got {in_shape:?}", self.cin),
            });
        }
        Ok(vec![in_shape[0], self.out_len(in_shape[1])?, self.cout])
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        let y = self.conv_with(x, self.w.data(), self.bias.as_ref());
        let res = Residual {
            in_shape: x.shape().to_vec(),
            kind: match kind {
                ResidualKind::Full => ResidualData::Input(x.clone()),
                ResidualKind::Minimal => ResidualData::None,
            },
        };
        (y, res)
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        self.transpose_conv(grad_out, &res.in_shape)
    }

    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor> {
        let (n, l) = (x.shape()[0], x.shape()[1]);
        let lo = self.out_len(l).expect("shapes validated");
        let cout = self.cout;
        let dw = match conv_algo::resolve(ConvOp::Conv1dVjpParams, &self.conv_dims(n, l, lo)) {
            ConvAlgo::Im2col => self.vjp_params_dw_im2col(x, grad_out, lo),
            _ => self.vjp_params_dw_direct(x, grad_out, lo),
        };
        let mut grads = vec![dw];
        if self.bias.is_some() {
            let mut db = Tensor::zeros(&[cout]);
            for chunk in grad_out.data().chunks(cout) {
                for (d, g) in db.data_mut().iter_mut().zip(chunk) {
                    *d += g;
                }
            }
            grads.push(db);
        }
        grads
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        let out_shape = self.out_shape(&res.in_shape)?;
        self.vijp_eliminate(h_in, &out_shape)
    }

    fn jvp_input(&self, _x: &Tensor, u: &Tensor) -> Tensor {
        self.conv_with(u, self.w.data(), None)
    }

    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor {
        let mut out = self.conv_with(x, dparams[0].data(), None);
        if self.bias.is_some() {
            for chunk in out.data_mut().chunks_mut(self.cout) {
                for (o, b) in chunk.iter_mut().zip(dparams[1].data()) {
                    *o += b;
                }
            }
        }
        out
    }

    fn inverse(&self, _y: &Tensor) -> Result<Tensor, LayerError> {
        Err(LayerError::NotInvertible {
            layer: self.label.clone(),
            reason: "1-D convolutions are used in the non-invertible Fig.-3 setting".into(),
        })
    }

    fn submersivity(&self) -> Submersivity {
        let (k, s, p) = (self.k, self.stride, self.pad);
        if s <= p || k <= p {
            // The Fig.-3 regime: reconstruction via fragments instead.
            return Submersivity::NonSubmersive {
                reason: format!("requires s > p and k > p (k={k}, s={s}, p={p})"),
                fragmental_ok: self.fragmental_ready().is_ok(),
            };
        }
        if self.cout > self.cin {
            return Submersivity::NonSubmersive {
                reason: format!("needs Cout ≤ Cin ({} > {})", self.cout, self.cin),
                fragmental_ok: false,
            };
        }
        let wd = self.w.data();
        for co in 0..self.cout {
            let diag = wd[(p * self.cin + co) * self.cout + co];
            if diag.abs() < 1e-8 {
                return Submersivity::NonSubmersive {
                    reason: format!("zero diagonal tap w[p,{co},{co}]"),
                    fragmental_ok: false,
                };
            }
            for ci in 0..co {
                if wd[(p * self.cin + ci) * self.cout + co] != 0.0 {
                    return Submersivity::NonSubmersive {
                        reason: format!("triangularity violated at w[p,{ci},{co}]"),
                        fragmental_ok: false,
                    };
                }
            }
        }
        Submersivity::Submersive {
            fast_path: s + p >= k,
        }
    }

    fn params(&self) -> Vec<&Tensor> {
        match &self.bias {
            Some(b) => vec![&self.w, b],
            None => vec![&self.w],
        }
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        match &mut self.bias {
            Some(b) => vec![&mut self.w, b],
            None => vec![&mut self.w],
        }
    }

    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        match self.out_shape(in_shape) {
            Ok(s) => 2.0 * (self.k * self.cin) as f64 * s.iter().product::<usize>() as f64,
            Err(_) => 0.0,
        }
    }

    fn project_submersive(&mut self) {
        let tap = self.pivot_tap();
        let (cin, cout) = (self.cin, self.cout);
        let wd = self.w.data_mut();
        for co in 0..cout {
            for ci in 0..co.min(cin) {
                wd[(tap * cin + ci) * cout + co] = 0.0;
            }
            if co < cin {
                let idx = (tap * cin + co) * cout + co;
                let d = wd[idx];
                if d.abs() < DIAG_FLOOR {
                    wd[idx] = if d >= 0.0 { DIAG_FLOOR } else { -DIAG_FLOOR };
                }
            }
        }
    }

    fn conv_tune_key(&self, in_shape: &[usize]) -> Option<String> {
        if in_shape.len() != 3 || in_shape[2] != self.cin {
            return None;
        }
        let lo = self.out_len(in_shape[1]).ok()?;
        Some(conv_algo::key(
            ConvOp::Conv1dFwd,
            &self.conv_dims(in_shape[0], in_shape[1], lo),
        ))
    }

    fn conv_autotune(&self, x: &Tensor) -> Vec<conv_algo::TuneOutcome> {
        self.autotune(x)
    }

    /// Capture the first `k−1` spatial slices of each block of `h_out`
    /// (Alg. 3's `h_init`). Block size must be > `k−1`.
    fn fragment_capture(&self, h_out: &Tensor, block: usize) -> Result<Fragment, LayerError> {
        self.fragmental_ready().map_err(|reason| LayerError::NoFragmental {
            layer: self.label.clone(),
            reason,
        })?;
        if block < self.k {
            return Err(LayerError::NoFragmental {
                layer: self.label.clone(),
                reason: format!("block size {block} must be ≥ k = {}", self.k),
            });
        }
        let (n, lo, cout) = (h_out.shape()[0], h_out.shape()[1], h_out.shape()[2]);
        let keep = self.k - 1;
        let n_blocks = (lo + block - 1) / block;
        let mut slices = Tensor::zeros(&[n, n_blocks * keep, cout]);
        let sd = slices.data_mut();
        let hd = h_out.data();
        for img in 0..n {
            for b in 0..n_blocks {
                for r in 0..keep {
                    let src_i = b * block + r;
                    let dst = (img * n_blocks * keep + b * keep + r) * cout;
                    if src_i < lo {
                        let src = (img * lo + src_i) * cout;
                        sd[dst..dst + cout].copy_from_slice(&hd[src..src + cout]);
                    }
                }
            }
        }
        Ok(Fragment {
            slices,
            block,
            out_shape: h_out.shape().to_vec(),
        })
    }

    /// Alg. 3: reconstruct the full output cotangent from the input
    /// cotangent `h_in` and the stored fragments, block-parallel.
    ///
    /// Recursion (Appendix 10, Eq. 20, adapted to our kernel convention —
    /// solving the tap-0 term):
    /// `h'[i+1,c'] = (h[i,c'] − Σ_{c''<c'} w[0,c',c''] h'[i+1,c'']
    ///               − Σ_{j≥1,c''} w[j,c',c''] h'[i+1−j,c'']) / w[0,c',c']`
    ///
    /// Blocks are independent by construction — exactly the parallelism
    /// Alg. 3 exploits — so the `(image, block)` tasks fan out across
    /// the persistent pool ([`pool::run_spans`]; every task writes a
    /// disjoint span of `hp`). Each task runs the identical serial
    /// recurrence, so parallel reconstruction is bit-identical to the
    /// 1-thread kernel; the Moonwalk forward-reconstruction phase no
    /// longer serializes at batch 1.
    fn fragment_reconstruct(
        &self,
        frag: &Fragment,
        h_in: &Tensor,
    ) -> Result<Tensor, LayerError> {
        self.fragmental_ready().map_err(|reason| LayerError::NoFragmental {
            layer: self.label.clone(),
            reason,
        })?;
        let (n, lo, cout) = (
            frag.out_shape[0],
            frag.out_shape[1],
            frag.out_shape[2],
        );
        let (k, cin) = (self.k, self.cin);
        let ll = h_in.shape()[1];
        let block = frag.block;
        let keep = k - 1;
        let n_blocks = (lo + block - 1) / block;
        let mut hp = Tensor::zeros(&[n, lo, cout]);
        let hd = h_in.data();
        let wd = self.w.data();
        let sd = frag.slices.data();
        // One span of hp per (image, block) task, in ascending order
        // (the last block of each image may be short).
        let mut spans: Vec<std::ops::Range<usize>> = Vec::with_capacity(n * n_blocks);
        for img in 0..n {
            for b in 0..n_blocks {
                let lo_i = b * block;
                let hi_i = ((b + 1) * block).min(lo);
                spans.push((img * lo + lo_i) * cout..(img * lo + hi_i) * cout);
            }
        }
        let workers = pool::effective_threads(n * n_blocks);
        pool::run_spans(hp.data_mut(), &spans, workers, |task, out_block| {
            let img = task / n_blocks;
            let b = task % n_blocks;
            let lo_i = b * block;
            let hi_i = ((b + 1) * block).min(lo);
            // The in-block recurrence compounds rounding error over up to
            // B steps, so accumulate in f64 (the kernel-side Pallas
            // version relies on the same trick being unnecessary only for
            // small B). The workspace is thread-local: persistent pool
            // workers live for the process, so after warm-up the
            // reconstruction allocates nothing. Stale contents are fine —
            // every row the recurrence reads is written first.
            RECON_BUF.with(|cell| {
                let mut buf = cell.borrow_mut();
                buf.resize(block * cout, 0.0);
                // 1) restore the stored k-1 prefix slices of this block
                for r in 0..keep {
                    let i = lo_i + r;
                    if i >= lo {
                        continue;
                    }
                    let src = (img * n_blocks * keep + b * keep + r) * cout;
                    for c in 0..cout {
                        buf[r * cout + c] = sd[src + c] as f64;
                    }
                }
                // 2) roll the recurrence forward inside the block.
                // h'[i,·] from the input-cotangent equation at i−1:
                // h[i−1,c] = Σ_{j,c'} w[j,c,c'] h'[i−j, c']   (p = 1)
                for i in lo_i + keep..hi_i {
                    let hrow_i = i - 1;
                    debug_assert!(hrow_i < ll);
                    let r = i - lo_i;
                    for co in 0..cout {
                        let mut acc = hd[(img * ll + hrow_i) * cin + co] as f64;
                        for c2 in 0..co {
                            acc -= wd[co * cout + c2] as f64 * buf[r * cout + c2];
                        }
                        for j in 1..k {
                            if j > i {
                                break;
                            }
                            let wrow = (j * cin + co) * cout;
                            let prow = (r - j) * cout;
                            for c2 in 0..cout {
                                acc -= wd[wrow + c2] as f64 * buf[prow + c2];
                            }
                        }
                        buf[r * cout + co] = acc / wd[co * cout + co] as f64;
                    }
                }
                // 3) write the block back in f32 (out_block is exactly
                // this task's span of hp)
                for r in 0..hi_i - lo_i {
                    for c in 0..cout {
                        out_block[r * cout + c] = buf[r * cout + c] as f32;
                    }
                }
            });
        });
        Ok(hp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;
    use crate::tensor::assert_close;

    fn input(n: usize, l: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed ^ 0xbeef);
        Tensor::randn(&[n, l, c], 1.0, &mut rng)
    }

    #[test]
    fn forward_shape_same_pad() {
        let mut rng = Rng::new(0);
        let conv = Conv1d::new(3, 4, 4, 1, 1, false, &mut rng);
        let x = input(2, 16, 4, 0);
        assert_eq!(conv.forward(&x).shape(), &[2, 16, 4]);
    }

    #[test]
    fn vjp_input_adjoint() {
        let mut rng = Rng::new(1);
        let conv = Conv1d::new(3, 3, 5, 1, 1, false, &mut rng);
        let x = input(2, 10, 3, 1);
        testutil::check_vjp_input_against_fd(&conv, &x, 50, 1e-3);
    }

    #[test]
    fn vjp_params_adjoint() {
        let mut rng = Rng::new(2);
        let conv = Conv1d::new(3, 3, 4, 2, 1, true, &mut rng);
        let x = input(2, 11, 3, 2);
        testutil::check_vjp_params_adjoint(&conv, &x, 51, 1e-3);
    }

    #[test]
    fn vijp_right_inverse_submersive() {
        let mut rng = Rng::new(3);
        let conv = Conv1d::new_submersive(3, 4, 4, 2, 1, &mut rng);
        assert!(conv.submersivity().is_submersive());
        let x = input(2, 11, 4, 3);
        testutil::check_vijp_right_inverse(&conv, &x, 52, 2e-3);
    }

    #[test]
    fn im2col_matches_direct_forward_and_vjp_params() {
        let mut rng = Rng::new(30);
        let conv = Conv1d::new(3, 4, 6, 2, 1, true, &mut rng);
        let x = input(3, 17, 4, 30);
        let lo = conv.out_len(17).unwrap();
        let direct = conv.conv_with_direct(&x, conv.w.data(), conv.bias.as_ref(), lo);
        let im2col = conv.conv_with_im2col(&x, conv.w.data(), conv.bias.as_ref(), lo);
        assert_close(&im2col, &direct, 1e-5, "conv1d forward im2col vs direct");
        let g = input(3, lo, 6, 31);
        let d_direct = conv.vjp_params_dw_direct(&x, &g, lo);
        let d_im2col = conv.vjp_params_dw_im2col(&x, &g, lo);
        assert_close(&d_im2col, &d_direct, 1e-5, "conv1d vjp_params im2col vs direct");
    }

    #[test]
    fn autotune_has_two_candidates_then_caches() {
        // Distinct geometry so this test cannot collide with others
        // sharing the process-global autotune cache.
        let mut rng = Rng::new(32);
        let conv = Conv1d::new(3, 3, 3, 1, 1, false, &mut rng);
        let x = input(2, 23, 3, 32);
        let first = conv.autotune_with(&x, 0, 1);
        assert_eq!(first.len(), 2);
        assert!(first.iter().all(|o| !o.cached));
        assert_eq!(first[0].candidates.len(), 2, "direct + im2col");
        let second = conv.autotune_with(&x, 0, 1);
        assert!(second.iter().all(|o| o.cached));
        assert_eq!(
            conv.conv_tune_key(x.shape()).as_deref(),
            Some(first[0].key.as_str())
        );
    }

    #[test]
    fn fragmental_regime_detected() {
        let mut rng = Rng::new(4);
        let conv = Conv1d::new_fragmental(3, 4, 4, &mut rng);
        match conv.submersivity() {
            Submersivity::NonSubmersive { fragmental_ok, .. } => assert!(fragmental_ok),
            s => panic!("expected NonSubmersive, got {s:?}"),
        }
    }

    #[test]
    fn fragment_roundtrip_exact() {
        // THE §5.1 property: capture fragments of a random output
        // cotangent, push it back through vjp_input, then reconstruct —
        // must equal the original exactly (up to fp).
        let mut rng = Rng::new(5);
        for (k, block) in [(3usize, 4usize), (3, 8), (2, 4), (4, 8), (3, 16)] {
            let conv = Conv1d::new_fragmental(k, 5, 5, &mut rng);
            let x = input(2, 32, 5, 5 + k as u64);
            let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
            let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
            let h = conv.vjp_input(&res, &hprime);
            let frag = conv.fragment_capture(&hprime, block).unwrap();
            let rec = conv.fragment_reconstruct(&frag, &h).unwrap();
            assert_close(&rec, &hprime, 2e-3, &format!("fragment k={k} B={block}"));
        }
    }

    #[test]
    fn fragment_memory_ratio() {
        // B=4, k=3 ⇒ store 2/4 = 50% (paper Fig. 3a); B=16 ⇒ 2/16 = 1/8.
        let mut rng = Rng::new(6);
        let conv = Conv1d::new_fragmental(3, 8, 8, &mut rng);
        let x = input(1, 64, 8, 6);
        let y = conv.forward(&x);
        let f4 = conv.fragment_capture(&y, 4).unwrap();
        let f16 = conv.fragment_capture(&y, 16).unwrap();
        assert_eq!(f4.slices.bytes() * 2, y.bytes());
        assert_eq!(f16.slices.bytes() * 8, y.bytes());
    }

    #[test]
    fn fragment_capture_rejects_small_block() {
        let mut rng = Rng::new(7);
        let conv = Conv1d::new_fragmental(3, 4, 4, &mut rng);
        let y = input(1, 16, 4, 7);
        assert!(conv.fragment_capture(&y, 2).is_err());
    }

    #[test]
    fn fragment_rejects_wrong_geometry() {
        let mut rng = Rng::new(8);
        let conv = Conv1d::new(3, 4, 4, 2, 1, false, &mut rng);
        let y = input(1, 8, 4, 8);
        assert!(matches!(
            conv.fragment_capture(&y, 4),
            Err(LayerError::NoFragmental { .. })
        ));
    }

    #[test]
    fn channel_reducing_fragmental() {
        let mut rng = Rng::new(9);
        let conv = Conv1d::new_fragmental(3, 6, 4, &mut rng);
        let x = input(1, 24, 6, 9);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
        let h = conv.vjp_input(&res, &hprime);
        let frag = conv.fragment_capture(&hprime, 8).unwrap();
        let rec = conv.fragment_reconstruct(&frag, &h).unwrap();
        assert_close(&rec, &hprime, 2e-3, "channel-reducing fragment");
    }
}
