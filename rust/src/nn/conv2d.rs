//! 2-D convolution (channel-last) with the paper's submersive
//! parameterisation and the **vijp** operator of §5 / Algorithm 2.
//!
//! Layout conventions (paper §3.1, tensor notation):
//! * input  `x  ∈ [N, H, W, Cin]`
//! * kernel `w  ∈ [k, k, Cin, Cout]`
//! * output `x' ∈ [N, H', W', Cout]`, `H' = (H + 2p − k)/s + 1`
//!
//! `x'[n,i',j',c'] = Σ_{ki,kj,c} w[ki,kj,c,c'] · x[n, s·i'+ki−p, s·j'+kj−p, c]`
//!
//! Submersivity (Lemma 1) requires `k > p`, `s > p`, `H > s(H'−1)`,
//! channel triangularity `w[p,p,c,c'] = 0 for c < c'` (⇒ `Cout ≤ Cin`) and
//! non-zero diagonal `w[p,p,c',c'] ≠ 0`. Under these, the vijp is a
//! Gaussian elimination whose pivots are the fixed diagonal taps; when
//! additionally `s + p ≥ k` the elimination decouples across spatial
//! positions entirely (the paper's *fully parallel* vijp, Alg. 2).

use crate::nn::{
    Layer, LayerError, Residual, ResidualData, ResidualKind, Submersivity,
};
use crate::runtime::pool;
use crate::tensor::conv_algo::{self, ConvAlgo, ConvDims, ConvOp};
use crate::tensor::{arena, ops, Tensor};
use crate::util::Rng;

/// Minimum |diagonal tap| enforced by the submersive projection.
pub const DIAG_FLOOR: f32 = 0.05;

/// Minimum work — output elements × kernel taps (`H'·W'·Cout·k²`, i.e.
/// FLOPs / (2·Cin)) — for the batch-1 spatial row-band paths to engage.
/// Below it the whole kernel is dispatch-scale (a few µs) and a region
/// fan-out would cost more than it saves; the same floor philosophy as
/// `ops::PAR_MIN_FLOPS`, sized for the persistent pool's park/wake cost.
/// Tiny tail layers of stride-2 stacks (H' = 2..4) stay serial.
const SPATIAL_MIN_TAP_ELEMS: usize = 4096;

/// The F(2×2, 3×3) Winograd kernel transform `G` (4×3). Every entry of
/// every F(2×2,3×3) transform matrix is in {0, ±1, ±½} — exact in
/// binary floating point — so the Winograd lowering's only rounding
/// difference vs Direct is summation order.
const WINO_G: [[f32; 3]; 4] = [
    [1.0, 0.0, 0.0],
    [0.5, 0.5, 0.5],
    [0.5, -0.5, 0.5],
    [0.0, 0.0, 1.0],
];

/// A channel-last 2-D convolution layer.
pub struct Conv2d {
    /// Kernel `[k, k, Cin, Cout]`.
    pub w: Tensor,
    /// Optional per-output-channel bias `[Cout]`.
    pub bias: Option<Tensor>,
    pub k: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
    label: String,
}

impl Conv2d {
    /// He-style init (unconstrained — the paper's Fig. 4 "standard" model).
    pub fn new(
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        bias: bool,
        rng: &mut Rng,
    ) -> Conv2d {
        assert!(k > 0 && stride > 0);
        let fan_in = (k * k * cin) as f32;
        let w = Tensor::randn(&[k, k, cin, cout], (2.0 / fan_in).sqrt(), rng);
        Conv2d {
            w,
            bias: bias.then(|| Tensor::zeros(&[cout])),
            k,
            cin,
            cout,
            stride,
            pad,
            label: format!("conv2d(k={k},s={stride},p={pad},{cin}->{cout})"),
        }
    }

    /// He init followed by projection onto the Lemma-1 constraint set
    /// (the paper's Fig. 4 "constrained / upper-triangular" model).
    pub fn new_submersive(
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        bias: bool,
        rng: &mut Rng,
    ) -> Conv2d {
        let mut conv = Conv2d::new(k, cin, cout, stride, pad, bias, rng);
        // Strengthen the diagonal so the triangular solve is well
        // conditioned from the start, then project.
        for c in 0..cout.min(cin) {
            let idx = conv.widx(pad, pad, c, c);
            conv.w.data_mut()[idx] = 1.0 + conv.w.data()[idx];
        }
        conv.project_submersive();
        conv
    }

    #[inline(always)]
    fn widx(&self, ki: usize, kj: usize, ci: usize, co: usize) -> usize {
        ((ki * self.k + kj) * self.cin + ci) * self.cout + co
    }

    /// Does the vijp elimination decouple across spatial positions?
    /// True iff the only kernel tap congruent to `p (mod s)` below `k`
    /// is `p` itself — guaranteed when `s + p ≥ k`.
    pub fn vijp_fast_path(&self) -> bool {
        self.stride + self.pad >= self.k
    }

    fn out_hw(&self, h: usize, w: usize) -> Result<(usize, usize), LayerError> {
        let (k, s, p) = (self.k, self.stride, self.pad);
        if h + 2 * p < k || w + 2 * p < k {
            return Err(LayerError::Shape {
                layer: self.label.clone(),
                reason: format!("input {h}x{w} smaller than kernel {k} with pad {p}"),
            });
        }
        Ok(((h + 2 * p - k) / s + 1, (w + 2 * p - k) / s + 1))
    }

    /// The [`ConvDims`] geometry for an `[N,H,W,Cin]` input — what the
    /// conv-algo dispatcher keys its autotune cache on.
    fn conv_dims(&self, n: usize, h: usize, w: usize, ho: usize, wo: usize) -> ConvDims {
        ConvDims {
            n,
            h,
            w,
            ho,
            wo,
            cin: self.cin,
            cout: self.cout,
            k: self.k,
            s: self.stride,
            p: self.pad,
        }
    }

    /// Gather one kernel tap's input slice: `buf[a*wo+b, ci] =
    /// x[img, s·a+ki−p, s·b+kj−p, ci]` (zeros outside). Per-tap gathers
    /// keep transient buffers at `H'·W'·Cin` instead of the full im2col
    /// matrix (`k²`-fold larger), which matters for the paper's memory
    /// accounting — see DESIGN.md §9.
    fn gather_tap(
        &self,
        x: &Tensor,
        img: usize,
        ki: usize,
        kj: usize,
        ho: usize,
        wo: usize,
        buf: &mut [f32],
    ) {
        self.gather_tap_rows(x, img, ki, kj, 0..ho, wo, buf);
    }

    /// [`Self::gather_tap`] restricted to output rows `rows` — the unit
    /// of the batch-1 spatial (row-band) parallel paths, where each
    /// worker gathers only its own band into per-worker arena scratch.
    fn gather_tap_rows(
        &self,
        x: &Tensor,
        img: usize,
        ki: usize,
        kj: usize,
        rows: std::ops::Range<usize>,
        wo: usize,
        buf: &mut [f32],
    ) {
        let (h, w, cin) = (x.shape()[1], x.shape()[2], self.cin);
        let (s, p) = (self.stride, self.pad);
        debug_assert_eq!(buf.len(), rows.len() * wo * cin);
        let xd = x.data();
        let x_base = img * h * w * cin;
        for (local, a) in rows.enumerate() {
            let ii = (s * a + ki) as isize - p as isize;
            if ii < 0 || ii as usize >= h {
                buf[local * wo * cin..(local + 1) * wo * cin].fill(0.0);
                continue;
            }
            let xrow = x_base + (ii as usize) * w * cin;
            for b in 0..wo {
                let jj = (s * b + kj) as isize - p as isize;
                let dst = (local * wo + b) * cin;
                if jj >= 0 && (jj as usize) < w {
                    let src = xrow + (jj as usize) * cin;
                    buf[dst..dst + cin].copy_from_slice(&xd[src..src + cin]);
                } else {
                    buf[dst..dst + cin].fill(0.0);
                }
            }
        }
    }

    /// Forward convolution with an arbitrary kernel (shared by `forward`,
    /// `jvp_input` and `jvp_params`, which differ only in kernel/bias),
    /// dispatched through the [`ConvAlgo`] lattice
    /// (`tensor::conv_algo`): forced override → autotune-cache hit →
    /// Direct. All lowerings produce the same values to fp tolerance
    /// (`rust/tests/conv_algo.rs`); Direct is bit-compatible with every
    /// release before the dispatcher existed.
    fn conv_with(&self, x: &Tensor, wdata: &[f32], bias: Option<&Tensor>) -> Tensor {
        assert_eq!(x.rank(), 4, "conv2d expects [N,H,W,C]");
        assert_eq!(x.shape()[3], self.cin, "channel mismatch");
        let (n, h, w_in) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (ho, wo) = self.out_hw(h, w_in).expect("shape checked by caller");
        let dims = self.conv_dims(n, h, w_in, ho, wo);
        match conv_algo::resolve(ConvOp::Conv2dFwd, &dims) {
            ConvAlgo::Im2col => self.conv_with_im2col(x, wdata, bias, ho, wo),
            ConvAlgo::Winograd => self.conv_with_winograd(x, wdata, bias, ho, wo),
            ConvAlgo::Direct => self.conv_with_direct(x, wdata, bias, ho, wo),
        }
    }

    /// Force a specific lowering (calibration and the equivalence
    /// tests go through this; normal callers use the dispatched
    /// [`Self::conv_with`]). Panics if `algo` is inapplicable.
    fn conv_with_algo(
        &self,
        x: &Tensor,
        wdata: &[f32],
        bias: Option<&Tensor>,
        algo: ConvAlgo,
    ) -> Tensor {
        let (n, h, w_in) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (ho, wo) = self.out_hw(h, w_in).expect("shape checked by caller");
        match algo {
            ConvAlgo::Im2col => self.conv_with_im2col(x, wdata, bias, ho, wo),
            ConvAlgo::Winograd => self.conv_with_winograd(x, wdata, bias, ho, wo),
            ConvAlgo::Direct => self.conv_with_direct(x, wdata, bias, ho, wo),
        }
    }

    /// The Direct lowering: per-tap gather + `[H'W',Cin]·[Cin,Cout]`
    /// matmuls. Images are independent, so the batch axis fans out
    /// across the worker pool (each worker leases its own tap buffer
    /// from the arena). A single-image batch has nothing to split on
    /// the batch axis, so it partitions the *output rows* instead
    /// (spatial row-band parallelism): each worker gathers only its
    /// band of a tap and runs the banded GEMM. Output rows are computed
    /// by exactly the serial kernel in the same tap order, so the
    /// banded result is bit-identical to the serial one — and one
    /// region covers all `k²` taps instead of dispatching a
    /// row-parallel GEMM per tap.
    fn conv_with_direct(
        &self,
        x: &Tensor,
        wdata: &[f32],
        bias: Option<&Tensor>,
        ho: usize,
        wo: usize,
    ) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let mut out = Tensor::zeros(&[n, ho, wo, cout]);
        let img_out = ho * wo * cout;
        let spatial = if n == 1 && img_out * k * k >= SPATIAL_MIN_TAP_ELEMS {
            pool::effective_threads(ho)
        } else {
            1
        };
        if spatial > 1 {
            pool::run_records(out.data_mut(), wo * cout, spatial, |rows, chunk| {
                let band = rows.len();
                let mut tap = arena::take(band * wo * cin);
                for ki in 0..k {
                    for kj in 0..k {
                        self.gather_tap_rows(x, 0, ki, kj, rows.clone(), wo, &mut tap);
                        let w_tap =
                            &wdata[(ki * k + kj) * cin * cout..(ki * k + kj + 1) * cin * cout];
                        ops::matmul_into_auto(&tap, w_tap, chunk, band * wo, cin, cout);
                    }
                }
                if let Some(b) = bias {
                    let bd = b.data();
                    for row in chunk.chunks_mut(cout) {
                        for (o, bv) in row.iter_mut().zip(bd) {
                            *o += bv;
                        }
                    }
                }
            });
            return out;
        }
        let workers = pool::effective_threads(n);
        pool::run_records(out.data_mut(), img_out, workers, |imgs, chunk| {
            let mut tap = arena::take(ho * wo * cin);
            for (local, img) in imgs.enumerate() {
                let o_img = &mut chunk[local * img_out..(local + 1) * img_out];
                for ki in 0..k {
                    for kj in 0..k {
                        self.gather_tap(x, img, ki, kj, ho, wo, &mut tap);
                        let w_tap =
                            &wdata[(ki * k + kj) * cin * cout..(ki * k + kj + 1) * cin * cout];
                        ops::matmul_into_auto(&tap, w_tap, o_img, ho * wo, cin, cout);
                    }
                }
                if let Some(b) = bias {
                    let bd = b.data();
                    for row in o_img.chunks_mut(cout) {
                        for (o, bv) in row.iter_mut().zip(bd) {
                            *o += bv;
                        }
                    }
                }
            }
        });
        out
    }

    /// Gather one image's full im2col patch matrix: row `a·W'+b` holds
    /// the `k²·Cin` receptive field of output position `(a, b)`, with
    /// column index `(ki·k + kj)·Cin + ci` — exactly the row-major
    /// flattening of the `[k,k,Cin,Cout]` kernel, so the conv is one
    /// `[H'W', k²Cin]·[k²Cin, Cout]` product. `k²`-fold more transient
    /// scratch than Direct's per-tap gathers (why Direct is the default
    /// and this is an autotune candidate, not a replacement).
    fn gather_patches(&self, x: &Tensor, img: usize, ho: usize, wo: usize, buf: &mut [f32]) {
        let (h, w, cin) = (x.shape()[1], x.shape()[2], self.cin);
        let (k, s, p) = (self.k, self.stride, self.pad);
        let plen = k * k * cin;
        debug_assert_eq!(buf.len(), ho * wo * plen);
        let xd = x.data();
        let x_base = img * h * w * cin;
        for a in 0..ho {
            for b in 0..wo {
                let row = &mut buf[(a * wo + b) * plen..(a * wo + b + 1) * plen];
                for ki in 0..k {
                    let seg = &mut row[ki * k * cin..(ki + 1) * k * cin];
                    let ii = (s * a + ki) as isize - p as isize;
                    if ii < 0 || ii as usize >= h {
                        seg.fill(0.0);
                        continue;
                    }
                    let xrow = x_base + (ii as usize) * w * cin;
                    for kj in 0..k {
                        let dst = &mut seg[kj * cin..(kj + 1) * cin];
                        let jj = (s * b + kj) as isize - p as isize;
                        if jj >= 0 && (jj as usize) < w {
                            let src = xrow + (jj as usize) * cin;
                            dst.copy_from_slice(&xd[src..src + cin]);
                        } else {
                            dst.fill(0.0);
                        }
                    }
                }
            }
        }
    }

    /// The im2col lowering: per image, gather the full patch matrix and
    /// run one `[H'W', k²Cin]·[k²Cin, Cout]` GEMM. The image loop is
    /// serial *on purpose* — the GEMM dispatcher (`select_gemm_algo`)
    /// owns the parallelism, the opposite split from Direct's
    /// batch-parallel fan-out; which wins is exactly what the autotuner
    /// measures.
    fn conv_with_im2col(
        &self,
        x: &Tensor,
        wdata: &[f32],
        bias: Option<&Tensor>,
        ho: usize,
        wo: usize,
    ) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let plen = k * k * cin;
        let pos = ho * wo;
        let mut out = Tensor::zeros(&[n, ho, wo, cout]);
        let img_out = pos * cout;
        let mut patches = arena::take(pos * plen);
        let od = out.data_mut();
        for img in 0..n {
            self.gather_patches(x, img, ho, wo, &mut patches);
            let o_img = &mut od[img * img_out..(img + 1) * img_out];
            ops::matmul_into_auto(&patches, wdata, o_img, pos, plen, cout);
            if let Some(b) = bias {
                let bd = b.data();
                for row in o_img.chunks_mut(cout) {
                    for (o, bv) in row.iter_mut().zip(bd) {
                        *o += bv;
                    }
                }
            }
        }
        out
    }

    /// The F(2×2, 3×3) Winograd lowering (`k == 3 && s == 1` only):
    /// `Y = Aᵀ [ (G g Gᵀ) ⊙ (Bᵀ d B) ] A` per 2×2 output tile. The
    /// element-wise products batch across tiles and channels into 16
    /// `[tiles, Cin]·[Cin, Cout]` GEMMs — 2.25× fewer multiplies than
    /// the 9-tap direct sweep in the large-channel limit. `U = G g Gᵀ`
    /// is computed once per call and shared by all images; `V`/`M` come
    /// from the arena per the declared `workspace_bytes`. Odd `H'`/`W'`
    /// clip the last tile row/column on the write-back.
    fn conv_with_winograd(
        &self,
        x: &Tensor,
        wdata: &[f32],
        bias: Option<&Tensor>,
        ho: usize,
        wo: usize,
    ) -> Tensor {
        assert!(
            self.k == 3 && self.stride == 1,
            "Winograd F(2x2,3x3) requires k=3, s=1"
        );
        let (n, h, w_in) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (cin, cout, p) = (self.cin, self.cout, self.pad);
        let (th, tw) = (ho.div_ceil(2), wo.div_ceil(2));
        let tiles = th * tw;
        let mut out = Tensor::zeros(&[n, ho, wo, cout]);
        // U[xy] ∈ [Cin, Cout] for each of the 16 transform positions.
        let mut u = arena::take(16 * cin * cout);
        for ci in 0..cin {
            for co in 0..cout {
                let mut g = [[0.0f32; 3]; 3];
                for (i, row) in g.iter_mut().enumerate() {
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = wdata[((i * 3 + j) * cin + ci) * cout + co];
                    }
                }
                // t = G·g (4×3), then U = t·Gᵀ (4×4).
                let mut t = [[0.0f32; 3]; 4];
                for (i, trow) in t.iter_mut().enumerate() {
                    for (j, tv) in trow.iter_mut().enumerate() {
                        *tv = (0..3).map(|m| WINO_G[i][m] * g[m][j]).sum();
                    }
                }
                for (i, trow) in t.iter().enumerate() {
                    for j in 0..4 {
                        let uv: f32 = (0..3).map(|m| trow[m] * WINO_G[j][m]).sum();
                        u[(i * 4 + j) * cin * cout + ci * cout + co] = uv;
                    }
                }
            }
        }
        let mut v = arena::take(16 * tiles * cin);
        let mut m = arena::take(16 * tiles * cout);
        let xd = x.data();
        let img_in = h * w_in * cin;
        let img_out = ho * wo * cout;
        let od = out.data_mut();
        let bd = bias.map(|b| b.data());
        for img in 0..n {
            // V[xy] ∈ [tiles, Cin]: V = Bᵀ d B per (tile, channel), d
            // the zero-padded 4×4 input patch at (2·ta−p, 2·tb−p).
            for ta in 0..th {
                for tb in 0..tw {
                    let tile = ta * tw + tb;
                    for ci in 0..cin {
                        let mut d = [[0.0f32; 4]; 4];
                        for (i, drow) in d.iter_mut().enumerate() {
                            let ii = (2 * ta + i) as isize - p as isize;
                            if ii < 0 || ii as usize >= h {
                                continue;
                            }
                            let xrow = img * img_in + (ii as usize) * w_in * cin;
                            for (j, dv) in drow.iter_mut().enumerate() {
                                let jj = (2 * tb + j) as isize - p as isize;
                                if jj >= 0 && (jj as usize) < w_in {
                                    *dv = xd[xrow + (jj as usize) * cin + ci];
                                }
                            }
                        }
                        // Bᵀ·d then ·B — both are ±1 row/column picks.
                        let mut t = [[0.0f32; 4]; 4];
                        for j in 0..4 {
                            t[0][j] = d[0][j] - d[2][j];
                            t[1][j] = d[1][j] + d[2][j];
                            t[2][j] = d[2][j] - d[1][j];
                            t[3][j] = d[1][j] - d[3][j];
                        }
                        for (i, trow) in t.iter().enumerate() {
                            let vals = [
                                trow[0] - trow[2],
                                trow[1] + trow[2],
                                trow[2] - trow[1],
                                trow[1] - trow[3],
                            ];
                            for (j, &val) in vals.iter().enumerate() {
                                v[(i * 4 + j) * tiles * cin + tile * cin + ci] = val;
                            }
                        }
                    }
                }
            }
            // M[xy] = V[xy]·U[xy] — the GEMM kernels accumulate, so
            // zero M first.
            m.fill(0.0);
            for xy in 0..16 {
                ops::matmul_into_auto(
                    &v[xy * tiles * cin..(xy + 1) * tiles * cin],
                    &u[xy * cin * cout..(xy + 1) * cin * cout],
                    &mut m[xy * tiles * cout..(xy + 1) * tiles * cout],
                    tiles,
                    cin,
                    cout,
                );
            }
            // Y = Aᵀ M A per tile: 2×2 outputs, clipped at the edges.
            let o_img = &mut od[img * img_out..(img + 1) * img_out];
            for ta in 0..th {
                for tb in 0..tw {
                    let tile = ta * tw + tb;
                    for co in 0..cout {
                        let mm =
                            |i: usize, j: usize| m[(i * 4 + j) * tiles * cout + tile * cout + co];
                        let mut t2 = [[0.0f32; 4]; 2];
                        for j in 0..4 {
                            t2[0][j] = mm(0, j) + mm(1, j) + mm(2, j);
                            t2[1][j] = mm(1, j) - mm(2, j) - mm(3, j);
                        }
                        for (dy, t2row) in t2.iter().enumerate() {
                            let oa = 2 * ta + dy;
                            if oa >= ho {
                                continue;
                            }
                            let y0 = t2row[0] + t2row[1] + t2row[2];
                            let y1 = t2row[1] - t2row[2] - t2row[3];
                            for (dx, yv) in [y0, y1].into_iter().enumerate() {
                                let ob = 2 * tb + dx;
                                if ob >= wo {
                                    continue;
                                }
                                o_img[(oa * wo + ob) * cout + co] =
                                    yv + bd.map_or(0.0, |b| b[co]);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The im2col lowering of the weight gradient: per image,
    /// `dw += patchesᵀ·g` as one `[k²Cin, H'W']·[H'W', Cout]` GEMM,
    /// accumulated serially across images (the GEMM dispatcher owns the
    /// parallelism — the transposed analogue of
    /// [`Self::conv_with_im2col`]).
    fn vjp_params_dw_im2col(
        &self,
        x: &Tensor,
        grad_out: &Tensor,
        ho: usize,
        wo: usize,
    ) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let plen = k * k * cin;
        let pos = ho * wo;
        let gd = grad_out.data();
        let mut dw = Tensor::zeros(&[k, k, cin, cout]);
        let mut patches = arena::take(pos * plen);
        for img in 0..n {
            self.gather_patches(x, img, ho, wo, &mut patches);
            ops::matmul_tn_into_auto(
                &patches,
                &gd[img * pos * cout..(img + 1) * pos * cout],
                dw.data_mut(),
                pos,
                plen,
                cout,
            );
        }
        dw
    }

    /// The Direct lowering of the weight gradient — an image-parallel
    /// reduction: each worker folds its contiguous image range into a
    /// private dw accumulator; partials merge in worker order, so a
    /// fixed thread count is bit-deterministic. The accumulators come
    /// from the arena so they are tracker-visible and recycled (no
    /// per-call heap churn). Single-image batches fall back to spatial
    /// row-band partitioning: each worker contracts its band of output
    /// rows against its band of the tap gather. Like the batch
    /// reduction, the band merge reorders the position sum, so batch-1
    /// parallel dw matches serial to fp tolerance (and is bit-stable at
    /// a fixed thread count).
    fn vjp_params_dw_direct(
        &self,
        x: &Tensor,
        grad_out: &Tensor,
        ho: usize,
        wo: usize,
    ) -> Tensor {
        let n = x.shape()[0];
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let wlen = k * k * cin * cout;
        let gd = grad_out.data();
        let img_g = ho * wo * cout;
        fn merge_add(a: &mut arena::Scratch, b: arena::Scratch) {
            for (av, bv) in a.iter_mut().zip(b.iter()) {
                *av += *bv;
            }
        }
        let workers = pool::effective_threads(n);
        let spatial = if n == 1 && ho * wo * cout * k * k >= SPATIAL_MIN_TAP_ELEMS {
            pool::effective_threads(ho)
        } else {
            1
        };
        let acc = if spatial > 1 {
            pool::run_reduce(
                ho,
                spatial,
                || arena::take_zeroed(wlen),
                |rows, acc| {
                    let g_band = &gd[rows.start * wo * cout..rows.end * wo * cout];
                    self.accumulate_dw_band(x, 0, rows, wo, g_band, acc);
                },
                merge_add,
            )
        } else {
            pool::run_reduce(
                n,
                workers,
                || arena::take_zeroed(wlen),
                |imgs, acc| {
                    for img in imgs {
                        let g_img = &gd[img * img_g..(img + 1) * img_g];
                        self.accumulate_dw_band(x, img, 0..ho, wo, g_img, acc);
                    }
                },
                merge_add,
            )
        };
        let mut dw = Tensor::zeros(&[k, k, cin, cout]);
        dw.data_mut().copy_from_slice(&acc);
        dw
    }

    /// Calibrate this layer's autotunable conv ops (forward and
    /// `vjp_params`) for input `x`: time every applicable [`ConvAlgo`]
    /// candidate and [`conv_algo::record`] the winner in the
    /// process-wide cache (persisted when a cache path is configured).
    /// Ops whose key is already cached return `cached: true` without
    /// re-timing — a warm cache makes calibration free. This is the
    /// *only* Conv2d path that turns wall-clock into dispatch
    /// decisions; `forward`/`vjp_params` themselves never time anything
    /// (the determinism contract in `tensor::conv_algo`).
    pub fn autotune(&self, x: &Tensor) -> Vec<conv_algo::TuneOutcome> {
        self.autotune_with(x, 1, 3)
    }

    /// [`Self::autotune`] with explicit bench warmup/iteration counts.
    pub fn autotune_with(
        &self,
        x: &Tensor,
        warmup: usize,
        iters: usize,
    ) -> Vec<conv_algo::TuneOutcome> {
        let (n, h, w_in) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (ho, wo) = self.out_hw(h, w_in).expect("autotune needs a valid input shape");
        let dims = self.conv_dims(n, h, w_in, ho, wo);
        let mut outcomes = Vec::new();
        for op in [ConvOp::Conv2dFwd, ConvOp::Conv2dVjpParams] {
            if let Some((algo, ms)) = conv_algo::cached(op, &dims) {
                outcomes.push(conv_algo::TuneOutcome {
                    key: conv_algo::key(op, &dims),
                    algo,
                    best_ms: ms,
                    candidates: Vec::new(),
                    cached: true,
                });
                continue;
            }
            let g = Tensor::full(&[n, ho, wo, self.cout], 0.5);
            let mut cands = Vec::new();
            for algo in conv_algo::candidates(op, &dims) {
                let stats = crate::util::timer::bench(warmup, iters, || match op {
                    ConvOp::Conv2dFwd => {
                        let _ = self.conv_with_algo(x, self.w.data(), self.bias.as_ref(), algo);
                    }
                    _ => {
                        let _ = if algo == ConvAlgo::Im2col {
                            self.vjp_params_dw_im2col(x, &g, ho, wo)
                        } else {
                            self.vjp_params_dw_direct(x, &g, ho, wo)
                        };
                    }
                });
                cands.push((algo, stats.median_ms()));
            }
            let &(best, best_ms) = cands
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .expect("Direct is always a candidate");
            conv_algo::record(op, &dims, best, best_ms);
            outcomes.push(conv_algo::TuneOutcome {
                key: conv_algo::key(op, &dims),
                algo: best,
                best_ms,
                candidates: cands,
                cached: false,
            });
        }
        outcomes
    }

    /// Transpose convolution (Eq. 12/13): scatter `g · wᵀ` back to input
    /// positions. Shared by `vjp_input` and the vijp residual term.
    fn transpose_conv(&self, g: &Tensor, in_shape: &[usize]) -> Tensor {
        let (n, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
        let (ho, wo) = (g.shape()[1], g.shape()[2]);
        let (k, s, p, cin, cout) = (self.k, self.stride, self.pad, self.cin, self.cout);
        // Per tap: tmp[H'W',Cin] = g·w_tapᵀ, scattered back to input
        // positions (the adjoint of the forward gather). Every tap weight
        // is transposed once into [Cout,Cin] — so the matmul runs the
        // vectorized AXPY kernel instead of length-Cout dot products
        // (§Perf iteration 1: 2.4x faster vjp_input) — and shared
        // read-only by the image-parallel workers (§Perf iteration 5).
        let mut out = Tensor::zeros(&[n, h, w, cin]);
        let mut wt_all = arena::take(k * k * cout * cin);
        {
            let wd = self.w.data();
            for t in 0..k * k {
                let w_tap = &wd[t * cin * cout..(t + 1) * cin * cout];
                let dst = &mut wt_all[t * cout * cin..(t + 1) * cout * cin];
                for ci in 0..cin {
                    for co in 0..cout {
                        dst[co * cin + ci] = w_tap[ci * cout + co];
                    }
                }
            }
        }
        let wt: &[f32] = &wt_all;
        let gd = g.data();
        let img_in = h * w * cin;
        let img_g = ho * wo * cout;
        // Single-image batches fall back to spatial parallelism over
        // **input-row bands** (banded accumulation): the scatter's tap
        // overlap means output (= input-gradient) rows, not g rows, are
        // the disjoint unit. Each worker owns a contiguous band of input
        // rows and, per tap, replays exactly the g rows a with
        // `s·a + ki − p` inside its band — the same (ki,kj,a,b) visit
        // order as the serial scatter restricted to the band, and the
        // banded GEMM computes tmp rows with the serial kernels' per-row
        // arithmetic, so the result is bit-identical to the serial path.
        let spatial = if n == 1 && ho * wo * cout * k * k >= SPATIAL_MIN_TAP_ELEMS {
            pool::effective_threads(h)
        } else {
            1
        };
        if spatial > 1 {
            pool::run_records(out.data_mut(), w * cin, spatial, |rows, chunk| {
                let band = rows.len();
                // Any tap maps at most this many g rows into the band.
                let max_rows = ((band - 1) / s + 1).min(ho);
                let mut tmp = arena::take(max_rows * wo * cin);
                for ki in 0..k {
                    for kj in 0..k {
                        // a-range with ii = s·a + ki − p in [rows.start,
                        // rows.end): solve the band bounds for a.
                        let lo = rows.start as isize + p as isize - ki as isize;
                        let hi = rows.end as isize - 1 + p as isize - ki as isize;
                        if hi < 0 {
                            continue;
                        }
                        let a_lo = if lo <= 0 {
                            0
                        } else {
                            (lo as usize + s - 1) / s
                        };
                        let a_hi = (hi as usize / s).min(ho - 1);
                        if a_lo > a_hi {
                            continue;
                        }
                        let rows_g = a_hi - a_lo + 1;
                        let t = &mut tmp[..rows_g * wo * cin];
                        t.fill(0.0);
                        ops::matmul_into_auto(
                            &gd[a_lo * wo * cout..(a_hi + 1) * wo * cout],
                            &wt[(ki * k + kj) * cout * cin..(ki * k + kj + 1) * cout * cin],
                            t,
                            rows_g * wo,
                            cout,
                            cin,
                        );
                        for (local_a, a) in (a_lo..=a_hi).enumerate() {
                            // s·a ≥ rows.start + p − ki by construction,
                            // so ii is in-band (and in-bounds).
                            let ii = s * a + ki - p;
                            let dst_row = (ii - rows.start) * w * cin;
                            for b in 0..wo {
                                let jj = (s * b + kj) as isize - p as isize;
                                if jj < 0 || jj as usize >= w {
                                    continue;
                                }
                                let src = (local_a * wo + b) * cin;
                                let dst = dst_row + (jj as usize) * cin;
                                for c in 0..cin {
                                    chunk[dst + c] += t[src + c];
                                }
                            }
                        }
                    }
                }
            });
            return out;
        }
        let workers = pool::effective_threads(n);
        pool::run_records(out.data_mut(), img_in, workers, |imgs, chunk| {
            let mut tmp = arena::take(ho * wo * cin);
            for (local, img) in imgs.enumerate() {
                let g_img = &gd[img * img_g..(img + 1) * img_g];
                let o_img = &mut chunk[local * img_in..(local + 1) * img_in];
                for ki in 0..k {
                    for kj in 0..k {
                        tmp.fill(0.0);
                        ops::matmul_into_auto(
                            g_img,
                            &wt[(ki * k + kj) * cout * cin..(ki * k + kj + 1) * cout * cin],
                            &mut tmp,
                            ho * wo,
                            cout,
                            cin,
                        );
                        for a in 0..ho {
                            let ii = (s * a + ki) as isize - p as isize;
                            if ii < 0 || ii as usize >= h {
                                continue;
                            }
                            for b in 0..wo {
                                let jj = (s * b + kj) as isize - p as isize;
                                if jj < 0 || jj as usize >= w {
                                    continue;
                                }
                                let src = (a * wo + b) * cin;
                                let dst = ((ii as usize) * w + jj as usize) * cin;
                                for c in 0..cin {
                                    o_img[dst + c] += tmp[src + c];
                                }
                            }
                        }
                    }
                }
            }
        });
        out
    }

    /// The vijp elimination (proof of Lemma 1 / Alg. 2): recover the output
    /// cotangent `h'` from the input cotangent `h`, where
    /// `h = TransposeConv(h', w)`. Fast path (no spatial coupling) when
    /// `s + p ≥ k`; otherwise a lexicographic sweep over (a, b) whose
    /// dependencies point only to already-eliminated positions (a2 ≤ a,
    /// b2 ≤ b — guaranteed by `s > p`).
    fn vijp_eliminate(&self, h: &Tensor, out_shape: &[usize]) -> Result<Tensor, LayerError> {
        if let Submersivity::NonSubmersive { reason, .. } = self.submersivity() {
            return Err(LayerError::NotSubmersive {
                layer: self.label.clone(),
                reason,
            });
        }
        let (n, hh, ww) = (h.shape()[0], h.shape()[1], h.shape()[2]);
        let (ho, wo, cout) = (out_shape[1], out_shape[2], out_shape[3]);
        let (s, cin) = (self.stride, self.cin);
        // Lemma 1 (i): every pivot row s·a must be a valid input index.
        if s * (ho - 1) >= hh || s * (wo - 1) >= ww {
            return Err(LayerError::NotSubmersive {
                layer: self.label.clone(),
                reason: format!("spatial bound violated: n={hh} !> s(n'-1)={}", s * (ho - 1)),
            });
        }
        let mut hp = Tensor::zeros(&[n, ho, wo, cout]);
        let hd = h.data();
        let img_h = hh * ww * cin;
        let img_hp = ho * wo * cout;
        let fast = self.vijp_fast_path();
        // Batch-1 spatial fast path: with no spatial coupling (Alg. 2)
        // every output position is solved independently, so a
        // single-image batch partitions the *output rows* into bands —
        // each worker gathers its band's pivot rows and runs the
        // identical per-position triangular solve, making the banded
        // result bit-identical to the serial one. The wavefront regime
        // stays serial at batch 1 (its positions couple). Same
        // minimum-work floor philosophy as the other row-band paths.
        let spatial = if n == 1 && fast && img_hp * self.k * self.k >= SPATIAL_MIN_TAP_ELEMS {
            pool::effective_threads(ho)
        } else {
            1
        };
        if spatial > 1 {
            let ranges = pool::chunk_ranges(ho, spatial);
            let spans: Vec<std::ops::Range<usize>> = ranges
                .iter()
                .map(|r| r.start * wo * cout..r.end * wo * cout)
                .collect();
            pool::run_spans(hp.data_mut(), &spans, spatial, |band, chunk| {
                let rows = ranges[band].clone();
                let mut cols = arena::take(cout * rows.len() * wo);
                self.vijp_rows_fast(hd, chunk, &mut cols, ww, rows, wo);
            });
            return Ok(hp);
        }
        // Images are independent in both regimes (even the wavefront only
        // couples positions *within* an image), so the batch axis fans
        // out across the worker pool.
        let workers = pool::effective_threads(n);
        pool::run_records(hp.data_mut(), img_hp, workers, |imgs, chunk| {
            let mut cols = if fast {
                Some(arena::take(cout * ho * wo))
            } else {
                None
            };
            for (local, img) in imgs.enumerate() {
                let h_img = &hd[img * img_h..(img + 1) * img_h];
                let hp_img = &mut chunk[local * img_hp..(local + 1) * img_hp];
                match cols.as_mut() {
                    Some(cols) => self.vijp_img_fast(h_img, hp_img, cols, ww, ho, wo),
                    None => self.vijp_img_wavefront(h_img, hp_img, ww, ho, wo),
                }
            }
        });
        Ok(hp)
    }

    /// Fully parallel vijp (Alg. 2) for one image: no spatial coupling, so
    /// the channel-triangular solve vectorizes across all positions — the
    /// same schedule the Pallas kernel uses (§Perf iter. 4). `cols` is the
    /// worker's `[Cout, H'W']` channel-major workspace.
    fn vijp_img_fast(
        &self,
        h_img: &[f32],
        hp_img: &mut [f32],
        cols: &mut [f32],
        ww: usize,
        ho: usize,
        wo: usize,
    ) {
        self.vijp_rows_fast(h_img, hp_img, cols, ww, 0..ho, wo);
    }

    /// [`Self::vijp_img_fast`] restricted to output rows `rows` — the
    /// unit of the batch-1 spatial fast path. `hp_band` is the
    /// `[rows·W', Cout]` output slice for the band, `cols` its
    /// `[Cout, rows·W']` channel-major workspace. Each position's
    /// gather/solve/scatter arithmetic is independent of the banding, so
    /// any band partition is bit-identical to the full-image call.
    fn vijp_rows_fast(
        &self,
        h_img: &[f32],
        hp_band: &mut [f32],
        cols: &mut [f32],
        ww: usize,
        rows: std::ops::Range<usize>,
        wo: usize,
    ) {
        let (k, s, p, cin, cout) = (self.k, self.stride, self.pad, self.cin, self.cout);
        let wd = self.w.data();
        let npos = rows.len() * wo;
        let hp_img = hp_band;
        // Gather pivot rows hs[a,b,co] = h[s·a, s·b, co].
        for (local, a) in rows.enumerate() {
            for b in 0..wo {
                let src = ((s * a) * ww + s * b) * cin;
                let pos = local * wo + b;
                for co in 0..cout {
                    cols[co * npos + pos] = h_img[src + co];
                }
            }
        }
        // Triangular solve, vectorized over positions.
        for co in 0..cout {
            let (done, rest) = cols.split_at_mut(co * npos);
            let cur = &mut rest[..npos];
            for c2 in 0..co {
                let wv = wd[((p * k + p) * cin + co) * cout + c2];
                if wv == 0.0 {
                    continue;
                }
                let prev = &done[c2 * npos..(c2 + 1) * npos];
                for (cv, pv) in cur.iter_mut().zip(prev) {
                    *cv -= wv * pv;
                }
            }
            let diag = wd[((p * k + p) * cin + co) * cout + co];
            let inv = 1.0 / diag;
            for cv in cur.iter_mut() {
                *cv *= inv;
            }
        }
        // Scatter back to channel-last layout.
        for pos in 0..npos {
            let dst = pos * cout;
            for co in 0..cout {
                hp_img[dst + co] = cols[co * npos + pos];
            }
        }
    }

    /// Accumulate `dw[ki,kj] += tap(rows)ᵀ · g(rows)` for one image's
    /// output-row band — the shared inner kernel of both `vjp_params`
    /// reductions (batch-parallel over images, batch-1 spatial over row
    /// bands). `g_band` is the `[rows·W', Cout]` slice of the output
    /// gradient matching `rows`; `acc` is the `[k,k,Cin,Cout]` flat
    /// accumulator.
    fn accumulate_dw_band(
        &self,
        x: &Tensor,
        img: usize,
        rows: std::ops::Range<usize>,
        wo: usize,
        g_band: &[f32],
        acc: &mut [f32],
    ) {
        let (k, cin, cout) = (self.k, self.cin, self.cout);
        let mut tap = arena::take(rows.len() * wo * cin);
        for ki in 0..k {
            for kj in 0..k {
                self.gather_tap_rows(x, img, ki, kj, rows.clone(), wo, &mut tap);
                ops::matmul_tn_into_auto(
                    &tap,
                    g_band,
                    &mut acc[(ki * k + kj) * cin * cout..(ki * k + kj + 1) * cin * cout],
                    rows.len() * wo,
                    cin,
                    cout,
                );
            }
        }
    }

    /// Spatially coupled vijp for one image (`s + p < k`): lexicographic
    /// wavefront whose dependencies point only to already-eliminated
    /// positions (a2 ≤ a, b2 ≤ b — guaranteed by `s > p`).
    fn vijp_img_wavefront(
        &self,
        h_img: &[f32],
        hp_img: &mut [f32],
        ww: usize,
        ho: usize,
        wo: usize,
    ) {
        let (k, s, p, cin, cout) = (self.k, self.stride, self.pad, self.cin, self.cout);
        let wd = self.w.data();
        // Max spatial back-reach of the elimination, in output positions.
        let reach = (k - 1 - p.min(k - 1)) / s; // floor((k-1-p)/s)
        for a in 0..ho {
            for b in 0..wo {
                for co in 0..cout {
                    // Pivot equation: h[s·a, s·b, channel=co].
                    let mut acc = h_img[((s * a) * ww + s * b) * cin + co];
                    // Subtract contributions of already-solved h' entries.
                    let a2lo = a.saturating_sub(reach);
                    let b2lo = b.saturating_sub(reach);
                    for a2 in a2lo..=a {
                        let ki = s * (a - a2) + p;
                        if ki >= k {
                            continue;
                        }
                        for b2 in b2lo..=b {
                            let kj = s * (b - b2) + p;
                            if kj >= k {
                                continue;
                            }
                            let last = a2 == a && b2 == b;
                            // Strictly-earlier positions contribute all
                            // channels; the pivot position contributes
                            // channels below the diagonal only.
                            let c_end = if last { co } else { cout };
                            let hprow = (a2 * wo + b2) * cout;
                            let wrow = ((ki * k + kj) * cin + co) * cout;
                            let mut sub = 0.0f32;
                            for c2 in 0..c_end {
                                sub += wd[wrow + c2] * hp_img[hprow + c2];
                            }
                            acc -= sub;
                        }
                    }
                    let diag = wd[((p * k + p) * cin + co) * cout + co];
                    hp_img[(a * wo + b) * cout + co] = acc / diag;
                }
            }
        }
    }
}

impl Layer for Conv2d {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        if in_shape.len() != 4 || in_shape[3] != self.cin {
            return Err(LayerError::Shape {
                layer: self.label.clone(),
                reason: format!("expected [N,H,W,{}], got {in_shape:?}", self.cin),
            });
        }
        let (ho, wo) = self.out_hw(in_shape[1], in_shape[2])?;
        Ok(vec![in_shape[0], ho, wo, self.cout])
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        let y = self.conv_with(x, self.w.data(), self.bias.as_ref());
        let res = Residual {
            in_shape: x.shape().to_vec(),
            kind: match kind {
                // Backprop must keep the full input for ∂x'/∂w.
                ResidualKind::Full => ResidualData::Input(x.clone()),
                // The input-vjp of a convolution needs only the weights —
                // Moonwalk Phase I stores *nothing* (paper §4.3).
                ResidualKind::Minimal => ResidualData::None,
            },
        };
        (y, res)
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        self.transpose_conv(grad_out, &res.in_shape)
    }

    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor> {
        let (n, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (ho, wo) = self.out_hw(h, w).expect("shapes validated");
        let dims = self.conv_dims(n, h, w, ho, wo);
        let dw = match conv_algo::resolve(ConvOp::Conv2dVjpParams, &dims) {
            ConvAlgo::Im2col => self.vjp_params_dw_im2col(x, grad_out, ho, wo),
            _ => self.vjp_params_dw_direct(x, grad_out, ho, wo),
        };
        let mut grads = vec![dw];
        if self.bias.is_some() {
            let mut db = Tensor::zeros(&[self.cout]);
            for chunk in grad_out.data().chunks(self.cout) {
                for (d, g) in db.data_mut().iter_mut().zip(chunk) {
                    *d += g;
                }
            }
            grads.push(db);
        }
        grads
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        let out_shape = self.out_shape(&res.in_shape)?;
        self.vijp_eliminate(h_in, &out_shape)
    }

    fn jvp_input(&self, _x: &Tensor, u: &Tensor) -> Tensor {
        // The convolution is linear in its input.
        self.conv_with(u, self.w.data(), None)
    }

    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor {
        let dw = &dparams[0];
        let mut out = self.conv_with(x, dw.data(), None);
        if self.bias.is_some() {
            let db = &dparams[1];
            for chunk in out.data_mut().chunks_mut(self.cout) {
                for (o, b) in chunk.iter_mut().zip(db.data()) {
                    *o += b;
                }
            }
        }
        out
    }

    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError> {
        // Only the 1×1 / s=1 / p=0 / Cin=Cout triangular configuration is
        // exactly invertible (per-pixel triangular solve); used by the
        // RevBackprop baseline.
        if !(self.k == 1 && self.stride == 1 && self.pad == 0 && self.cin == self.cout) {
            return Err(LayerError::NotInvertible {
                layer: self.label.clone(),
                reason: "only k=1, s=1, p=0, Cin=Cout convolutions are invertible".into(),
            });
        }
        let c = self.cin;
        let wd = self.w.data(); // [1,1,c,c] => [c,c] row ci, col co
        for co in 0..c {
            if wd[co * c + co].abs() < 1e-8 {
                return Err(LayerError::NotInvertible {
                    layer: self.label.clone(),
                    reason: format!("zero diagonal at channel {co}"),
                });
            }
        }
        let mut x = Tensor::zeros(y.shape());
        let yd = y.data();
        let xd = x.data_mut();
        let bias: Option<&[f32]> = self.bias.as_ref().map(|b| b.data());
        // y[c'] = Σ_{ci} w[ci,c'] x[ci] (+ b); triangular (w[ci,c']=0, ci<c')
        // ⇒ back-substitute from the last channel.
        for pix in 0..y.len() / c {
            let yrow = &yd[pix * c..(pix + 1) * c];
            let xrow = &mut xd[pix * c..(pix + 1) * c];
            for co in (0..c).rev() {
                let mut acc = yrow[co] - bias.map_or(0.0, |b| b[co]);
                for ci in co + 1..c {
                    acc -= wd[ci * c + co] * xrow[ci];
                }
                xrow[co] = acc / wd[co * c + co];
            }
        }
        Ok(x)
    }

    fn submersivity(&self) -> Submersivity {
        let (k, s, p) = (self.k, self.stride, self.pad);
        // Lemma 1 (i): spatial bounds (the n > s(n'−1) part is checked at
        // vijp time against the concrete input shape).
        if k <= p {
            return Submersivity::NonSubmersive {
                reason: format!("requires k > p (k={k}, p={p})"),
                fragmental_ok: false,
            };
        }
        if s <= p {
            return Submersivity::NonSubmersive {
                reason: format!("requires s > p (s={s}, p={p})"),
                fragmental_ok: false, // 2-D fragmental not implemented
            };
        }
        if self.cout > self.cin {
            return Submersivity::NonSubmersive {
                reason: format!(
                    "channel triangularity needs Cout ≤ Cin ({} > {})",
                    self.cout, self.cin
                ),
                fragmental_ok: false,
            };
        }
        // Lemma 1 (ii)+(iii): triangularity and diagonal support of the
        // pivot tap w[p,p,·,·].
        let wd = self.w.data();
        for co in 0..self.cout {
            let diag = wd[((p * k + p) * self.cin + co) * self.cout + co];
            if diag.abs() < 1e-8 {
                return Submersivity::NonSubmersive {
                    reason: format!("zero diagonal tap w[p,p,{co},{co}]"),
                    fragmental_ok: false,
                };
            }
            for ci in 0..co {
                let v = wd[((p * k + p) * self.cin + ci) * self.cout + co];
                if v != 0.0 {
                    return Submersivity::NonSubmersive {
                        reason: format!(
                            "triangularity violated: w[p,p,{ci},{co}] = {v} ≠ 0"
                        ),
                        fragmental_ok: false,
                    };
                }
            }
        }
        Submersivity::Submersive {
            fast_path: self.vijp_fast_path(),
        }
    }

    fn params(&self) -> Vec<&Tensor> {
        match &self.bias {
            Some(b) => vec![&self.w, b],
            None => vec![&self.w],
        }
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        match &mut self.bias {
            Some(b) => vec![&mut self.w, b],
            None => vec![&mut self.w],
        }
    }

    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        match self.out_shape(in_shape) {
            Ok(s) => {
                2.0 * (self.k * self.k * self.cin) as f64
                    * s.iter().product::<usize>() as f64
            }
            Err(_) => 0.0,
        }
    }

    /// Project onto the Lemma-1 constraint set: zero the sub-triangular
    /// entries of the pivot tap and keep the diagonal away from zero
    /// (§6.4 "constrained convolutions").
    fn project_submersive(&mut self) {
        let (k, p, cin, cout) = (self.k, self.pad, self.cin, self.cout);
        if k <= p {
            return; // structurally non-submersive; nothing to project
        }
        let wd = self.w.data_mut();
        for co in 0..cout {
            for ci in 0..co.min(cin) {
                wd[((p * k + p) * cin + ci) * cout + co] = 0.0;
            }
            if co < cin {
                let idx = ((p * k + p) * cin + co) * cout + co;
                let d = wd[idx];
                if d.abs() < DIAG_FLOOR {
                    wd[idx] = if d >= 0.0 { DIAG_FLOOR } else { -DIAG_FLOOR };
                }
            }
        }
    }

    fn conv_tune_key(&self, in_shape: &[usize]) -> Option<String> {
        if in_shape.len() != 4 || in_shape[3] != self.cin {
            return None;
        }
        let (ho, wo) = self.out_hw(in_shape[1], in_shape[2]).ok()?;
        Some(conv_algo::key(
            ConvOp::Conv2dFwd,
            &self.conv_dims(in_shape[0], in_shape[1], in_shape[2], ho, wo),
        ))
    }

    fn conv_autotune(&self, x: &Tensor) -> Vec<conv_algo::TuneOutcome> {
        self.autotune(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;
    use crate::tensor::assert_close;

    fn sub_conv(k: usize, s: usize, p: usize, cin: usize, cout: usize, seed: u64) -> Conv2d {
        let mut rng = Rng::new(seed);
        Conv2d::new_submersive(k, cin, cout, s, p, false, &mut rng)
    }

    fn input(n: usize, h: usize, w: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed ^ 0xdead);
        Tensor::randn(&[n, h, w, c], 1.0, &mut rng)
    }

    #[test]
    fn forward_known_values() {
        // 1x1 conv is a per-pixel matmul — verify by hand.
        let mut rng = Rng::new(0);
        let mut conv = Conv2d::new(1, 2, 1, 1, 0, false, &mut rng);
        conv.w.data_mut().copy_from_slice(&[2.0, 3.0]);
        let x = Tensor::from_vec(vec![1.0, 10.0, 2.0, 20.0], &[1, 1, 2, 2]);
        let y = conv.forward(&x);
        assert_eq!(y.shape(), &[1, 1, 2, 1]);
        assert_eq!(y.data(), &[32.0, 64.0]);
    }

    #[test]
    fn forward_padding_and_stride_shape() {
        let conv = sub_conv(3, 2, 1, 4, 4, 1);
        let x = input(2, 9, 9, 4, 1);
        let y = conv.forward(&x);
        // (9 + 2 - 3)/2 + 1 = 5
        assert_eq!(y.shape(), &[2, 5, 5, 4]);
    }

    #[test]
    fn vjp_input_adjoint() {
        let conv = sub_conv(3, 2, 1, 3, 3, 2);
        let x = input(2, 7, 7, 3, 2);
        testutil::check_vjp_input_against_fd(&conv, &x, 42, 1e-3);
    }

    #[test]
    fn vjp_params_adjoint() {
        let mut rng = Rng::new(5);
        let conv = Conv2d::new(3, 3, 5, 2, 1, true, &mut rng);
        let x = input(2, 6, 6, 3, 5);
        testutil::check_vjp_params_adjoint(&conv, &x, 43, 1e-3);
    }

    #[test]
    fn vijp_right_inverse_fast_path() {
        // k=3, s=2, p=1 — the paper's fully-parallel configuration.
        let conv = sub_conv(3, 2, 1, 4, 4, 3);
        assert!(conv.vijp_fast_path());
        let x = input(2, 9, 9, 4, 3);
        testutil::check_vijp_right_inverse(&conv, &x, 44, 2e-3);
    }

    #[test]
    fn vijp_right_inverse_channel_reducing() {
        // Cout < Cin exercises the non-square channel solve.
        let conv = sub_conv(3, 2, 1, 6, 3, 6);
        let x = input(1, 9, 9, 6, 6);
        testutil::check_vijp_right_inverse(&conv, &x, 45, 2e-3);
    }

    #[test]
    fn vijp_right_inverse_spatially_coupled() {
        // k=5, s=3, p=2: s+p=5 ≥ k → still fast; use k=5,s=3,p=1: s+p=4 < 5
        // → tap j=p and j=p+s=4 both < k ⇒ real spatial coupling.
        let conv = sub_conv(5, 3, 1, 3, 3, 7);
        assert!(!conv.vijp_fast_path());
        assert!(conv.submersivity().is_submersive());
        let x = input(2, 13, 13, 3, 7);
        testutil::check_vijp_right_inverse(&conv, &x, 46, 2e-3);
    }

    #[test]
    fn vijp_stride1_same_pad_rejected() {
        // s=1, p=1 violates s > p — the Fig. 3 non-submersive setting.
        let mut rng = Rng::new(8);
        let conv = Conv2d::new(3, 3, 3, 1, 1, false, &mut rng);
        assert!(!conv.submersivity().is_submersive());
        let x = input(1, 6, 6, 3, 8);
        let (_, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let h = input(1, 6, 6, 3, 9);
        assert!(matches!(
            conv.vijp(&res, &h),
            Err(LayerError::NotSubmersive { .. })
        ));
    }

    #[test]
    fn channel_expansion_rejected() {
        let mut rng = Rng::new(9);
        let conv = Conv2d::new(3, 3, 8, 2, 1, false, &mut rng);
        assert!(!conv.submersivity().is_submersive());
    }

    #[test]
    fn triangularity_violation_detected() {
        let mut conv = sub_conv(3, 2, 1, 4, 4, 10);
        // Break the constraint manually.
        let idx = conv.widx(1, 1, 0, 2);
        conv.w.data_mut()[idx] = 0.5;
        assert!(!conv.submersivity().is_submersive());
    }

    #[test]
    fn im2col_and_winograd_match_direct_forward() {
        // Stride-1 3×3 with odd H'/W' (clipped Winograd tiles), bias on,
        // and asymmetric H≠W so row/column indexing mistakes can't cancel.
        let mut rng = Rng::new(21);
        let conv = Conv2d::new(3, 4, 6, 1, 1, true, &mut rng);
        let x = input(2, 7, 9, 4, 21);
        let direct = conv.conv_with_algo(&x, conv.w.data(), conv.bias.as_ref(), ConvAlgo::Direct);
        for algo in [ConvAlgo::Im2col, ConvAlgo::Winograd] {
            let y = conv.conv_with_algo(&x, conv.w.data(), conv.bias.as_ref(), algo);
            assert_eq!(y.shape(), direct.shape());
            assert_close(&y, &direct, 1e-5, algo.label());
        }
        // Unpadded: output 5×7, all tiles interior on one axis only.
        let conv = Conv2d::new(3, 3, 3, 1, 0, false, &mut rng);
        let x = input(1, 7, 9, 3, 22);
        let direct = conv.conv_with_algo(&x, conv.w.data(), None, ConvAlgo::Direct);
        for algo in [ConvAlgo::Im2col, ConvAlgo::Winograd] {
            let y = conv.conv_with_algo(&x, conv.w.data(), None, algo);
            assert_close(&y, &direct, 1e-5, algo.label());
        }
    }

    #[test]
    fn im2col_matches_direct_strided_vjp_params() {
        let mut rng = Rng::new(23);
        let conv = Conv2d::new(3, 3, 5, 2, 1, true, &mut rng);
        let x = input(2, 9, 9, 3, 23);
        let (ho, wo) = conv.out_hw(9, 9).unwrap();
        let g = input(2, ho, wo, 5, 24);
        let d_direct = conv.vjp_params_dw_direct(&x, &g, ho, wo);
        let d_im2col = conv.vjp_params_dw_im2col(&x, &g, ho, wo);
        assert_close(&d_im2col, &d_direct, 1e-5, "vjp_params im2col vs direct");
    }

    #[test]
    fn autotune_records_winner_then_serves_from_cache() {
        // Distinct geometry so this test cannot collide with other
        // tests sharing the process-global autotune cache.
        let mut rng = Rng::new(25);
        let conv = Conv2d::new(3, 2, 2, 1, 1, false, &mut rng);
        let x = input(3, 11, 11, 2, 25);
        let first = conv.autotune_with(&x, 0, 1);
        assert_eq!(first.len(), 2, "fwd + vjp_params");
        assert!(first.iter().all(|o| !o.cached));
        // Forward has all three candidates on this k=3/s=1 shape.
        assert_eq!(first[0].candidates.len(), 3);
        let second = conv.autotune_with(&x, 0, 1);
        assert!(second.iter().all(|o| o.cached), "second pass must be free");
        assert_eq!(second[0].algo, first[0].algo);
        // The layer-trait view agrees on the forward key.
        assert_eq!(
            conv.conv_tune_key(x.shape()).as_deref(),
            Some(first[0].key.as_str())
        );
    }

    #[test]
    fn projection_idempotent_and_constraining() {
        let mut rng = Rng::new(11);
        let mut conv = Conv2d::new(3, 6, 6, 2, 1, false, &mut rng);
        conv.project_submersive();
        assert!(conv.submersivity().is_submersive());
        let snapshot = conv.w.clone();
        conv.project_submersive();
        assert_eq!(conv.w, snapshot, "projection must be idempotent");
    }

    #[test]
    fn inverse_1x1_triangular() {
        let mut rng = Rng::new(12);
        let conv = Conv2d::new_submersive(1, 4, 4, 1, 0, true, &mut rng);
        let x = input(2, 5, 5, 4, 12);
        let y = conv.forward(&x);
        let xr = conv.inverse(&y).unwrap();
        assert_close(&xr, &x, 1e-4, "1x1 conv inverse");
    }

    #[test]
    fn inverse_strided_rejected() {
        let conv = sub_conv(3, 2, 1, 4, 4, 13);
        let x = input(1, 9, 9, 4, 13);
        let y = conv.forward(&x);
        assert!(matches!(
            conv.inverse(&y),
            Err(LayerError::NotInvertible { .. })
        ));
    }

    #[test]
    fn minimal_residual_stores_nothing() {
        let conv = sub_conv(3, 2, 1, 4, 4, 14);
        let x = input(1, 9, 9, 4, 14);
        let (_, res_min) = conv.forward_res(&x, ResidualKind::Minimal);
        let (_, res_full) = conv.forward_res(&x, ResidualKind::Full);
        assert_eq!(crate::nn::residual_bytes(&res_min), 0);
        assert_eq!(crate::nn::residual_bytes(&res_full), x.bytes());
    }

    #[test]
    fn spatial_bound_violation_detected_at_vijp() {
        // n = s(n'−1) exactly ⇒ pivot row out of range must be rejected.
        // k=2, s=2, p=1: H' = (H + 2 - 2)/2 + 1 = H/2 + 1; H=4 → H'=3,
        // s(H'-1)=4 = H ⇒ violation.
        let mut rng = Rng::new(15);
        let conv = Conv2d::new_submersive(2, 3, 3, 2, 1, false, &mut rng);
        let x = input(1, 4, 4, 3, 15);
        let (y, res) = conv.forward_res(&x, ResidualKind::Minimal);
        let h = Tensor::zeros(x.shape());
        let _ = y;
        assert!(matches!(
            conv.vijp(&res, &h),
            Err(LayerError::NotSubmersive { .. })
        ));
    }
}
