//! Dense (fully connected) layer `y = x·W + b`, flattening any input rank
//! to `[N, Din]`. Submersive iff `W` has full column rank (generic when
//! `Dout ≤ Din`); its vijp is the Moore–Penrose right-inverse
//! `h' = (h·W)·(WᵀW)⁻¹`, computed by a dense Gram solve — illustrating
//! the paper's point that vijp must be hand-derived per layer class (§7).
//!
//! Parallelism comes entirely through the auto-selected GEMM kernels
//! (`ops::matmul*_into_auto`): with the persistent worker runtime the
//! selection thresholds admit much smaller `[N, Din]·[Din, Dout]`
//! products (region dispatch is a park/wake round-trip, not a thread
//! spawn), so classifier heads parallelize even at small batch sizes —
//! no layer-local pool code is needed here.

use crate::nn::{
    Layer, LayerError, Residual, ResidualData, ResidualKind, Submersivity,
};
use crate::tensor::{ops, Tensor};
use crate::util::Rng;

/// A dense layer with weight `[Din, Dout]` and bias `[Dout]`.
pub struct Dense {
    pub w: Tensor,
    pub bias: Option<Tensor>,
    pub din: usize,
    pub dout: usize,
    label: String,
}

impl Dense {
    pub fn new(din: usize, dout: usize, bias: bool, rng: &mut Rng) -> Dense {
        let w = Tensor::randn(&[din, dout], (2.0 / din as f32).sqrt(), rng);
        Dense {
            w,
            bias: bias.then(|| Tensor::zeros(&[dout])),
            din,
            dout,
            label: format!("dense({din}->{dout})"),
        }
    }

    fn flat(&self, x: &Tensor) -> (usize, usize) {
        let n = x.shape()[0];
        let d: usize = x.shape()[1..].iter().product();
        assert_eq!(d, self.din, "dense input dim {d} != {}", self.din);
        (n, d)
    }
}

impl Layer for Dense {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        let d: usize = in_shape[1..].iter().product();
        if in_shape.is_empty() || d != self.din {
            return Err(LayerError::Shape {
                layer: self.label.clone(),
                reason: format!("expected flattenable to [N,{}], got {in_shape:?}", self.din),
            });
        }
        Ok(vec![in_shape[0], self.dout])
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        // The flatten is a pure view change — run the GEMM directly on the
        // input payload instead of materializing a reshaped copy (the seed
        // allocated one per call; §Perf iteration 5).
        let (n, d) = self.flat(x);
        let mut y = Tensor::zeros(&[n, self.dout]);
        ops::matmul_into_auto(x.data(), self.w.data(), y.data_mut(), n, d, self.dout);
        if let Some(b) = &self.bias {
            for chunk in y.data_mut().chunks_mut(self.dout) {
                for (o, bv) in chunk.iter_mut().zip(b.data()) {
                    *o += bv;
                }
            }
        }
        let res = Residual {
            in_shape: x.shape().to_vec(),
            kind: match kind {
                ResidualKind::Full => ResidualData::Input(x.clone()),
                // Like convolutions: the input-vjp is `g·Wᵀ` — no residual.
                ResidualKind::Minimal => ResidualData::None,
            },
        };
        (y, res)
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        // h = g · Wᵀ (contract over the shared Dout axis). The raw kernels
        // skip shape checks in release builds, so validate here.
        assert_eq!(grad_out.rank(), 2, "dense vjp_input expects [N,Dout]");
        assert_eq!(grad_out.shape()[1], self.dout, "dense grad dim mismatch");
        let n = grad_out.shape()[0];
        let mut g = Tensor::zeros(&[n, self.din]);
        ops::matmul_nt_into_auto(
            grad_out.data(),
            self.w.data(),
            g.data_mut(),
            n,
            self.dout,
            self.din,
        );
        g.reshaped_inplace(&res.in_shape)
    }

    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor> {
        let (n, d) = self.flat(x);
        assert_eq!(grad_out.len(), n * self.dout, "dense grad shape mismatch");
        // dw = xᵀ · g without copying x into a 2-d view.
        let mut dw = Tensor::zeros(&[d, self.dout]);
        ops::matmul_tn_into_auto(x.data(), grad_out.data(), dw.data_mut(), n, d, self.dout);
        let mut grads = vec![dw];
        if self.bias.is_some() {
            let mut db = Tensor::zeros(&[self.dout]);
            for chunk in grad_out.data().chunks(self.dout) {
                for (dv, g) in db.data_mut().iter_mut().zip(chunk) {
                    *dv += g;
                }
            }
            grads.push(db);
        }
        grads
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        if self.dout > self.din {
            return Err(LayerError::NotSubmersive {
                layer: self.label.clone(),
                reason: format!("Dout {} > Din {}", self.dout, self.din),
            });
        }
        let n = res.in_shape[0];
        assert_eq!(h_in.len(), n * self.din, "dense vijp cotangent mismatch");
        // h' = (h·W) (WᵀW)⁻¹
        let mut hw = Tensor::zeros(&[n, self.dout]);
        ops::matmul_into_auto(
            h_in.data(),
            self.w.data(),
            hw.data_mut(),
            n,
            self.din,
            self.dout,
        );
        let gram = ops::matmul_tn(&self.w, &self.w);
        ops::solve_right(&gram, &hw).map_err(|e| LayerError::NotSubmersive {
            layer: self.label.clone(),
            reason: format!("Gram solve failed: {e}"),
        })
    }

    fn jvp_input(&self, _x: &Tensor, u: &Tensor) -> Tensor {
        let n = u.shape()[0];
        assert_eq!(u.len(), n * self.din, "dense jvp tangent mismatch");
        let mut out = Tensor::zeros(&[n, self.dout]);
        ops::matmul_into_auto(u.data(), self.w.data(), out.data_mut(), n, self.din, self.dout);
        out
    }

    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor {
        let (n, d) = self.flat(x);
        let mut out = Tensor::zeros(&[n, self.dout]);
        ops::matmul_into_auto(x.data(), dparams[0].data(), out.data_mut(), n, d, self.dout);
        if self.bias.is_some() {
            for chunk in out.data_mut().chunks_mut(self.dout) {
                for (o, b) in chunk.iter_mut().zip(dparams[1].data()) {
                    *o += b;
                }
            }
        }
        out
    }

    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError> {
        if self.din != self.dout {
            return Err(LayerError::NotInvertible {
                layer: self.label.clone(),
                reason: "non-square weight".into(),
            });
        }
        // x = (y - b) W⁻¹ ⇔ solve x W = (y - b).
        let mut rhs = y.clone();
        if let Some(b) = &self.bias {
            for chunk in rhs.data_mut().chunks_mut(self.dout) {
                for (o, bv) in chunk.iter_mut().zip(b.data()) {
                    *o -= bv;
                }
            }
        }
        ops::solve_right(&self.w, &rhs).map_err(|e| LayerError::NotInvertible {
            layer: self.label.clone(),
            reason: format!("singular weight: {e}"),
        })
    }

    fn submersivity(&self) -> Submersivity {
        if self.dout > self.din {
            return Submersivity::NonSubmersive {
                reason: format!("Dout {} > Din {}", self.dout, self.din),
                fragmental_ok: false,
            };
        }
        // Full column rank is generic; certified at vijp time by the Gram
        // solve's pivot check.
        Submersivity::Submersive { fast_path: true }
    }

    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        2.0 * in_shape[0] as f64 * (self.din * self.dout) as f64
    }

    fn params(&self) -> Vec<&Tensor> {
        match &self.bias {
            Some(b) => vec![&self.w, b],
            None => vec![&self.w],
        }
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        match &mut self.bias {
            Some(b) => vec![&mut self.w, b],
            None => vec![&mut self.w],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;
    use crate::tensor::assert_close;

    #[test]
    fn forward_flattens() {
        let mut rng = Rng::new(0);
        let dense = Dense::new(12, 3, true, &mut rng);
        let x = Tensor::randn(&[2, 2, 3, 2], 1.0, &mut rng);
        let y = dense.forward(&x);
        assert_eq!(y.shape(), &[2, 3]);
    }

    #[test]
    fn vjp_adjoints() {
        let mut rng = Rng::new(1);
        let dense = Dense::new(8, 4, true, &mut rng);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        testutil::check_vjp_input_against_fd(&dense, &x, 80, 1e-3);
        testutil::check_vjp_params_adjoint(&dense, &x, 81, 1e-3);
    }

    #[test]
    fn vijp_right_inverse() {
        let mut rng = Rng::new(2);
        let dense = Dense::new(10, 4, false, &mut rng);
        let x = Tensor::randn(&[3, 10], 1.0, &mut rng);
        testutil::check_vijp_right_inverse(&dense, &x, 82, 1e-2);
    }

    #[test]
    fn vijp_expanding_rejected() {
        let mut rng = Rng::new(3);
        let dense = Dense::new(3, 7, false, &mut rng);
        let x = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let (_, res) = dense.forward_res(&x, ResidualKind::Minimal);
        assert!(dense.vijp(&res, &x).is_err());
    }

    #[test]
    fn inverse_square() {
        let mut rng = Rng::new(4);
        let dense = Dense::new(5, 5, true, &mut rng);
        let x = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let y = dense.forward(&x);
        assert_close(&dense.inverse(&y).unwrap(), &x, 1e-3, "dense inverse");
    }

    #[test]
    fn vjp_input_reshapes_to_input_rank() {
        let mut rng = Rng::new(5);
        let dense = Dense::new(12, 2, false, &mut rng);
        let x = Tensor::randn(&[2, 2, 3, 2], 1.0, &mut rng);
        let (y, res) = dense.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::full(y.shape(), 1.0);
        assert_eq!(dense.vjp_input(&res, &g).shape(), x.shape());
    }
}
