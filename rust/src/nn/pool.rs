//! Max pooling (the paper's final-stage layer: "the final layer performs
//! max pooling and projects the feature map to a scalar", §6.2) and the
//! channel-replicating Upsample used as the networks' entry layer
//! ("an input of size 256×256×3 is first upsampled to ... 128 channels").
//!
//! Max pooling with disjoint windows is *submersive*: its Jacobian rows
//! are distinct standard basis vectors (one per window argmax), hence
//! surjective. Its minimal residual is the argmax index per output — the
//! same data its vjp needs — and its vijp is a plain gather.
//!
//! Upsample is *expanding* (output dim > input dim) so it cannot be
//! submersive; it carries no parameters, and the Moonwalk engine handles
//! it by checkpointing its output cotangent during Phase II (§4.1's
//! "gradient checkpointing" fallback).

use crate::nn::{
    IndexTensor, Layer, LayerError, Residual, ResidualData, ResidualKind, Submersivity,
};
use crate::tensor::Tensor;

/// Max pooling over `[N,H,W,C]` with square window = stride (disjoint).
pub struct MaxPool2d {
    pub window: usize,
}

impl MaxPool2d {
    pub fn new(window: usize) -> MaxPool2d {
        assert!(window > 0);
        MaxPool2d { window }
    }

    fn pool(&self, x: &Tensor) -> (Tensor, Vec<u32>) {
        let (n, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let q = self.window;
        let (ho, wo) = (h / q, w / q);
        assert!(ho > 0 && wo > 0, "pool window {q} larger than input {h}x{w}");
        let mut out = Tensor::zeros(&[n, ho, wo, c]);
        let mut arg = vec![0u32; n * ho * wo * c];
        let xd = x.data();
        let od = out.data_mut();
        for img in 0..n {
            for a in 0..ho {
                for b in 0..wo {
                    for ch in 0..c {
                        let mut best = f32::NEG_INFINITY;
                        let mut best_idx = 0usize;
                        for di in 0..q {
                            for dj in 0..q {
                                let idx =
                                    ((img * h + a * q + di) * w + b * q + dj) * c + ch;
                                if xd[idx] > best {
                                    best = xd[idx];
                                    best_idx = idx;
                                }
                            }
                        }
                        let o = ((img * ho + a) * wo + b) * c + ch;
                        od[o] = best;
                        arg[o] = best_idx as u32;
                    }
                }
            }
        }
        (out, arg)
    }

    fn argmaxes<'a>(&self, res: &'a Residual) -> &'a IndexTensor {
        match &res.kind {
            ResidualData::ArgMax(ix) => ix,
            other => panic!("MaxPool residual must be ArgMax, got {other:?}"),
        }
    }
}

impl Layer for MaxPool2d {
    fn name(&self) -> String {
        format!("maxpool2d({})", self.window)
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        if in_shape.len() != 4 {
            return Err(LayerError::Shape {
                layer: self.name(),
                reason: format!("expected [N,H,W,C], got {in_shape:?}"),
            });
        }
        let q = self.window;
        if in_shape[1] < q || in_shape[2] < q {
            return Err(LayerError::Shape {
                layer: self.name(),
                reason: format!("window {q} larger than spatial dims {in_shape:?}"),
            });
        }
        Ok(vec![in_shape[0], in_shape[1] / q, in_shape[2] / q, in_shape[3]])
    }

    fn forward_res(&self, x: &Tensor, _kind: ResidualKind) -> (Tensor, Residual) {
        // Both tiers need exactly the argmaxes — max pooling is another
        // layer whose parameter-free vjp residual is tiny.
        let (y, arg) = self.pool(x);
        let shape = y.shape().to_vec();
        (
            y,
            Residual {
                in_shape: x.shape().to_vec(),
                kind: ResidualData::ArgMax(IndexTensor::from_vec(arg, &shape)),
            },
        )
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        let ix = self.argmaxes(res);
        let mut out = Tensor::zeros(&res.in_shape);
        let od = out.data_mut();
        for (g, &i) in grad_out.data().iter().zip(ix.data()) {
            od[i as usize] += g;
        }
        out
    }

    fn vjp_params(&self, _x: &Tensor, _grad_out: &Tensor) -> Vec<Tensor> {
        Vec::new()
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        // Rows of J are distinct basis vectors e_{argmax}; the right
        // inverse gathers the input cotangent at each argmax.
        let ix = self.argmaxes(res);
        let hd = h_in.data();
        let data = ix.data().iter().map(|&i| hd[i as usize]).collect();
        Ok(Tensor::from_vec(data, ix.shape()))
    }

    fn jvp_input(&self, x: &Tensor, u: &Tensor) -> Tensor {
        let (_, arg) = self.pool(x);
        let ud = u.data();
        let shape = self.out_shape(x.shape()).expect("validated");
        let data = arg.iter().map(|&i| ud[i as usize]).collect();
        Tensor::from_vec(data, &shape)
    }

    fn jvp_params(&self, x: &Tensor, _dparams: &[Tensor]) -> Tensor {
        let shape = self.out_shape(x.shape()).expect("validated");
        Tensor::zeros(&shape)
    }

    fn inverse(&self, _y: &Tensor) -> Result<Tensor, LayerError> {
        Err(LayerError::NotInvertible {
            layer: self.name(),
            reason: "max pooling discards non-max elements".into(),
        })
    }

    fn submersivity(&self) -> Submersivity {
        // Disjoint windows ⇒ distinct argmaxes ⇒ surjective Jacobian.
        Submersivity::Submersive { fast_path: true }
    }

    fn params(&self) -> Vec<&Tensor> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        Vec::new()
    }
}

/// Channel-replicating upsample: `out[..., c'] = x[..., c' mod Cin]`,
/// rank-preserving on the spatial grid, expanding on channels.
pub struct Upsample {
    pub cin: usize,
    pub cout: usize,
}

impl Upsample {
    pub fn new(cin: usize, cout: usize) -> Upsample {
        assert!(cout >= cin, "upsample must expand channels");
        Upsample { cin, cout }
    }
}

impl Layer for Upsample {
    fn name(&self) -> String {
        format!("upsample({}->{})", self.cin, self.cout)
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        if in_shape.is_empty() || *in_shape.last().unwrap() != self.cin {
            return Err(LayerError::Shape {
                layer: self.name(),
                reason: format!("expected trailing dim {}, got {in_shape:?}", self.cin),
            });
        }
        let mut s = in_shape.to_vec();
        *s.last_mut().unwrap() = self.cout;
        Ok(s)
    }

    fn forward_res(&self, x: &Tensor, _kind: ResidualKind) -> (Tensor, Residual) {
        let shape = self.out_shape(x.shape()).expect("validated");
        let (cin, cout) = (self.cin, self.cout);
        let mut out = Tensor::zeros(&shape);
        {
            let od = out.data_mut();
            // Whole-chunk replication (no per-element modulo, §Perf it. 5).
            for (pix, chunk) in x.data().chunks(cin).enumerate() {
                let dst = &mut od[pix * cout..(pix + 1) * cout];
                let mut off = 0;
                while off + cin <= cout {
                    dst[off..off + cin].copy_from_slice(chunk);
                    off += cin;
                }
                if off < cout {
                    dst[off..].copy_from_slice(&chunk[..cout - off]);
                }
            }
        }
        (
            out,
            Residual {
                in_shape: x.shape().to_vec(),
                kind: ResidualData::None,
            },
        )
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        let (cin, cout) = (self.cin, self.cout);
        let mut out = Tensor::zeros(&res.in_shape);
        {
            let od = out.data_mut();
            for (pix, chunk) in grad_out.data().chunks(cout).enumerate() {
                let dst = &mut od[pix * cin..(pix + 1) * cin];
                for (c2, &g) in chunk.iter().enumerate() {
                    dst[c2 % cin] += g;
                }
            }
        }
        out
    }

    fn vjp_params(&self, _x: &Tensor, _grad_out: &Tensor) -> Vec<Tensor> {
        Vec::new()
    }

    fn vijp(&self, _res: &Residual, _h_in: &Tensor) -> Result<Tensor, LayerError> {
        // Expanding Jacobian ⇒ non-trivial cokernel; the output cotangent
        // is NOT a function of the input cotangent. Engines must
        // checkpoint it in Phase II instead (§4.1).
        Err(LayerError::NotSubmersive {
            layer: self.name(),
            reason: "channel expansion has a non-trivial cokernel".into(),
        })
    }

    fn jvp_input(&self, _x: &Tensor, u: &Tensor) -> Tensor {
        self.forward_res(u, ResidualKind::Minimal).0
    }

    fn jvp_params(&self, x: &Tensor, _dparams: &[Tensor]) -> Tensor {
        let shape = self.out_shape(x.shape()).expect("validated");
        Tensor::zeros(&shape)
    }

    fn inverse(&self, _y: &Tensor) -> Result<Tensor, LayerError> {
        Err(LayerError::NotInvertible {
            layer: self.name(),
            reason: "expanding map".into(),
        })
    }

    fn submersivity(&self) -> Submersivity {
        Submersivity::NonSubmersive {
            reason: "channel expansion (output dim > input dim)".into(),
            fragmental_ok: false,
        }
    }

    fn params(&self) -> Vec<&Tensor> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil;
    use crate::tensor::ops;
    use crate::util::Rng;

    #[test]
    fn pool_known_values() {
        let p = MaxPool2d::new(2);
        let x = Tensor::from_vec(
            vec![1.0, 5.0, 2.0, 3.0, 9.0, 4.0, 0.0, 7.0, 6.0, 8.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            &[1, 4, 4, 1],
        );
        let y = p.forward(&x);
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        assert_eq!(y.data(), &[9.0, 7.0, 8.0, 6.0]);
    }

    #[test]
    fn pool_vjp_scatter() {
        let p = MaxPool2d::new(2);
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[2, 4, 4, 3], 1.0, &mut rng);
        let (y, res) = p.forward_res(&x, ResidualKind::Minimal);
        let g = Tensor::full(y.shape(), 1.0);
        let h = p.vjp_input(&res, &g);
        // Exactly one 1 per pooling window per channel.
        assert_eq!(ops::sum(&h), (2 * 2 * 2 * 3) as f32);
    }

    #[test]
    fn pool_vijp_right_inverse() {
        let p = MaxPool2d::new(2);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[2, 6, 6, 4], 1.0, &mut rng);
        testutil::check_vijp_right_inverse(&p, &x, 70, 1e-5);
    }

    #[test]
    fn pool_jvp_matches_fd() {
        // jvp at a point where argmaxes are stable under perturbation.
        let p = MaxPool2d::new(2);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[1, 4, 4, 2], 1.0, &mut rng);
        let u = Tensor::randn(x.shape(), 0.01, &mut rng);
        let fd = testutil::fd_jvp_input(&p, &x, &u, 1e-3);
        let an = p.jvp_input(&x, &u);
        crate::tensor::assert_close(&an, &fd, 1e-2, "pool jvp");
    }

    #[test]
    fn upsample_replicates_and_adjoints() {
        let up = Upsample::new(2, 5);
        let x = Tensor::from_vec(vec![1.0, 2.0], &[1, 1, 1, 2]);
        let y = up.forward(&x);
        assert_eq!(y.data(), &[1.0, 2.0, 1.0, 2.0, 1.0]);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 3, 3, 2], 1.0, &mut rng);
        testutil::check_vjp_input_against_fd(&up, &x, 71, 1e-3);
    }

    #[test]
    fn upsample_vijp_rejected() {
        let up = Upsample::new(2, 4);
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[1, 2, 2, 2], 1.0, &mut rng);
        let (_, res) = up.forward_res(&x, ResidualKind::Minimal);
        let h = Tensor::zeros(x.shape());
        assert!(up.vijp(&res, &h).is_err());
        assert!(!up.submersivity().is_submersive());
    }
}
