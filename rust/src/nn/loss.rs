//! Loss heads. A loss provides the scalar objective and its gradient with
//! respect to the network output — the starting cotangent of Phase II
//! (`∂J/∂x_L`), which every engine then propagates in its own way.

use crate::tensor::{ops, Tensor};

/// A differentiable scalar loss over the network output.
pub trait Loss: Send + Sync {
    fn name(&self) -> &'static str;

    /// Scalar loss value.
    fn value(&self, y: &Tensor) -> f32;

    /// Gradient of the loss w.r.t. `y` (the output cotangent `∂J/∂x_L`).
    fn grad(&self, y: &Tensor) -> Tensor;

    /// Directional derivative `⟨∂J/∂y, u⟩` (for forward-mode engines);
    /// default goes through `grad`.
    fn jvp(&self, y: &Tensor, u: &Tensor) -> f32 {
        ops::dot(&self.grad(y), u)
    }
}

/// Mean of all outputs — the "project the feature map to a scalar" loss
/// used by the paper's memory/time sweeps (§6.2), where the objective's
/// form is irrelevant and only the differentiation pattern matters.
pub struct MeanLoss;

impl Loss for MeanLoss {
    fn name(&self) -> &'static str {
        "mean"
    }

    fn value(&self, y: &Tensor) -> f32 {
        ops::sum(y) / y.len() as f32
    }

    fn grad(&self, y: &Tensor) -> Tensor {
        Tensor::full(y.shape(), 1.0 / y.len() as f32)
    }
}

/// Softmax cross-entropy with integer class targets over logits `[N, C]`
/// (the Fig.-4 classification head).
pub struct SoftmaxCrossEntropy {
    pub targets: Vec<usize>,
}

impl SoftmaxCrossEntropy {
    pub fn new(targets: Vec<usize>) -> SoftmaxCrossEntropy {
        SoftmaxCrossEntropy { targets }
    }

    /// Row-wise softmax probabilities (numerically stabilized).
    pub fn probs(&self, y: &Tensor) -> Tensor {
        assert_eq!(y.rank(), 2);
        let (n, c) = (y.shape()[0], y.shape()[1]);
        let mut p = Tensor::zeros(&[n, c]);
        for i in 0..n {
            let row = &y.data()[i * c..(i + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0;
            for (j, &v) in row.iter().enumerate() {
                let e = (v - m).exp();
                p.data_mut()[i * c + j] = e;
                z += e;
            }
            for j in 0..c {
                p.data_mut()[i * c + j] /= z;
            }
        }
        p
    }

    /// Classification accuracy of logits against the stored targets.
    pub fn accuracy(&self, y: &Tensor) -> f32 {
        let (n, c) = (y.shape()[0], y.shape()[1]);
        assert_eq!(n, self.targets.len());
        let mut correct = 0;
        for i in 0..n {
            if ops::argmax(&y.data()[i * c..(i + 1) * c]) == self.targets[i] {
                correct += 1;
            }
        }
        correct as f32 / n as f32
    }
}

impl Loss for SoftmaxCrossEntropy {
    fn name(&self) -> &'static str {
        "softmax_xent"
    }

    fn value(&self, y: &Tensor) -> f32 {
        let (n, c) = (y.shape()[0], y.shape()[1]);
        assert_eq!(n, self.targets.len(), "target count mismatch");
        let p = self.probs(y);
        let mut loss = 0.0;
        for i in 0..n {
            loss -= p.data()[i * c + self.targets[i]].max(1e-12).ln();
        }
        loss / n as f32
    }

    fn grad(&self, y: &Tensor) -> Tensor {
        let (n, c) = (y.shape()[0], y.shape()[1]);
        let mut g = self.probs(y);
        for i in 0..n {
            g.data_mut()[i * c + self.targets[i]] -= 1.0;
        }
        ops::scale(&g, 1.0 / n as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mean_loss_grad() {
        let y = Tensor::from_vec(vec![1.0, 3.0, 5.0, 7.0], &[2, 2]);
        let l = MeanLoss;
        assert_eq!(l.value(&y), 4.0);
        assert_eq!(l.grad(&y).data(), &[0.25; 4]);
    }

    #[test]
    fn xent_matches_finite_difference() {
        let mut rng = Rng::new(0);
        let y = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let loss = SoftmaxCrossEntropy::new(vec![2, 0, 4]);
        let g = loss.grad(&y);
        let eps = 1e-3;
        for idx in [0usize, 4, 7, 12, 14] {
            let mut yp = y.clone();
            yp.data_mut()[idx] += eps;
            let mut ym = y.clone();
            ym.data_mut()[idx] -= eps;
            let fd = (loss.value(&yp) - loss.value(&ym)) / (2.0 * eps);
            assert!(
                (fd - g.data()[idx]).abs() < 1e-3,
                "idx {idx}: fd {fd} vs {}",
                g.data()[idx]
            );
        }
    }

    #[test]
    fn probs_sum_to_one() {
        let mut rng = Rng::new(1);
        let y = Tensor::randn(&[4, 6], 3.0, &mut rng);
        let loss = SoftmaxCrossEntropy::new(vec![0; 4]);
        let p = loss.probs(&y);
        for i in 0..4 {
            let s: f32 = p.data()[i * 6..(i + 1) * 6].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn accuracy_counts() {
        let y = Tensor::from_vec(vec![0.9, 0.1, 0.2, 0.8], &[2, 2]);
        let loss = SoftmaxCrossEntropy::new(vec![0, 0]);
        assert_eq!(loss.accuracy(&y), 0.5);
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        let y = Tensor::from_vec(vec![10.0, -10.0, -10.0, 10.0], &[2, 2]);
        let loss = SoftmaxCrossEntropy::new(vec![0, 1]);
        assert!(loss.value(&y) < 1e-3);
    }
}
