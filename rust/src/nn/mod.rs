//! The submersive layer library.
//!
//! Every layer exposes the four differential operators the paper builds on
//! (§3.1, Eqs. 1–3):
//!
//! * `forward`        — `x' = f(x; θ)`
//! * `vjp_input`      — `h = h' · ∂x'/∂x` (standard reverse mode)
//! * `vjp_params`     — `g = h' · ∂x'/∂θ`
//! * **`vijp`**       — `h' = h · (∂x'/∂x)^+` (the paper's novel
//!   vector-inverse-Jacobian product, Eq. 3/9), defined when the layer is
//!   *submersive* — its input-output Jacobian has full row rank (Def. 1).
//! * `jvp_input` / `jvp_params` — forward-mode tangents (for the
//!   forward-mode and projected-forward baselines and pure-forward
//!   Moonwalk).
//! * `inverse`        — exact input reconstruction for *invertible*
//!   configurations (RevBackprop baseline); errs otherwise.
//!
//! Residual storage is explicit and two-tiered, mirroring the paper's
//! Phase-I distinction: [`ResidualKind::Full`] stores whatever Backprop
//! needs to compute *parameter* gradients (typically the layer input),
//! while [`ResidualKind::Minimal`] stores only what the *input* cotangent
//! path needs (LeakyReLU sign bits, pooling argmax indices — and for
//! convolutions **nothing at all**, which is Moonwalk's Phase-I saving).

pub mod activation;
pub mod conv1d;
pub mod conv2d;
pub mod dense;
pub mod loss;
pub mod pool;
pub mod reversible;

pub use activation::LeakyRelu;
pub use conv1d::Conv1d;
pub use conv2d::Conv2d;
pub use dense::Dense;
pub use loss::{Loss, MeanLoss, SoftmaxCrossEntropy};
pub use pool::{MaxPool2d, Upsample};
pub use reversible::{CouplingBlock, MomentumBlock, ResidualBlock};

use crate::tensor::{BitTensor, Tensor};

/// How much residual a forward pass should retain (paper Fig. 1a vs 1b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualKind {
    /// Enough to compute parameter gradients in a later backward pass
    /// (Backprop's requirement): typically the full layer input.
    Full,
    /// Only what `vjp_input` / `vijp` need (Moonwalk Phase I): sign bits,
    /// argmax indices — or nothing.
    Minimal,
}

/// What a layer stored during a forward pass.
#[derive(Debug)]
pub struct Residual {
    /// Input shape (needed to size `vjp_input` outputs; negligible memory).
    pub in_shape: Vec<usize>,
    pub kind: ResidualData,
}

/// Layer-specific residual payloads. All tensor payloads are tracked, so
/// memory profiles see exactly what each method keeps alive.
#[derive(Debug)]
pub enum ResidualData {
    /// Nothing stored (convolutions and dense layers under
    /// [`ResidualKind::Minimal`]: their input-vjp needs only the weights).
    None,
    /// The full layer input (Backprop's residual for parameter grads).
    Input(Tensor),
    /// Sign bits of the input (LeakyReLU — 32× smaller than the input,
    /// paper §4.5).
    Signs(BitTensor),
    /// Flat argmax indices (max pooling); stored as u32 per output element.
    ArgMax(IndexTensor),
    /// Composite-block residual (the reversible blocks of
    /// [`reversible`]): the inner layers' own Minimal residuals, plus
    /// the block input under [`ResidualKind::Full`] — `None` at the
    /// Minimal tier, which is the zero-residual contract that lets a
    /// reversible stack run Moonwalk Phase I storing nothing at all.
    Block {
        /// Block input (Full tier only — what `vjp_params` recomputation
        /// consumes; the Minimal tier stores `None`).
        input: Option<Tensor>,
        /// Inner layers' residuals, in block-specific order.
        inner: Vec<Residual>,
    },
}

/// A tracked u32 index tensor (pooling argmax residuals).
#[derive(Debug)]
pub struct IndexTensor {
    data: Vec<u32>,
    shape: Vec<usize>,
}

impl IndexTensor {
    pub fn from_vec(data: Vec<u32>, shape: &[usize]) -> IndexTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        crate::tensor::tracker::alloc(data.len() * 4);
        IndexTensor {
            data,
            shape: shape.to_vec(),
        }
    }

    pub fn data(&self) -> &[u32] {
        &self.data
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

impl Drop for IndexTensor {
    fn drop(&mut self) {
        crate::tensor::tracker::free(self.data.len() * 4);
    }
}

/// Is a layer submersive (Def. 1), and if so can its vijp avoid the
/// sequential spatial wavefront?
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Submersivity {
    /// The Jacobian is surjective for all valid parameters; `fast_path`
    /// means the vijp elimination has no spatial coupling (paper Alg. 2:
    /// holds for convolutions when `s + p ≥ k`) and is fully parallel
    /// over spatial positions.
    Submersive { fast_path: bool },
    /// Not submersive; `fragmental_ok` means the layer supports the
    /// fragmental-checkpointing reconstruction of §5.1 instead.
    NonSubmersive {
        reason: String,
        fragmental_ok: bool,
    },
}

impl Submersivity {
    pub fn is_submersive(&self) -> bool {
        matches!(self, Submersivity::Submersive { .. })
    }
}

/// Cotangent fragments stored by fragmental gradient checkpointing
/// (paper §5.1 / Alg. 3): the first `k−1` spatial slices of each block of
/// the *output* cotangent, captured during Phase II.
#[derive(Debug)]
pub struct Fragment {
    /// `[n_blocks * (k-1), channels]` stored slices, tracked.
    pub slices: Tensor,
    /// Block size `B` used at capture.
    pub block: usize,
    /// Full output-cotangent shape `[N, L', C']`.
    pub out_shape: Vec<usize>,
}

/// Typed layer errors.
#[derive(Debug, thiserror::Error)]
pub enum LayerError {
    #[error("layer `{layer}` is not submersive: {reason}")]
    NotSubmersive { layer: String, reason: String },
    #[error("layer `{layer}` is not invertible: {reason}")]
    NotInvertible { layer: String, reason: String },
    #[error("layer `{layer}` does not support fragmental checkpointing: {reason}")]
    NoFragmental { layer: String, reason: String },
    #[error("shape error in `{layer}`: {reason}")]
    Shape { layer: String, reason: String },
}

/// The layer interface (see module docs). Object-safe; networks hold
/// `Vec<Box<dyn Layer>>`.
pub trait Layer: Send + Sync {
    /// Human-readable name (used in configs, metrics and errors).
    fn name(&self) -> String;

    /// Output shape for a given input shape.
    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError>;

    /// Forward pass storing the requested residual tier.
    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual);

    /// Forward pass without residuals.
    fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_res(x, ResidualKind::Minimal).0
    }

    /// `h = h' · ∂x'/∂x` from the stored residual.
    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor;

    /// `g_θ = h' · ∂x'/∂θ`, given the layer input explicitly (engines pass
    /// either the stored Full residual or a Phase-III recomputed input).
    /// Returns one tensor per parameter, aligned with [`Layer::params`].
    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor>;

    /// **vijp** — `h' = h · (∂x'/∂x)^+` (paper Eq. 9): recover the output
    /// cotangent from the input cotangent. Requires submersivity; the
    /// residual supplies sign/argmax data where the Jacobian depends on
    /// the input.
    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError>;

    /// Forward-mode input tangent: `u' = (∂x'/∂x) · u`.
    fn jvp_input(&self, x: &Tensor, u: &Tensor) -> Tensor;

    /// Forward-mode parameter tangent: `u' = (∂x'/∂θ) · dθ`.
    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor;

    /// Exact inverse `x = f⁻¹(x')` for invertible configurations
    /// (RevBackprop); errs when the layer is not invertible.
    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError>;

    /// Lemma-1 style submersivity check against the *current* parameters.
    fn submersivity(&self) -> Submersivity;

    /// Parameters (possibly empty).
    fn params(&self) -> Vec<&Tensor>;

    /// Mutable parameters (for the optimizer).
    fn params_mut(&mut self) -> Vec<&mut Tensor>;

    /// Total parameter count.
    fn n_params(&self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }

    /// Project parameters onto the submersive constraint set (Lemma 1 /
    /// §6.4 "constrained convolutions"); the constrained trainer calls
    /// this after every optimizer step. Default: no-op.
    fn project_submersive(&mut self) {}

    /// Rough forward-pass FLOP estimate for an input shape (used by the
    /// Table-1 analytic time model and the planner). Default: one op per
    /// output element.
    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        self.out_shape(in_shape)
            .map(|s| s.iter().product::<usize>() as f64)
            .unwrap_or(0.0)
    }

    /// Fragmental checkpointing (§5.1): capture the minimal cotangent
    /// fragments of `h_out` needed to reconstruct it later.
    fn fragment_capture(&self, _h_out: &Tensor, _block: usize) -> Result<Fragment, LayerError> {
        Err(LayerError::NoFragmental {
            layer: self.name(),
            reason: "not implemented for this layer type".into(),
        })
    }

    /// Fragmental checkpointing: reconstruct the full output cotangent
    /// from the input cotangent and stored fragments (Alg. 3).
    fn fragment_reconstruct(
        &self,
        _frag: &Fragment,
        _h_in: &Tensor,
    ) -> Result<Tensor, LayerError> {
        Err(LayerError::NoFragmental {
            layer: self.name(),
            reason: "not implemented for this layer type".into(),
        })
    }

    /// The canonical conv-autotune cache key of this layer's *forward*
    /// op for an input shape (`tensor::conv_algo`), letting the planner
    /// attach timed-probe data to `Box<dyn Layer>` chains without
    /// downcasting. `None` for layers that are not dispatched convs
    /// (the default).
    fn conv_tune_key(&self, _in_shape: &[usize]) -> Option<String> {
        None
    }

    /// Calibrate this layer's conv-algorithm choices for input `x`,
    /// recording winners in the process-wide autotune cache (see
    /// `Conv1d::autotune` / `Conv2d::autotune`). Layers without
    /// dispatched convs return no outcomes (the default). This is the
    /// only `Layer` entry point that may measure wall-clock time.
    fn conv_autotune(&self, _x: &Tensor) -> Vec<crate::tensor::conv_algo::TuneOutcome> {
        Vec::new()
    }
}

/// Boxed layer alias used throughout.
pub type LayerBox = Box<dyn Layer>;

/// Bytes held by a residual (for memory model cross-checks in tests).
pub fn residual_bytes(res: &Residual) -> usize {
    match &res.kind {
        ResidualData::None => 0,
        ResidualData::Input(t) => t.bytes(),
        ResidualData::Signs(b) => b.bytes(),
        ResidualData::ArgMax(ix) => ix.data().len() * 4,
        ResidualData::Block { input, inner } => {
            input.as_ref().map(Tensor::bytes).unwrap_or(0)
                + inner.iter().map(residual_bytes).sum::<usize>()
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Finite-difference oracles shared by per-layer unit tests.

    use super::*;
    use crate::tensor::ops;

    /// Numerical jvp wrt input: `(f(x+eps*u) - f(x-eps*u)) / (2 eps)`.
    pub fn fd_jvp_input(layer: &dyn Layer, x: &Tensor, u: &Tensor, eps: f32) -> Tensor {
        let xp = ops::add(x, &ops::scale(u, eps));
        let xm = ops::sub(x, &ops::scale(u, eps));
        ops::scale(&ops::sub(&layer.forward(&xp), &layer.forward(&xm)), 0.5 / eps)
    }

    /// Check `<g, u> == <h', jvp(u)>` for random u — validates vjp against
    /// jvp, and jvp against finite differences.
    pub fn check_vjp_input_against_fd(
        layer: &dyn Layer,
        x: &Tensor,
        seed: u64,
        tol: f32,
    ) {
        let mut rng = crate::util::Rng::new(seed);
        let (y, res) = layer.forward_res(x, ResidualKind::Full);
        for trial in 0..3 {
            let u = Tensor::randn(x.shape(), 1.0, &mut rng);
            let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
            let jvp_fd = fd_jvp_input(layer, x, &u, 1e-2);
            let jvp_an = layer.jvp_input(x, &u);
            let fd_dot = ops::dot(&hprime, &jvp_fd);
            let an_dot = ops::dot(&hprime, &jvp_an);
            let scale = an_dot.abs().max(1.0);
            assert!(
                (fd_dot - an_dot).abs() / scale < tol * 10.0,
                "jvp vs fd mismatch (trial {trial}): {fd_dot} vs {an_dot}"
            );
            let g = layer.vjp_input(&res, &hprime);
            let vjp_dot = ops::dot(&g, &u);
            assert!(
                (vjp_dot - an_dot).abs() / scale < tol,
                "vjp vs jvp adjoint mismatch (trial {trial}): {vjp_dot} vs {an_dot}"
            );
        }
    }

    /// Check `<g_θ, dθ> == <h', jvp_params(dθ)>` for random dθ.
    pub fn check_vjp_params_adjoint(layer: &dyn Layer, x: &Tensor, seed: u64, tol: f32) {
        let mut rng = crate::util::Rng::new(seed);
        let y = layer.forward(x);
        for _ in 0..3 {
            let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
            let dparams: Vec<Tensor> = layer
                .params()
                .iter()
                .map(|p| Tensor::randn(p.shape(), 1.0, &mut rng))
                .collect();
            let jp = layer.jvp_params(x, &dparams);
            let lhs: f32 = layer
                .vjp_params(x, &hprime)
                .iter()
                .zip(&dparams)
                .map(|(g, d)| ops::dot(g, d))
                .sum();
            let rhs = ops::dot(&hprime, &jp);
            let scale = rhs.abs().max(1.0);
            assert!(
                (lhs - rhs).abs() / scale < tol,
                "vjp_params adjoint mismatch: {lhs} vs {rhs}"
            );
        }
    }

    /// THE Moonwalk property: vijp is a right-inverse of vjp on the row
    /// space. For any output cotangent h', `vijp(vjp_input(h')) == h'`.
    pub fn check_vijp_right_inverse(layer: &dyn Layer, x: &Tensor, seed: u64, tol: f32) {
        let mut rng = crate::util::Rng::new(seed);
        let (y, res) = layer.forward_res(x, ResidualKind::Minimal);
        for trial in 0..3 {
            let hprime = Tensor::randn(y.shape(), 1.0, &mut rng);
            let h = layer.vjp_input(&res, &hprime);
            let recovered = layer.vijp(&res, &h).expect("layer should be submersive");
            crate::tensor::assert_close(
                &recovered,
                &hprime,
                tol,
                &format!("vijp right-inverse (trial {trial})"),
            );
        }
    }
}
