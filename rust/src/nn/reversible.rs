//! Reversible composite blocks — the paper's best case for Phase-I
//! residual storage (**zero bytes**), grown from the two cited related
//! works: RevNet coupling blocks (Gomez et al., *The Reversible Residual
//! Network*) and momentum residual networks (Sander et al., *Momentum
//! Residual Neural Networks*).
//!
//! All three blocks operate on a channel split of the trailing axis
//! (channel-last layouts `[N, …, C]` with `C` even): `x = (x_a ‖ x_b)`,
//! each half mapped by an inner [`Layer`] that must be shape-preserving
//! on the half width. Their input–output Jacobians are *unit-triangular
//! compositions* (or triangular with a fixed `γ` diagonal for the
//! momentum variant), hence exactly invertible for **any** differentiable
//! inner layer — submersivity of the block does not require submersivity
//! of `f`/`g`. Consequently:
//!
//! * `vijp` is an exact, fixed-point-free analytic inverse built from at
//!   most two inner `vjp_input` calls — no linear solves, no iteration;
//! * the [`ResidualKind::Minimal`] residual stores only the inner
//!   layers' own minimal residuals (nothing at all for conv/dense
//!   inners), so a pure block stack runs Moonwalk Phase I with **zero**
//!   stored bytes and its tracked peak stays flat in depth;
//! * `inverse` reconstructs the input from the output exactly, so the
//!   RevBackprop baseline applies to block stacks too.
//!
//! Why the plain full-width residual `y = x + f(x)` is *not* here: its
//! vijp is `h · (I + J_f)⁻ᵀ`, which for arbitrary `f` needs a linear
//! solve or a fixed-point iteration (i-ResNet style) — both violate the
//! zero-residual, fixed-point-free contract. [`ResidualBlock`] instead
//! restricts `f` to read the first half and write the second
//! (`y = (x_a, x_b + f(x_a))`), the unique additive-residual structure
//! whose Jacobian is nilpotent-above-diagonal (`J_f̃² = 0`), giving the
//! exact one-call inverse `(I + J_f̃)⁻¹ = I − J_f̃`. Stacking two such
//! blocks with swapped halves is exactly the coupling block.
//!
//! Inverse formulas (documented invariants; each is enforced by
//! `rust/tests/reversible.rs` and the per-block unit tests below):
//!
//! | block      | forward                              | vijp (given `h`, returns `h'`)                      |
//! |------------|--------------------------------------|-----------------------------------------------------|
//! | residual   | `y = (xa, xb + f(xa))`               | `h'b = hb;  h'a = ha − f.vjp(h'b)`                  |
//! | coupling   | `y1 = x1 + f(x2); y2 = x2 + g(y1)`   | `h'2 = h2 − f.vjp(h1);  h'1 = h1 − g.vjp(h'2)`      |
//! | momentum   | `v' = γ·v + f(x);  x' = x + v'`      | `w = hv/γ; h'x = hx − f.vjp(w); h'v = w − h'x`      |

use crate::nn::{Layer, LayerBox, LayerError, Residual, ResidualData, ResidualKind, Submersivity};
use crate::tensor::{ops, Tensor};

/// Split the trailing axis in half: `x = (a ‖ b)` per row.
fn split_last(x: &Tensor) -> (Tensor, Tensor) {
    let c = *x.shape().last().expect("split_last needs rank ≥ 1");
    assert!(c % 2 == 0, "reversible split needs an even trailing axis, got {c}");
    let half = c / 2;
    let rows = x.len() / c;
    let mut hshape = x.shape().to_vec();
    *hshape.last_mut().unwrap() = half;
    let mut a = Tensor::zeros(&hshape);
    let mut b = Tensor::zeros(&hshape);
    {
        let xd = x.data();
        let ad = a.data_mut();
        for r in 0..rows {
            ad[r * half..(r + 1) * half].copy_from_slice(&xd[r * c..r * c + half]);
        }
    }
    {
        let xd = x.data();
        let bd = b.data_mut();
        for r in 0..rows {
            bd[r * half..(r + 1) * half].copy_from_slice(&xd[r * c + half..(r + 1) * c]);
        }
    }
    (a, b)
}

/// Inverse of [`split_last`]: interleave two half-width tensors back
/// into one full-width tensor along the trailing axis.
fn concat_last(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "concat_last halves must agree");
    let half = *a.shape().last().expect("concat_last needs rank ≥ 1");
    let c = half * 2;
    let rows = a.len() / half.max(1);
    let mut shape = a.shape().to_vec();
    *shape.last_mut().unwrap() = c;
    let mut y = Tensor::zeros(&shape);
    {
        let ad = a.data();
        let bd = b.data();
        let yd = y.data_mut();
        for r in 0..rows {
            yd[r * c..r * c + half].copy_from_slice(&ad[r * half..(r + 1) * half]);
            yd[r * c + half..(r + 1) * c].copy_from_slice(&bd[r * half..(r + 1) * half]);
        }
    }
    y
}

/// The half-width shape of a block input, or a named shape error.
fn half_shape(in_shape: &[usize], layer: &str) -> Result<Vec<usize>, LayerError> {
    let c = *in_shape.last().ok_or_else(|| LayerError::Shape {
        layer: layer.into(),
        reason: "rank-0 input".into(),
    })?;
    if c % 2 != 0 {
        return Err(LayerError::Shape {
            layer: layer.into(),
            reason: format!("trailing axis {c} must be even for the channel split"),
        });
    }
    let mut h = in_shape.to_vec();
    *h.last_mut().unwrap() = c / 2;
    Ok(h)
}

/// Check an inner layer preserves the half-width shape.
fn check_preserving(
    inner: &dyn Layer,
    half: &[usize],
    block: &str,
) -> Result<(), LayerError> {
    let out = inner.out_shape(half)?;
    if out != half {
        return Err(LayerError::Shape {
            layer: block.into(),
            reason: format!(
                "inner layer `{}` must be shape-preserving on the half width: {half:?} -> {out:?}",
                inner.name()
            ),
        });
    }
    Ok(())
}

/// Unpack a block residual's inner residual list (panics on a foreign
/// residual, like every layer does on a mismatched payload).
fn block_inner<'a>(res: &'a Residual, who: &str) -> (&'a [Residual], Option<&'a Tensor>) {
    match &res.kind {
        ResidualData::Block { inner, input } => (inner.as_slice(), input.as_ref()),
        other => panic!("{who}: expected a Block residual, got {other:?}"),
    }
}

/// Build the block residual for a forward pass: inner residuals always
/// ride at their own Minimal tier (that is all `vjp_input`/`vijp` need);
/// the Full tier adds the block input, which is what a later
/// `vjp_params` recomputation consumes — exactly the `Mθ = input bytes`
/// accounting of every other parameterized layer.
fn block_residual(
    x: &Tensor,
    kind: ResidualKind,
    inner: Vec<Residual>,
) -> Residual {
    Residual {
        in_shape: x.shape().to_vec(),
        kind: ResidualData::Block {
            input: match kind {
                ResidualKind::Full => Some(x.clone()),
                ResidualKind::Minimal => None,
            },
            inner,
        },
    }
}

// ---------------------------------------------------------------------------
// ResidualBlock
// ---------------------------------------------------------------------------

/// A channel-disjoint residual block `y = (x_a, x_b + f(x_a))`: the
/// inner layer reads the first half of the trailing axis and its output
/// is added to the second half. The read/write disjointness makes the
/// residual Jacobian nilpotent (`J² = 0`), so `(I + J)⁻¹ = I − J`
/// exactly — see the module docs for why the full-width `y = x + f(x)`
/// cannot satisfy the fixed-point-free contract.
pub struct ResidualBlock {
    /// The wrapped residual branch (half width → half width).
    pub f: LayerBox,
    label: String,
}

impl ResidualBlock {
    /// Wrap `f` (any shape-preserving half-width layer) as the residual
    /// branch.
    pub fn new(f: LayerBox) -> ResidualBlock {
        let label = format!("residual_block({})", f.name());
        ResidualBlock { f, label }
    }
}

impl Layer for ResidualBlock {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        let half = half_shape(in_shape, &self.label)?;
        check_preserving(self.f.as_ref(), &half, &self.label)?;
        Ok(in_shape.to_vec())
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        let _sp = crate::span!("residual_block.forward");
        let (xa, xb) = split_last(x);
        let (f_out, res_f) = self.f.forward_res(&xa, ResidualKind::Minimal);
        assert_eq!(
            f_out.shape(),
            xa.shape(),
            "{}: inner layer must be shape-preserving",
            self.label
        );
        let yb = ops::add(&xb, &f_out);
        let y = concat_last(&xa, &yb);
        (y, block_residual(x, kind, vec![res_f]))
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        let (inner, _) = block_inner(res, &self.label);
        let (ha_p, hb_p) = split_last(grad_out);
        // ∂y_a/∂x_a = I, ∂y_b/∂x_a = J_f, ∂y_b/∂x_b = I.
        let ha = ops::add(&ha_p, &self.f.vjp_input(&inner[0], &hb_p));
        concat_last(&ha, &hb_p)
    }

    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor> {
        let (xa, _) = split_last(x);
        let (_, hb_p) = split_last(grad_out);
        self.f.vjp_params(&xa, &hb_p)
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        let _sp = crate::span!("residual_block.vijp");
        let (inner, _) = block_inner(res, &self.label);
        let (ha, hb) = split_last(h_in);
        // Unit-triangular inverse: h'b = hb, h'a = ha − h'b·J_f.
        let ha_p = ops::sub(&ha, &self.f.vjp_input(&inner[0], &hb));
        Ok(concat_last(&ha_p, &hb))
    }

    fn jvp_input(&self, x: &Tensor, u: &Tensor) -> Tensor {
        let (xa, _) = split_last(x);
        let (ua, ub) = split_last(u);
        let vb = ops::add(&ub, &self.f.jvp_input(&xa, &ua));
        concat_last(&ua, &vb)
    }

    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor {
        let (xa, _) = split_last(x);
        let vb = self.f.jvp_params(&xa, dparams);
        concat_last(&Tensor::zeros(xa.shape()), &vb)
    }

    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError> {
        // The read channels pass through untouched, so the branch input
        // is available verbatim: xa = ya, xb = yb − f(ya).
        let (ya, yb) = split_last(y);
        let xb = ops::sub(&yb, &self.f.forward(&ya));
        Ok(concat_last(&ya, &xb))
    }

    fn submersivity(&self) -> Submersivity {
        // Unit-triangular Jacobian ⇒ invertible for ANY differentiable
        // inner layer (inner submersivity not required).
        Submersivity::Submersive { fast_path: true }
    }

    fn params(&self) -> Vec<&Tensor> {
        self.f.params()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        self.f.params_mut()
    }

    fn project_submersive(&mut self) {
        self.f.project_submersive();
    }

    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        match half_shape(in_shape, &self.label) {
            Ok(h) => {
                self.f.flops_estimate(&h) + h.iter().product::<usize>() as f64
            }
            Err(_) => 0.0,
        }
    }

    fn conv_autotune(&self, x: &Tensor) -> Vec<crate::tensor::conv_algo::TuneOutcome> {
        let (xa, _) = split_last(x);
        self.f.conv_autotune(&xa)
    }
}

// ---------------------------------------------------------------------------
// CouplingBlock
// ---------------------------------------------------------------------------

/// A RevNet coupling block (Gomez et al.): over the channel split
/// `x = (x1 ‖ x2)`,
///
/// ```text
/// y1 = x1 + f(x2)
/// y2 = x2 + g(y1)
/// ```
///
/// Both halves are updated, so unlike [`ResidualBlock`] no channel
/// passes through untouched — yet the Jacobian is a product of two
/// unit-triangular factors and the block stays exactly invertible:
/// `x2 = y2 − g(y1)`, `x1 = y1 − f(x2)`.
pub struct CouplingBlock {
    /// First branch `f` (reads `x2`, updates the first half).
    pub f: LayerBox,
    /// Second branch `g` (reads `y1`, updates the second half).
    pub g: LayerBox,
    label: String,
}

impl CouplingBlock {
    /// Wrap `f` and `g` (shape-preserving half-width layers).
    pub fn new(f: LayerBox, g: LayerBox) -> CouplingBlock {
        let label = format!("coupling({}|{})", f.name(), g.name());
        CouplingBlock { f, g, label }
    }

    /// The Phase-II cotangent entering `y1` for an output cotangent
    /// `(h1', h2')`: `u = h1' + h2'·J_g` — shared by `vjp_input` and
    /// `vjp_params`.
    fn y1_cotangent(&self, res_g: &Residual, h1_p: &Tensor, h2_p: &Tensor) -> Tensor {
        ops::add(h1_p, &self.g.vjp_input(res_g, h2_p))
    }
}

impl Layer for CouplingBlock {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        let half = half_shape(in_shape, &self.label)?;
        check_preserving(self.f.as_ref(), &half, &self.label)?;
        check_preserving(self.g.as_ref(), &half, &self.label)?;
        Ok(in_shape.to_vec())
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        let _sp = crate::span!("coupling.forward");
        let (x1, x2) = split_last(x);
        let (f_out, res_f) = self.f.forward_res(&x2, ResidualKind::Minimal);
        assert_eq!(
            f_out.shape(),
            x1.shape(),
            "{}: inner `f` must be shape-preserving",
            self.label
        );
        let y1 = ops::add(&x1, &f_out);
        let (g_out, res_g) = self.g.forward_res(&y1, ResidualKind::Minimal);
        assert_eq!(
            g_out.shape(),
            x2.shape(),
            "{}: inner `g` must be shape-preserving",
            self.label
        );
        let y2 = ops::add(&x2, &g_out);
        let y = concat_last(&y1, &y2);
        (y, block_residual(x, kind, vec![res_f, res_g]))
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        let (inner, _) = block_inner(res, &self.label);
        let (h1_p, h2_p) = split_last(grad_out);
        let u = self.y1_cotangent(&inner[1], &h1_p, &h2_p);
        // x1 feeds y1 (identity): h_x1 = u.
        // x2 feeds y2 (identity) and y1 via f: h_x2 = h2' + u·J_f.
        let h_x2 = ops::add(&h2_p, &self.f.vjp_input(&inner[0], &u));
        concat_last(&u, &h_x2)
    }

    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor> {
        // Engines hand the block input (stored Full residual or a
        // Phase-III recomputed activation); rebuild the inner forward
        // state (y1, res_g) from it, then route the cotangents.
        let (x1, x2) = split_last(x);
        let y1 = ops::add(&x1, &self.f.forward(&x2));
        let (_, res_g) = self.g.forward_res(&y1, ResidualKind::Minimal);
        let (h1_p, h2_p) = split_last(grad_out);
        let u = self.y1_cotangent(&res_g, &h1_p, &h2_p);
        let mut grads = self.f.vjp_params(&x2, &u);
        grads.extend(self.g.vjp_params(&y1, &h2_p));
        grads
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        let _sp = crate::span!("coupling.vijp");
        let (inner, _) = block_inner(res, &self.label);
        let (h1, h2) = split_last(h_in);
        // Invert the two unit-triangular factors in reverse order:
        // h2' = h2 − h1·J_f, then h1' = h1 − h2'·J_g.
        let h2_p = ops::sub(&h2, &self.f.vjp_input(&inner[0], &h1));
        let h1_p = ops::sub(&h1, &self.g.vjp_input(&inner[1], &h2_p));
        Ok(concat_last(&h1_p, &h2_p))
    }

    fn jvp_input(&self, x: &Tensor, u: &Tensor) -> Tensor {
        let (x1, x2) = split_last(x);
        let y1 = ops::add(&x1, &self.f.forward(&x2));
        let (u1, u2) = split_last(u);
        let v1 = ops::add(&u1, &self.f.jvp_input(&x2, &u2));
        let v2 = ops::add(&u2, &self.g.jvp_input(&y1, &v1));
        concat_last(&v1, &v2)
    }

    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor {
        let (x1, x2) = split_last(x);
        let y1 = ops::add(&x1, &self.f.forward(&x2));
        let nf = self.f.params().len();
        let v1 = self.f.jvp_params(&x2, &dparams[..nf]);
        let v2 = ops::add(
            &self.g.jvp_params(&y1, &dparams[nf..]),
            &self.g.jvp_input(&y1, &v1),
        );
        concat_last(&v1, &v2)
    }

    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError> {
        let (y1, y2) = split_last(y);
        let x2 = ops::sub(&y2, &self.g.forward(&y1));
        let x1 = ops::sub(&y1, &self.f.forward(&x2));
        Ok(concat_last(&x1, &x2))
    }

    fn submersivity(&self) -> Submersivity {
        Submersivity::Submersive { fast_path: true }
    }

    fn params(&self) -> Vec<&Tensor> {
        let mut p = self.f.params();
        p.extend(self.g.params());
        p
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut p = self.f.params_mut();
        p.extend(self.g.params_mut());
        p
    }

    fn project_submersive(&mut self) {
        self.f.project_submersive();
        self.g.project_submersive();
    }

    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        match half_shape(in_shape, &self.label) {
            Ok(h) => {
                let elems = h.iter().product::<usize>() as f64;
                self.f.flops_estimate(&h) + self.g.flops_estimate(&h) + 2.0 * elems
            }
            Err(_) => 0.0,
        }
    }

    fn conv_autotune(&self, x: &Tensor) -> Vec<crate::tensor::conv_algo::TuneOutcome> {
        let (x1, x2) = split_last(x);
        let y1 = ops::add(&x1, &self.f.forward(&x2));
        let mut out = self.f.conv_autotune(&x2);
        out.extend(self.g.conv_autotune(&y1));
        out
    }
}

// ---------------------------------------------------------------------------
// MomentumBlock
// ---------------------------------------------------------------------------

/// A momentum residual block (Sander et al.): the state carries a
/// velocity in the second half of the trailing axis, `x = (x_s ‖ v_s)`:
///
/// ```text
/// v' = γ·v_s + f(x_s)
/// x' = x_s + v'
/// ```
///
/// The Jacobian is triangular with diagonal blocks `{I, γI}`, so the
/// block is exactly invertible whenever `γ ≠ 0` — enforced at
/// construction (`γ ∈ (0, 1]`, the damping regime of the paper).
pub struct MomentumBlock {
    /// The force branch `f` (reads the position half).
    pub f: LayerBox,
    /// Velocity damping factor `γ ∈ (0, 1]`.
    pub gamma: f32,
    label: String,
}

impl MomentumBlock {
    /// Wrap `f` with damping `γ`; asserts `0 < γ ≤ 1` (at `γ = 0` the
    /// velocity channels leave the Jacobian's row space and the block
    /// stops being submersive).
    pub fn new(f: LayerBox, gamma: f32) -> MomentumBlock {
        assert!(
            gamma > 0.0 && gamma <= 1.0,
            "momentum block needs γ ∈ (0, 1], got {gamma}"
        );
        let label = format!("momentum(g={gamma},{})", f.name());
        MomentumBlock { f, gamma, label }
    }
}

impl Layer for MomentumBlock {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>, LayerError> {
        let half = half_shape(in_shape, &self.label)?;
        check_preserving(self.f.as_ref(), &half, &self.label)?;
        Ok(in_shape.to_vec())
    }

    fn forward_res(&self, x: &Tensor, kind: ResidualKind) -> (Tensor, Residual) {
        let _sp = crate::span!("momentum.forward");
        let (xs, vs) = split_last(x);
        let (f_out, res_f) = self.f.forward_res(&xs, ResidualKind::Minimal);
        assert_eq!(
            f_out.shape(),
            xs.shape(),
            "{}: inner layer must be shape-preserving",
            self.label
        );
        let mut v_new = ops::scale(&vs, self.gamma);
        ops::axpy_inplace(&mut v_new, 1.0, &f_out);
        let x_new = ops::add(&xs, &v_new);
        let y = concat_last(&x_new, &v_new);
        (y, block_residual(x, kind, vec![res_f]))
    }

    fn vjp_input(&self, res: &Residual, grad_out: &Tensor) -> Tensor {
        let (inner, _) = block_inner(res, &self.label);
        let (hx_p, hv_p) = split_last(grad_out);
        // Both outputs receive f(x_s) and γ·v_s, so their cotangents
        // travel together: w = hx' + hv'.
        let w = ops::add(&hx_p, &hv_p);
        let h_xs = ops::add(&hx_p, &self.f.vjp_input(&inner[0], &w));
        let h_vs = ops::scale(&w, self.gamma);
        concat_last(&h_xs, &h_vs)
    }

    fn vjp_params(&self, x: &Tensor, grad_out: &Tensor) -> Vec<Tensor> {
        let (xs, _) = split_last(x);
        let (hx_p, hv_p) = split_last(grad_out);
        let w = ops::add(&hx_p, &hv_p);
        self.f.vjp_params(&xs, &w)
    }

    fn vijp(&self, res: &Residual, h_in: &Tensor) -> Result<Tensor, LayerError> {
        let _sp = crate::span!("momentum.vijp");
        let (inner, _) = block_inner(res, &self.label);
        let (h_xs, h_vs) = split_last(h_in);
        // From h_vs = γ·(hx' + hv') recover the shared term, then peel
        // hx' off the position row: hx' = h_xs − w·J_f, hv' = w − hx'.
        let w = ops::scale(&h_vs, 1.0 / self.gamma);
        let hx_p = ops::sub(&h_xs, &self.f.vjp_input(&inner[0], &w));
        let hv_p = ops::sub(&w, &hx_p);
        Ok(concat_last(&hx_p, &hv_p))
    }

    fn jvp_input(&self, x: &Tensor, u: &Tensor) -> Tensor {
        let (xs, _) = split_last(x);
        let (us, uv) = split_last(u);
        let mut dv = ops::scale(&uv, self.gamma);
        ops::axpy_inplace(&mut dv, 1.0, &self.f.jvp_input(&xs, &us));
        let dx = ops::add(&us, &dv);
        concat_last(&dx, &dv)
    }

    fn jvp_params(&self, x: &Tensor, dparams: &[Tensor]) -> Tensor {
        let (xs, _) = split_last(x);
        let dv = self.f.jvp_params(&xs, dparams);
        concat_last(&dv, &dv)
    }

    fn inverse(&self, y: &Tensor) -> Result<Tensor, LayerError> {
        let (x_new, v_new) = split_last(y);
        let xs = ops::sub(&x_new, &v_new);
        let vs = ops::scale(&ops::sub(&v_new, &self.f.forward(&xs)), 1.0 / self.gamma);
        Ok(concat_last(&xs, &vs))
    }

    fn submersivity(&self) -> Submersivity {
        // γ > 0 by construction ⇒ the triangular Jacobian has a full
        // diagonal and the block is always submersive.
        Submersivity::Submersive { fast_path: true }
    }

    fn params(&self) -> Vec<&Tensor> {
        self.f.params()
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        self.f.params_mut()
    }

    fn project_submersive(&mut self) {
        self.f.project_submersive();
    }

    fn flops_estimate(&self, in_shape: &[usize]) -> f64 {
        match half_shape(in_shape, &self.label) {
            Ok(h) => {
                let elems = h.iter().product::<usize>() as f64;
                self.f.flops_estimate(&h) + 3.0 * elems
            }
            Err(_) => 0.0,
        }
    }

    fn conv_autotune(&self, x: &Tensor) -> Vec<crate::tensor::conv_algo::TuneOutcome> {
        let (xs, _) = split_last(x);
        self.f.conv_autotune(&xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::testutil::{
        check_vijp_right_inverse, check_vjp_input_against_fd, check_vjp_params_adjoint,
    };
    use crate::nn::{Dense, LeakyRelu};
    use crate::tensor::assert_close;
    use crate::util::Rng;

    fn dense_block(c: usize, seed: u64) -> (LayerBox, LayerBox) {
        let mut rng = Rng::new(seed);
        (
            Box::new(Dense::new(c, c, true, &mut rng)),
            Box::new(Dense::new(c, c, true, &mut rng)),
        )
    }

    #[test]
    fn split_concat_roundtrip() {
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[2, 3, 6], 1.0, &mut rng);
        let (a, b) = split_last(&x);
        assert_eq!(a.shape(), &[2, 3, 3]);
        assert_eq!(concat_last(&a, &b), x);
    }

    #[test]
    fn residual_block_quartet() {
        let mut rng = Rng::new(1);
        let block = ResidualBlock::new(Box::new(Dense::new(4, 4, true, &mut rng)));
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        check_vjp_input_against_fd(&block, &x, 10, 1e-3);
        check_vjp_params_adjoint(&block, &x, 11, 1e-3);
        check_vijp_right_inverse(&block, &x, 12, 1e-3);
    }

    #[test]
    fn residual_block_nonlinear_inner() {
        // Inner submersivity is NOT required: LeakyReLU is submersive,
        // but the point is that the sign-dependent Jacobian rides in the
        // inner Minimal residual and the block inverse stays exact.
        let mut rng = Rng::new(2);
        let block = ResidualBlock::new(Box::new(LeakyRelu::new(0.3)));
        let x = Tensor::randn(&[2, 5, 6], 1.0, &mut rng);
        check_vjp_input_against_fd(&block, &x, 20, 1e-3);
        check_vijp_right_inverse(&block, &x, 21, 1e-3);
        assert_eq!(block.n_params(), 0);
    }

    #[test]
    fn coupling_block_quartet() {
        let (f, g) = dense_block(4, 3);
        let block = CouplingBlock::new(f, g);
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        check_vjp_input_against_fd(&block, &x, 30, 1e-3);
        check_vjp_params_adjoint(&block, &x, 31, 1e-3);
        check_vijp_right_inverse(&block, &x, 32, 1e-3);
    }

    #[test]
    fn coupling_block_mixed_inner() {
        // A nonlinear g branch: the y1 recomputation in vjp_params and
        // the stored res_g must agree.
        let mut rng = Rng::new(5);
        let block = CouplingBlock::new(
            Box::new(Dense::new(3, 3, false, &mut rng)),
            Box::new(LeakyRelu::new(0.2)),
        );
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        check_vjp_input_against_fd(&block, &x, 40, 1e-3);
        check_vjp_params_adjoint(&block, &x, 41, 1e-3);
        check_vijp_right_inverse(&block, &x, 42, 1e-3);
    }

    #[test]
    fn momentum_block_quartet() {
        let mut rng = Rng::new(6);
        let block = MomentumBlock::new(Box::new(Dense::new(4, 4, true, &mut rng)), 0.9);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        check_vjp_input_against_fd(&block, &x, 50, 1e-3);
        check_vjp_params_adjoint(&block, &x, 51, 1e-3);
        check_vijp_right_inverse(&block, &x, 52, 1e-3);
    }

    #[test]
    fn inverses_are_exact() {
        let mut rng = Rng::new(7);
        let (f, g) = dense_block(3, 8);
        let blocks: Vec<LayerBox> = vec![
            Box::new(ResidualBlock::new(Box::new(Dense::new(3, 3, true, &mut rng)))),
            Box::new(CouplingBlock::new(f, g)),
            Box::new(MomentumBlock::new(Box::new(LeakyRelu::new(0.4)), 0.7)),
        ];
        for block in &blocks {
            let x = Tensor::randn(&[2, 6], 1.0, &mut rng);
            let y = block.forward(&x);
            let rec = block.inverse(&y).unwrap();
            assert_close(&rec, &x, 1e-4, &block.name());
        }
    }

    #[test]
    fn zero_residual_at_minimal_tier() {
        let mut rng = Rng::new(9);
        let (f, g) = dense_block(4, 10);
        let block = CouplingBlock::new(f, g);
        let x = Tensor::randn(&[2, 8], 1.0, &mut rng);
        let (_, res_min) = block.forward_res(&x, ResidualKind::Minimal);
        assert_eq!(crate::nn::residual_bytes(&res_min), 0, "the paper's best case");
        let (_, res_full) = block.forward_res(&x, ResidualKind::Full);
        assert_eq!(crate::nn::residual_bytes(&res_full), x.bytes());
    }

    #[test]
    fn shape_errors_are_named() {
        let mut rng = Rng::new(11);
        // Odd trailing axis.
        let block = ResidualBlock::new(Box::new(LeakyRelu::new(0.1)));
        let err = block.out_shape(&[2, 5]).unwrap_err();
        assert!(err.to_string().contains("even"), "{err}");
        // Non-preserving inner layer.
        let block = CouplingBlock::new(
            Box::new(Dense::new(4, 2, false, &mut rng)),
            Box::new(LeakyRelu::new(0.1)),
        );
        let err = block.out_shape(&[2, 8]).unwrap_err();
        assert!(err.to_string().contains("shape-preserving"), "{err}");
    }

    #[test]
    #[should_panic(expected = "γ ∈ (0, 1]")]
    fn momentum_rejects_zero_gamma() {
        MomentumBlock::new(Box::new(LeakyRelu::new(0.1)), 0.0);
    }

    #[test]
    fn params_order_is_f_then_g() {
        let (f, g) = dense_block(3, 12);
        let f_w0 = f.params()[0].data()[0];
        let g_w0 = g.params()[0].data()[0];
        let block = CouplingBlock::new(f, g);
        let ps = block.params();
        assert_eq!(ps.len(), 4); // w+b for each branch
        assert_eq!(ps[0].data()[0], f_w0);
        assert_eq!(ps[2].data()[0], g_w0);
    }
}
