//! Synthetic datasets — the ImageNet substitute (DESIGN.md §2).
//!
//! The Fig.-4 experiment only needs a non-trivial, learnable multi-class
//! task; we generate class-conditional Gabor-like oriented textures with
//! additive noise. Class `c` determines the orientation and frequency of
//! a sinusoidal grating; per-sample random phase and noise make the task
//! non-memorizable. The same generator produces 1-D waveforms for the
//! fragmental experiments.

use crate::tensor::Tensor;
use crate::util::Rng;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub classes: usize,
    pub hw: usize,
    pub cin: usize,
    pub noise: f32,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            classes: 8,
            hw: 64,
            cin: 3,
            noise: 0.3,
            seed: 0,
        }
    }
}

/// An in-memory labelled dataset with deterministic train/test splits.
pub struct TextureDataset {
    pub spec: SyntheticSpec,
    images: Vec<Vec<f32>>,
    labels: Vec<usize>,
}

impl TextureDataset {
    /// Generate `n` samples (2-D images `[hw, hw, cin]`).
    pub fn generate(spec: SyntheticSpec, n: usize) -> TextureDataset {
        let mut rng = Rng::new(spec.seed ^ 0x7e57_da7au64);
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(spec.classes);
            images.push(Self::render(&spec, class, &mut rng));
            labels.push(class);
        }
        TextureDataset {
            spec,
            images,
            labels,
        }
    }

    /// One Gabor-like texture for a class.
    fn render(spec: &SyntheticSpec, class: usize, rng: &mut Rng) -> Vec<f32> {
        let hw = spec.hw;
        let cin = spec.cin;
        // Class determines orientation + spatial frequency.
        let theta = std::f32::consts::PI * class as f32 / spec.classes as f32;
        let freq = 2.0 + (class % 4) as f32 * 1.5;
        let phase = rng.uniform_range(0.0, std::f64::consts::TAU) as f32;
        let (ct, st) = (theta.cos(), theta.sin());
        let mut img = vec![0.0f32; hw * hw * cin];
        for i in 0..hw {
            for j in 0..hw {
                let u = i as f32 / hw as f32 - 0.5;
                let v = j as f32 / hw as f32 - 0.5;
                let t = (u * ct + v * st) * freq * std::f32::consts::TAU + phase;
                let base = t.sin();
                for c in 0..cin {
                    // Mild per-channel modulation so channels are informative.
                    let chan = base * (1.0 - 0.15 * c as f32)
                        + spec.noise * rng.normal() as f32;
                    img[(i * hw + j) * cin + c] = chan;
                }
            }
        }
        img
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// A batch `[batch, hw, hw, cin]` + labels, by sample indices.
    pub fn batch(&self, indices: &[usize]) -> (Tensor, Vec<usize>) {
        let (data, labels) = self.batch_raw(indices);
        (
            Tensor::from_vec(data, &self.batch_shape(indices.len())),
            labels,
        )
    }

    /// [`Self::batch`]'s payload as plain (tracker-invisible) vectors —
    /// for the prefetch pipeline's producer thread, which must not touch
    /// the global allocation tracker while the training thread holds a
    /// `tracker::measure` window open. Convert on the consuming thread
    /// with `Tensor::from_vec(data, &batch_shape(n))` (zero-copy).
    pub fn batch_raw(&self, indices: &[usize]) -> (Vec<f32>, Vec<usize>) {
        let per = self.spec.hw * self.spec.hw * self.spec.cin;
        let mut data = Vec::with_capacity(indices.len() * per);
        let mut labels = Vec::with_capacity(indices.len());
        for &i in indices {
            data.extend_from_slice(&self.images[i]);
            labels.push(self.labels[i]);
        }
        (data, labels)
    }

    /// Tensor shape of an `n`-sample batch.
    pub fn batch_shape(&self, n: usize) -> Vec<usize> {
        vec![n, self.spec.hw, self.spec.hw, self.spec.cin]
    }

    /// Deterministic shuffled batch iterator for one epoch.
    pub fn epoch_batches(&self, batch: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        idx.chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| c.to_vec())
            .collect()
    }

    /// [`Self::epoch_batches`] on a **splittable per-epoch stream**: the
    /// shuffle is drawn from `stream_seed(seed, epoch)` rather than a
    /// live generator, so the result depends only on `(seed, epoch)` —
    /// never on how much randomness the caller consumed before. This is
    /// what lets sharded (`replicas = N`) and unsharded runs provably
    /// draw the same global sample sequence (`distributed::pipeline`).
    pub fn epoch_batches_seeded(&self, batch: usize, seed: u64, epoch: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(crate::util::rng::stream_seed(seed, &[epoch]));
        self.epoch_batches(batch, &mut rng)
    }

    /// Split off the last `frac` of samples as a test set.
    pub fn split(mut self, frac: f64) -> (TextureDataset, TextureDataset) {
        let n_test = ((self.len() as f64) * frac).round() as usize;
        let n_train = self.len() - n_test;
        let test_imgs = self.images.split_off(n_train);
        let test_labels = self.labels.split_off(n_train);
        let test = TextureDataset {
            spec: self.spec.clone(),
            images: test_imgs,
            labels: test_labels,
        };
        (self, test)
    }

    pub fn labels(&self) -> &[usize] {
        &self.labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = TextureDataset::generate(SyntheticSpec::default(), 4);
        let b = TextureDataset::generate(SyntheticSpec::default(), 4);
        assert_eq!(a.images[2], b.images[2]);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn batch_shapes() {
        let spec = SyntheticSpec {
            hw: 16,
            cin: 2,
            ..Default::default()
        };
        let ds = TextureDataset::generate(spec, 10);
        let (x, y) = ds.batch(&[0, 3, 7]);
        assert_eq!(x.shape(), &[3, 16, 16, 2]);
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean absolute pixel difference between two classes should exceed
        // within-class difference (i.e. class signal exists).
        let spec = SyntheticSpec {
            hw: 16,
            cin: 1,
            noise: 0.05,
            classes: 4,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        let a0 = TextureDataset::render(&spec, 0, &mut rng);
        let a1 = TextureDataset::render(&spec, 0, &mut rng);
        let b0 = TextureDataset::render(&spec, 2, &mut rng);
        let d_within: f32 =
            a0.iter().zip(&a1).map(|(x, y)| (x - y).abs()).sum::<f32>() / a0.len() as f32;
        let d_between: f32 =
            a0.iter().zip(&b0).map(|(x, y)| (x - y).abs()).sum::<f32>() / a0.len() as f32;
        // Random phase makes within-class distances nonzero; between-class
        // should still be at least comparable.
        assert!(d_between > 0.5 * d_within);
    }

    #[test]
    fn split_partitions() {
        let ds = TextureDataset::generate(
            SyntheticSpec {
                hw: 8,
                ..Default::default()
            },
            20,
        );
        let (train, test) = ds.split(0.25);
        assert_eq!(train.len(), 15);
        assert_eq!(test.len(), 5);
    }

    #[test]
    fn seeded_epochs_are_history_independent() {
        let ds = TextureDataset::generate(
            SyntheticSpec {
                hw: 8,
                ..Default::default()
            },
            12,
        );
        let fresh = ds.epoch_batches_seeded(4, 42, 3);
        // Burn arbitrary randomness elsewhere — the seeded epoch must not
        // care (this is exactly what `epoch_batches` cannot guarantee).
        let mut rng = Rng::new(42);
        for _ in 0..17 {
            rng.next_u64();
        }
        assert_eq!(fresh, ds.epoch_batches_seeded(4, 42, 3));
        // Distinct epochs reshuffle.
        assert_ne!(fresh, ds.epoch_batches_seeded(4, 42, 4));
        let mut all: Vec<usize> = fresh.into_iter().flatten().collect();
        all.sort();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_batches_cover_dataset() {
        let ds = TextureDataset::generate(
            SyntheticSpec {
                hw: 8,
                ..Default::default()
            },
            12,
        );
        let mut rng = Rng::new(1);
        let batches = ds.epoch_batches(4, &mut rng);
        assert_eq!(batches.len(), 3);
        let mut all: Vec<usize> = batches.into_iter().flatten().collect();
        all.sort();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
    }
}
