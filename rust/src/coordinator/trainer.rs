//! The config-driven trainer: engine-agnostic, replica-aware training
//! loop with streaming gradient application, an async double-buffered
//! data pipeline, per-step memory/time accounting and JSONL metric
//! logging — the Fig.-4 harness and the e2e example's core.
//!
//! Data parallelism (`replicas > 1`) goes through
//! [`crate::distributed::ReplicaGroup`]: each step's global batch is
//! sharded by the deterministic [`BatchPlan`] (so any replica count draws
//! the same global sample sequence), one engine instance runs per replica
//! on the group's **transport** — in-process on the persistent pool by
//! default, or one worker subprocess per replica under
//! `--transport unix` — and per-layer gradients are all-reduced
//! streamed. The reduce overlaps the replicas' sweeps, and the JSONL log
//! records `reduce_s` / `prefetch_wait_s` / `transport` next to the
//! pool-lifecycle deltas so the overlap is visible per step.
//!
//! Each step starts with the group's parameter sync ([`ReplicaGroup::sync`])
//! — a no-op in-process, a full parameter upload (and dead-worker
//! respawn) over a remote transport — so the optimizer's latest update
//! is what every replica differentiates.

use std::path::Path;

use crate::autodiff::GradEngine;
use crate::coordinator::data::TextureDataset;
use crate::coordinator::optimizer::Optimizer;
use crate::distributed::pipeline::{BatchPlan, Prefetcher};
use crate::distributed::transport::{LossSpec, ShardSpec, Transport};
use crate::distributed::{ReduceOp, ReplicaGroup, RetryPolicy, StepStats};
use crate::model::Network;
use crate::runtime::pool;
use crate::tensor::{tracker, Tensor};
use crate::util::json::Json;
use crate::util::logging::JsonlWriter;
use crate::util::{Rng, Timer};

/// Summary of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f32,
    pub train_accuracy: f32,
    pub test_accuracy: f32,
    pub loss_curve: Vec<f32>,
    pub peak_mem_bytes: usize,
    pub total_time_s: f64,
    /// Replica count the run was sharded across.
    pub replicas: usize,
    /// Transport the replicas executed on (`"local"`, `"unix"`).
    pub transport: String,
    /// Total seconds spent folding in the streamed all-reduce.
    pub reduce_time_s: f64,
    /// Total seconds the step loop was blocked waiting on the prefetcher.
    pub prefetch_wait_s: f64,
    /// Predicted peak extra bytes of the engine's compiled execution
    /// plan (`GradEngine::planned_peak_bytes`) — `None` for
    /// fixed-strategy engines, `Some` for the budgeted `PlannedEngine`.
    /// Compare against [`Self::peak_mem_bytes`], the measured peak.
    pub planned_peak_bytes: Option<usize>,
    /// Total failed step attempts that were retried under the trainer's
    /// [`RetryPolicy`] (0 on a fault-free run).
    pub retries: usize,
    /// Total failovers — elastic membership shrinks onto surviving
    /// workers after a retry budget was exhausted (0 on a fault-free
    /// run).
    pub failovers: usize,
    /// Heartbeat-grace expiries the supervisor hit during this run
    /// (delta of the `supervisor.heartbeat_misses` metric; 0 without a
    /// socket transport or on a healthy run).
    pub heartbeat_misses: u64,
    /// Dead workers respawned during this run (delta of the
    /// `supervisor.respawns` metric).
    pub respawns: u64,
    /// Milliseconds spent sleeping in retry backoff during this run
    /// (delta of the `supervisor.backoff_wait_ms` metric).
    pub backoff_wait_ms: u64,
    /// Straggler flags raised during this run (delta of the
    /// `supervisor.stragglers` metric): shards whose wall time exceeded
    /// the fleet's streaming mean by the configured z-score
    /// (`--straggler-z`). 0 on a local transport or a healthy fleet.
    pub stragglers: u64,
}

/// Classification trainer binding a network, engine, optimizer and data.
pub struct Trainer<'a> {
    pub net: &'a mut Network,
    pub engine: &'a dyn GradEngine,
    pub optimizer: Optimizer,
    pub log_every: usize,
    /// Data-parallel replica count (1 = plain single-stream training).
    /// The global batch must be divisible by it.
    pub replicas: usize,
    /// Replica transport override. `None` executes replicas in-process;
    /// `Some` routes them through the given transport (e.g. a spawned
    /// `UnixTransport`), whose replica count must equal [`Self::replicas`].
    /// A successful `train` hands the transport back here afterwards, so
    /// repeated runs reuse the same workers; a run that fails mid-training
    /// drops it (remote workers are torn down with it).
    pub transport: Option<Box<dyn Transport>>,
    /// How step failures are handled: each failed attempt is re-synced
    /// and replayed bit-exactly (optimizer state untouched, partial
    /// gradient deliveries discarded), and with `failover` enabled an
    /// exhausted retry budget shrinks the elastic membership onto the
    /// survivors instead of aborting the run. The default retries twice
    /// without failover.
    pub retry: RetryPolicy,
    /// Micro-steps accumulated per optimizer step (≥ 1). With `K > 1`
    /// each optimizer step draws `K` consecutive global batches,
    /// **sum**-reduces their gradients ([`ReduceOp::Sum`]) and scales by
    /// `1 / (replicas · K)` before applying — the effective batch is
    /// `batch · K` at the per-step memory footprint of `batch`. `K = 1`
    /// (the default) keeps the original mean-reduced path bit-exactly.
    pub grad_accum: usize,
}

impl<'a> Trainer<'a> {
    pub fn new(
        net: &'a mut Network,
        engine: &'a dyn GradEngine,
        optimizer: Optimizer,
    ) -> Trainer<'a> {
        Trainer {
            net,
            engine,
            optimizer,
            log_every: 10,
            replicas: 1,
            transport: None,
            retry: RetryPolicy::default(),
            grad_accum: 1,
        }
    }

    /// Train for `steps` optimizer steps, logging to `metrics` (JSONL)
    /// when given. `batch` is the **global** batch; with `replicas = N`
    /// each replica computes on `batch / N` samples and gradients are
    /// mean-reduced, so the update equals the single-replica one at the
    /// same effective batch (up to fp reassociation). With
    /// [`Self::grad_accum`]` = K > 1` each optimizer step accumulates
    /// `K` consecutive micro-batches (sum-reduced, scaled by
    /// `1 / (N · K)`), for an effective batch of `batch · K`.
    ///
    /// Step failures (worker death, hangs past the heartbeat grace,
    /// exceeded step deadlines) are retried under [`Self::retry`]:
    /// partial gradient deliveries are discarded, dead workers are
    /// respawned and re-synced, and the identical batch is replayed —
    /// so a recovered run's loss curve is bit-identical to a fault-free
    /// one.
    pub fn train(
        &mut self,
        train: &TextureDataset,
        test: &TextureDataset,
        batch: usize,
        steps: usize,
        rng: &mut Rng,
        metrics: Option<&Path>,
    ) -> anyhow::Result<TrainReport> {
        let replicas = self.replicas.max(1);
        // A lent transport is taken for the run and handed back at the
        // end (see below), so repeated train() calls keep their worker
        // subprocesses instead of silently falling back to in-process.
        let (group, restore_transport) = match self.transport.take() {
            Some(t) => {
                if t.replicas() != replicas {
                    let n = t.replicas();
                    self.transport = Some(t);
                    anyhow::bail!(
                        "transport executes {n} replicas but the trainer is \
                         configured for {replicas}"
                    );
                }
                (ReplicaGroup::with_transport(t)?, true)
            }
            None => (ReplicaGroup::new(replicas)?, false),
        };
        let transport_name = group.transport_name();
        // One stream seed drives the whole run's data order; BatchPlan
        // derives each epoch's shuffle from (seed, epoch), so the
        // sequence is replica-count invariant.
        let data_seed = rng.next_u64();
        let accum = self.grad_accum.max(1);
        let plan = BatchPlan::new(train, batch, replicas, data_seed)?;
        let mut writer = match metrics {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        let mut loss_curve = Vec::with_capacity(steps);
        let mut peak_mem = 0usize;
        let mut reduce_total_s = 0f64;
        let mut prefetch_total_s = 0f64;
        let mut retries_total = 0usize;
        let mut failovers_total = 0usize;
        let heartbeat_ms = group.heartbeat_ms();
        // Supervisor recovery counters are process-global (they also move
        // under other groups/tests in this process), so the run and each
        // step report deltas against baselines captured here.
        let hb0 = crate::obs::metrics::counter("supervisor.heartbeat_misses");
        let rs0 = crate::obs::metrics::counter("supervisor.respawns");
        let bw0 = crate::obs::metrics::counter("supervisor.backoff_wait_ms");
        let st0 = crate::obs::metrics::counter("supervisor.stragglers");
        let timer = Timer::start();
        let depth = self.net.depth();
        // The prefetch producer lives for the duration of the step loop:
        // it materializes and shards batch t+1 while step t computes.
        std::thread::scope(|scope| -> anyhow::Result<()> {
            let prefetch = Prefetcher::spawn(scope, plan, steps * accum);
            for step in 1..=steps {
                let _step_span = crate::span!("train.step", step = step);
                // Push the optimizer's latest parameters to every
                // replica before the step: a no-op in-process, the full
                // upload (+ dead-worker respawn) over a remote
                // transport. Outside the measurement window, so remote
                // serialization never skews the step's memory profile.
                // Parameters don't change between micro-steps, so one
                // sync covers the whole accumulation window.
                group.sync(self.net)?;
                self.optimizer.begin_step();
                let step_timer = Timer::start();
                let pool0 = pool::stats();
                let mut epoch = 0usize;
                let mut step_loss = 0f32;
                let mut step_reduce_s = 0f64;
                let mut step_peak = 0usize;
                let mut step_allocs = 0usize;
                let mut step_stats = StepStats::default();
                let mut step_prefetch_s = 0f64;
                // K > 1 accumulates sum-reduced micro-gradients here;
                // K = 1 applies each layer directly (the original path).
                let mut acc: Vec<Vec<Tensor>> = (0..depth).map(|_| Vec::new()).collect();
                let op = if accum == 1 { ReduceOp::Mean } else { ReduceOp::Sum };
                for micro in 0..accum {
                    let (step_batch, prefetch_wait_s) = prefetch.next()?;
                    prefetch_total_s += prefetch_wait_s;
                    step_prefetch_s += prefetch_wait_s;
                    if micro == 0 {
                        epoch = step_batch.epoch;
                    }
                    // Tensor materialization happens here, on this
                    // thread, *before* the measurement window opens —
                    // the producer only ever built raw
                    // (tracker-invisible) payloads, so per-step
                    // peak/alloc profiles stay deterministic.
                    let shard_tensors = step_batch.into_shards();
                    let shards: Vec<ShardSpec<'_>> = shard_tensors
                        .iter()
                        .map(|(x, labels)| ShardSpec {
                            x,
                            loss: LossSpec::SoftmaxXent(labels),
                        })
                        .collect();
                    // The group streams reduced per-layer gradients;
                    // they are collected here so the (aliasing-safe)
                    // apply happens after the engines release the
                    // network. The figure benches measure the paper's
                    // grad-free accounting with a dropping sink instead.
                    let (out, prof) = {
                        let net = &*self.net;
                        let engine = self.engine;
                        let retry = self.retry;
                        tracker::measure(|| group.step_retrying(net, engine, &shards, op, retry))
                    };
                    let (result, stats) = out?;
                    step_stats.retries += stats.retries;
                    step_stats.failovers += stats.failovers;
                    debug_assert_eq!(result.grads.len(), depth);
                    step_loss += result.loss;
                    step_reduce_s += result.reduce_s;
                    step_peak = step_peak.max(prof.peak_extra_bytes);
                    step_allocs += prof.allocs;
                    if accum == 1 {
                        for (li, grads) in result.grads.iter().enumerate() {
                            if !grads.is_empty() {
                                self.optimizer.apply_layer(self.net, li, grads);
                            }
                        }
                    } else {
                        for (li, grads) in result.grads.into_iter().enumerate() {
                            if grads.is_empty() {
                                continue;
                            }
                            if acc[li].is_empty() {
                                acc[li] = grads;
                            } else {
                                for (a, g) in acc[li].iter_mut().zip(&grads) {
                                    for (av, gv) in a.data_mut().iter_mut().zip(g.data()) {
                                        *av += *gv;
                                    }
                                }
                            }
                        }
                    }
                }
                if accum > 1 {
                    // Sum over replicas × micro-steps of per-shard-mean
                    // gradients; 1/(N·K) turns that into the mean over
                    // the effective batch.
                    let scale = 1.0 / (replicas * accum) as f32;
                    for (li, grads) in acc.iter_mut().enumerate() {
                        if grads.is_empty() {
                            continue;
                        }
                        for g in grads.iter_mut() {
                            for v in g.data_mut() {
                                *v *= scale;
                            }
                        }
                        self.optimizer.apply_layer(self.net, li, grads);
                    }
                }
                let pool1 = pool::stats();
                let step_loss = step_loss / accum as f32;
                retries_total += step_stats.retries;
                failovers_total += step_stats.failovers;
                reduce_total_s += step_reduce_s;
                peak_mem = peak_mem.max(step_peak);
                loss_curve.push(step_loss);
                // Live-telemetry stamps (write-only; nothing the engines
                // compute reads them): the `/healthz` freshness gauge and
                // the coordinator-side step-time histogram `/metrics`
                // scrapes mid-run.
                crate::obs::metrics::gauge_set(
                    crate::obs::http::LAST_STEP_GAUGE,
                    crate::obs::span::now_us() as f64,
                );
                crate::obs::metrics::observe("train.step_seconds", step_timer.elapsed_s());

                if let Some(w) = writer.as_mut() {
                    if step % self.log_every == 0 || step == steps {
                        w.write(&Json::from_pairs(vec![
                            ("step", step.into()),
                            ("epoch", epoch.into()),
                            ("loss", (step_loss as f64).into()),
                            ("peak_mem_bytes", step_peak.into()),
                            ("allocs", step_allocs.into()),
                            ("step_time_s", step_timer.elapsed_s().into()),
                            ("engine", self.engine.name().as_str().into()),
                            ("threads", pool::threads().into()),
                            // Replica-sharding signals: how many replicas
                            // this step fanned across, which transport
                            // executed them, how long the streamed
                            // all-reduce folds took (overlapped with the
                            // sweeps — compare to step_time_s), and how
                            // long the loop waited on the data pipeline
                            // (≈ 0 when prefetch hides it).
                            ("replicas", replicas.into()),
                            ("transport", transport_name.as_str().into()),
                            ("shard_batch", (batch / replicas).into()),
                            ("grad_accum", accum.into()),
                            ("reduce_s", step_reduce_s.into()),
                            ("prefetch_wait_s", step_prefetch_s.into()),
                            // Fault-tolerance signals: failed attempts
                            // replayed this step, membership shrinks
                            // onto survivors, how many executors are
                            // live, and the transport's heartbeat
                            // interval (0 = no heartbeats). All zeros /
                            // full membership on a healthy run.
                            ("retries", step_stats.retries.into()),
                            ("failovers", step_stats.failovers.into()),
                            ("members", group.members().into()),
                            ("heartbeat_ms", (heartbeat_ms as usize).into()),
                            // Supervisor recovery stats, cumulative since
                            // the run started (deltas of the process-global
                            // obs::metrics counters — see TrainReport).
                            (
                                "heartbeat_misses",
                                (crate::obs::metrics::counter("supervisor.heartbeat_misses")
                                    .saturating_sub(hb0) as usize)
                                    .into(),
                            ),
                            (
                                "respawns",
                                (crate::obs::metrics::counter("supervisor.respawns")
                                    .saturating_sub(rs0) as usize)
                                    .into(),
                            ),
                            (
                                "backoff_wait_ms",
                                (crate::obs::metrics::counter("supervisor.backoff_wait_ms")
                                    .saturating_sub(bw0) as usize)
                                    .into(),
                            ),
                            // Straggler flags (z-score outliers of the
                            // fleet's step-time distribution) cumulative
                            // since the run started.
                            (
                                "stragglers",
                                (crate::obs::metrics::counter("supervisor.stragglers")
                                    .saturating_sub(st0) as usize)
                                    .into(),
                            ),
                            // Execution-planner signals: the compiled
                            // plan's predicted peak (0 when the engine
                            // has no plan) next to this step's measured
                            // peak — the budget invariant is
                            // measured_peak staying at or under the
                            // `--budget` the plan was compiled for.
                            (
                                "planned_peak",
                                self.engine.planned_peak_bytes().unwrap_or(0).into(),
                            ),
                            ("measured_peak", step_peak.into()),
                            // Pool-lifecycle deltas for this step:
                            // parallel regions dispatched, worker
                            // wake/park round trips, plus the (monotone)
                            // team size — with replicas > 1 the replica
                            // fan-out region replaces the per-kernel
                            // regions, so these drop sharply.
                            ("pool_regions", (pool1.regions - pool0.regions).into()),
                            ("pool_wakes", (pool1.wakes - pool0.wakes).into()),
                            ("pool_parks", (pool1.parks - pool0.parks).into()),
                            ("pool_workers", pool1.workers_spawned.into()),
                        ]))?;
                        // Flush per step so a crash (or an external tail
                        // -f) never loses the row that was just logged.
                        w.flush()?;
                    }
                }
            }
            Ok(())
        })?;
        if let Some(w) = writer.as_mut() {
            w.flush()?;
        }

        let train_accuracy = self.evaluate(train, batch);
        let test_accuracy = self.evaluate(test, batch);
        if restore_transport {
            self.transport = Some(group.into_transport());
        }
        Ok(TrainReport {
            steps,
            final_loss: *loss_curve.last().unwrap_or(&f32::NAN),
            train_accuracy,
            test_accuracy,
            loss_curve,
            peak_mem_bytes: peak_mem,
            total_time_s: timer.elapsed_s(),
            replicas,
            transport: transport_name,
            reduce_time_s: reduce_total_s,
            prefetch_wait_s: prefetch_total_s,
            planned_peak_bytes: self.engine.planned_peak_bytes(),
            retries: retries_total,
            failovers: failovers_total,
            heartbeat_misses: crate::obs::metrics::counter("supervisor.heartbeat_misses")
                .saturating_sub(hb0),
            respawns: crate::obs::metrics::counter("supervisor.respawns").saturating_sub(rs0),
            backoff_wait_ms: crate::obs::metrics::counter("supervisor.backoff_wait_ms")
                .saturating_sub(bw0),
            stragglers: crate::obs::metrics::counter("supervisor.stragglers")
                .saturating_sub(st0),
        })
    }

    /// Mean accuracy over a dataset.
    pub fn evaluate(&self, data: &TextureDataset, batch: usize) -> f32 {
        if data.is_empty() {
            return f32::NAN;
        }
        let mut correct = 0.0;
        let mut count = 0usize;
        let idx: Vec<usize> = (0..data.len()).collect();
        for chunk in idx.chunks(batch) {
            let (x, labels) = data.batch(chunk);
            let y = self.net.forward(&x);
            let loss = crate::nn::SoftmaxCrossEntropy::new(labels);
            correct += loss.accuracy(&y) * chunk.len() as f32;
            count += chunk.len();
        }
        correct / count as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{Backprop, Moonwalk, MoonwalkOpts};
    use crate::coordinator::data::SyntheticSpec;
    use crate::coordinator::optimizer::OptimizerKind;
    use crate::model::{build_cnn2d, SubmersiveCnn2dSpec};

    fn tiny_setup(seed: u64) -> (Network, TextureDataset, TextureDataset) {
        let mut rng = Rng::new(seed);
        let spec = SubmersiveCnn2dSpec {
            input_hw: 16,
            depth: 2,
            channels: 6,
            cin: 2,
            classes: 3,
            ..Default::default()
        };
        let net = build_cnn2d(&spec, &mut rng);
        let data = TextureDataset::generate(
            SyntheticSpec {
                hw: 16,
                cin: 2,
                classes: 3,
                noise: 0.15,
                seed,
            },
            60,
        );
        let (train, test) = data.split(0.2);
        (net, train, test)
    }

    #[test]
    fn training_reduces_loss_backprop() {
        let (mut net, train, test) = tiny_setup(0);
        let opt = Optimizer::new(OptimizerKind::Adam, 2e-3, &net, true);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        let mut rng = Rng::new(1);
        let rep = t.train(&train, &test, 4, 30, &mut rng, None).unwrap();
        let early: f32 = rep.loss_curve[..5].iter().sum::<f32>() / 5.0;
        let late: f32 = rep.loss_curve[rep.loss_curve.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(late < early, "loss should fall: {early} -> {late}");
    }

    #[test]
    fn training_with_moonwalk_engine_works() {
        let (mut net, train, test) = tiny_setup(2);
        let opt = Optimizer::new(OptimizerKind::Adam, 2e-3, &net, true);
        let engine = Moonwalk::new(MoonwalkOpts::default());
        let mut t = Trainer::new(&mut net, &engine, opt);
        let mut rng = Rng::new(3);
        let rep = t.train(&train, &test, 4, 20, &mut rng, None).unwrap();
        assert!(rep.final_loss.is_finite());
        assert!(rep.peak_mem_bytes > 0);
        assert_eq!(rep.replicas, 1);
        assert_eq!(rep.transport, "local");
    }

    #[test]
    fn training_with_replicas_matches_data_order_and_logs_reduce() {
        let (mut net, train, test) = tiny_setup(6);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        t.replicas = 2;
        t.log_every = 1;
        let dir = std::env::temp_dir().join("moonwalk_trainer_replicas_test");
        let path = dir.join("metrics.jsonl");
        let mut rng = Rng::new(7);
        let rep = t.train(&train, &test, 4, 4, &mut rng, Some(&path)).unwrap();
        assert!(rep.final_loss.is_finite());
        assert_eq!(rep.replicas, 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.req_usize("replicas").unwrap(), 2);
        assert_eq!(first.req_usize("shard_batch").unwrap(), 2);
        assert_eq!(first.req_str("transport").unwrap(), "local");
        assert!(first.get("reduce_s").as_f64().is_some());
        assert!(first.get("prefetch_wait_s").as_f64().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mismatched_transport_replicas_rejected() {
        use crate::distributed::transport::LocalTransport;
        let (mut net, train, test) = tiny_setup(10);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        t.replicas = 2;
        t.transport = Some(Box::new(LocalTransport::new(4)));
        let mut rng = Rng::new(11);
        assert!(t.train(&train, &test, 4, 2, &mut rng, None).is_err());
    }

    #[test]
    fn indivisible_replica_batch_rejected() {
        let (mut net, train, test) = tiny_setup(8);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        t.replicas = 3;
        let mut rng = Rng::new(9);
        assert!(t.train(&train, &test, 4, 2, &mut rng, None).is_err());
    }

    #[test]
    fn metrics_file_written() {
        let (mut net, train, test) = tiny_setup(4);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        t.log_every = 2;
        let dir = std::env::temp_dir().join("moonwalk_trainer_test");
        let path = dir.join("metrics.jsonl");
        let mut rng = Rng::new(5);
        t.train(&train, &test, 4, 6, &mut rng, Some(&path)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() >= 3);
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert!(first.get("loss").as_f64().is_some());
        // Planner signals: measured peak always present; planned peak is
        // 0 for fixed-strategy engines like Backprop.
        assert!(first.req_usize("measured_peak").unwrap() > 0);
        assert_eq!(first.req_usize("planned_peak").unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grad_accum_matches_equivalent_global_batch() {
        // (batch 4, grad_accum 2) and (batch 8, grad_accum 1) consume the
        // identical sample sequence (the epoch shuffle is batch-size
        // invariant) and apply mathematically equal updates, so their
        // loss curves agree up to fp reassociation.
        let run = |batch: usize, accum: usize| {
            let (mut net, train, test) = tiny_setup(20);
            let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
            let engine = Backprop;
            let mut t = Trainer::new(&mut net, &engine, opt);
            t.grad_accum = accum;
            let mut rng = Rng::new(21);
            t.train(&train, &test, batch, 4, &mut rng, None).unwrap()
        };
        let big = run(8, 1);
        let acc = run(4, 2);
        assert_eq!(acc.loss_curve.len(), 4);
        for (a, b) in big.loss_curve.iter().zip(&acc.loss_curve) {
            assert!(
                (a - b).abs() < 1e-3,
                "accumulated loss curve must track the large-batch one: {a} vs {b}"
            );
        }
        assert_eq!(acc.retries, 0);
        assert_eq!(acc.failovers, 0);
    }

    #[test]
    fn metrics_include_fault_tolerance_fields() {
        let (mut net, train, test) = tiny_setup(30);
        let opt = Optimizer::new(OptimizerKind::Sgd, 1e-3, &net, false);
        let engine = Backprop;
        let mut t = Trainer::new(&mut net, &engine, opt);
        t.log_every = 1;
        t.replicas = 2;
        let dir = std::env::temp_dir().join("moonwalk_trainer_fault_fields_test");
        let path = dir.join("metrics.jsonl");
        let mut rng = Rng::new(31);
        let rep = t.train(&train, &test, 4, 2, &mut rng, Some(&path)).unwrap();
        assert_eq!(rep.retries, 0);
        assert_eq!(rep.failovers, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.req_usize("retries").unwrap(), 0);
        assert_eq!(first.req_usize("failovers").unwrap(), 0);
        assert_eq!(first.req_usize("members").unwrap(), 2);
        assert_eq!(first.req_usize("heartbeat_ms").unwrap(), 0);
        assert_eq!(first.req_usize("grad_accum").unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
